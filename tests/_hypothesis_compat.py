"""Fallback shim so the suite collects (and non-hypothesis tests run) when
``hypothesis`` is not installed — e.g. in network-isolated containers.

``install()`` registers stub ``hypothesis`` / ``hypothesis.strategies``
modules in :data:`sys.modules` *before* test modules are imported (conftest
calls it at import time).  Under the stub, ``@given``-decorated tests skip
cleanly at runtime instead of killing collection; everything else is inert.

With real hypothesis installed, ``install()`` is a no-op.
"""
from __future__ import annotations

import sys
import types


def have_hypothesis() -> bool:
    try:
        import hypothesis  # noqa: F401
        return True
    except ImportError:
        return False


class _Strategy:
    """Inert strategy placeholder supporting the combinator surface."""

    def __init__(self, desc: str = "stub"):
        self.desc = desc

    def __repr__(self) -> str:
        return f"<stub strategy {self.desc}>"

    def map(self, fn):
        return _Strategy(f"{self.desc}.map")

    def filter(self, fn):
        return _Strategy(f"{self.desc}.filter")

    def flatmap(self, fn):
        return _Strategy(f"{self.desc}.flatmap")


def _strategy_factory(name):
    def make(*args, **kwargs):
        return _Strategy(name)
    make.__name__ = name
    return make


def _given(*_args, **_kwargs):
    def decorate(fn):
        # NOTE: no functools.wraps — copying __wrapped__ would make pytest
        # resolve the original signature and demand fixtures for the
        # hypothesis-injected arguments.
        def skipper(*args, **kwargs):
            import pytest
            pytest.skip("hypothesis not installed")
        skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
        skipper.__doc__ = fn.__doc__
        skipper.__module__ = fn.__module__
        skipper.is_hypothesis_test = True
        return skipper
    return decorate


class _Settings:
    """Stub for ``hypothesis.settings``: decorator + profile registry."""

    _profiles: dict = {}

    def __init__(self, *args, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, parent=None, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        return None

    @classmethod
    def get_profile(cls, name):
        return cls._profiles.get(name, {})


class _HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    large_base_example = "large_base_example"
    function_scoped_fixture = "function_scoped_fixture"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much,
                cls.large_base_example, cls.function_scoped_fixture]


def _assume(condition) -> bool:
    if not condition:
        import pytest
        pytest.skip("hypothesis.assume(False) under stub")
    return True


_STRATEGY_NAMES = (
    "integers", "floats", "booleans", "text", "binary", "lists", "tuples",
    "dictionaries", "sampled_from", "one_of", "just", "none", "builds",
    "from_regex", "characters", "sets", "permutations", "data",
)


def install() -> bool:
    """Register the stub modules; returns True if the stub was installed."""
    if have_hypothesis():
        return False

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in _STRATEGY_NAMES:
        setattr(st_mod, name, _strategy_factory(name))

    def composite(fn):
        return _strategy_factory(f"composite:{fn.__name__}")
    st_mod.composite = composite

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _Settings
    hyp.HealthCheck = _HealthCheck
    hyp.assume = _assume
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)
    hyp.strategies = st_mod
    hyp.__version__ = "0.0.0-stub"
    hyp.__stub__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    return True
