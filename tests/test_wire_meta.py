"""Property-based tests for the META_BINARY header tag codec.

The binary codec is the reason steady-state sends never pickle: every
header the serving stack emits (job ids, ops, the SLO priority/deadline
keys) must round-trip exactly through ``_enc_header``/``_dec_header``, and
anything outside the flat vocabulary must raise ``_Unencodable`` so the
channel falls back to a *whole-header* pickle (``META_PICKLE``) rather
than corrupting the wire.  Hypothesis drives arbitrary headers over the
full vocabulary; a deterministic corpus keeps the invariants covered when
hypothesis is absent (the stub in ``_hypothesis_compat`` skips ``@given``
tests instead of failing collection).
"""
from __future__ import annotations

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ipc.channel import (DEADLINE_KEY, PRIO_KEY, _HX_KEY, _I64_MAX,
                               _I64_MIN, MetaOverflow, _Unencodable,
                               _dec_header, _enc_header)

_CAP = 1 << 16


def _roundtrip(header: dict, cap: int = _CAP) -> dict:
    buf = bytearray(cap)
    end = _enc_header(memoryview(buf), 0, header)
    assert end <= cap
    return _dec_header(bytes(buf), 0)


# -- strategies over the codec's exact vocabulary ---------------------------

def _scalars():
    return st.one_of(
        st.none(),
        st.booleans(),
        st.integers(min_value=_I64_MIN, max_value=_I64_MAX),
        st.floats(allow_nan=False),       # NaN != NaN breaks dict equality
        st.text(max_size=64),
        st.binary(max_size=64),
    )


def _values():
    # tuples/lists of scalars (one nesting level — the wire vocabulary
    # is recursive, but flat collections are what the stack actually
    # sends, e.g. the heap scatter list under _HX_KEY)
    return st.one_of(
        _scalars(),
        st.lists(_scalars(), max_size=8),
        st.lists(_scalars(), max_size=8).map(tuple),
    )


def _headers():
    return st.dictionaries(st.text(max_size=32), _values(), max_size=16)


@given(_headers())
def test_binary_header_roundtrip(header):
    """Any header inside the vocabulary decodes to an equal dict."""
    assert _roundtrip(header) == header


@given(st.integers(min_value=0, max_value=255),
       st.integers(min_value=_I64_MIN, max_value=_I64_MAX))
def test_slo_keys_roundtrip(prio, deadline):
    """The reserved SLO keys ride the binary codec for any i64 value —
    adding a lane/deadline must never demote a header to pickle."""
    header = {"job_id": 7, "op": "work", PRIO_KEY: prio,
              DEADLINE_KEY: deadline}
    assert _roundtrip(header) == header


@given(st.integers())
def test_int_pickle_boundary(v):
    """Ints encode iff they fit i64; outside that the codec refuses
    (whole-header pickle fallback) instead of truncating."""
    buf = bytearray(_CAP)
    if _I64_MIN <= v <= _I64_MAX:
        assert _roundtrip({"k": v}) == {"k": v}
    else:
        with pytest.raises(_Unencodable):
            _enc_header(memoryview(buf), 0, {"k": v})


@given(_headers())
@settings(max_examples=20)
def test_roundtrip_preserves_types(header):
    """bool/int and tuple/list distinctions survive the wire (True must
    not come back as 1, a scatter tuple must not come back as a list)."""
    out = _roundtrip(header)
    for k, v in header.items():
        assert type(out[k]) is type(v)


# -- deterministic corpus: runs (not skips) without hypothesis --------------

_CORPUS = [
    {},
    {"job_id": 1, "op": "generate", "mode": "pipelined"},
    {"eof": True, "gen": 0, "step": -1},
    {"none": None, "f": 0.5, "neg": -1, "big": _I64_MAX, "small": _I64_MIN},
    {PRIO_KEY: 3, DEADLINE_KEY: 123_456_789_000},
    {_HX_KEY: (0, 4096, 1, 128), "job_id": 9},
    {"t": (1, "a", None, True), "l": [0.25, b"xy"], "empty": ()},
    {"bytes": b"\x00\xff" * 16, "unicode": "π∆-rocket"},
]


def test_corpus_roundtrip():
    for header in _CORPUS:
        assert _roundtrip(header) == header, header


def test_corpus_preserves_types():
    out = _roundtrip({"b": True, "i": 1, "t": (1, 2), "l": [1, 2]})
    assert out["b"] is True and type(out["i"]) is int
    assert type(out["t"]) is tuple and type(out["l"]) is list


def test_unencodable_values_refuse():
    """Rich values (the pickle-fallback boundary): dict values, non-str
    keys, oversized ints, and arbitrary objects all raise _Unencodable."""
    buf = bytearray(_CAP)
    for header in ({"k": {"nested": 1}}, {1: "non-str key"},
                   {"k": 1 << 64}, {"k": object()},
                   {"k": [object()]}):
        with pytest.raises(_Unencodable):
            _enc_header(memoryview(buf), 0, header)


def test_overflow_raises_meta_overflow():
    """A header that cannot fit the meta region raises MetaOverflow (the
    channel aborts the slot) rather than writing out of bounds."""
    with pytest.raises(MetaOverflow):
        _roundtrip({"k": b"x" * 128}, cap=64)
