"""Sharding rules: every produced spec must divide the array dims over the
production mesh (AbstractMesh: no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.launch import specs as specs_mod
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import api as shard_api
from repro.sharding import rules

def _abstract_mesh(sizes, names):
    try:                                   # newer jax: (axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:                      # jax<=0.4.x: ((name, size), ...)
        return AbstractMesh(tuple(zip(names, sizes)))


SINGLE = _abstract_mesh((16, 16), ("data", "model"))
MULTI = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def assert_divisible(spec_tree, abs_tree, mesh):
    sizes = _axis_sizes(mesh)
    flat_s = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_a = jax.tree.leaves(abs_tree)
    assert len(flat_s) == len(flat_a)
    for spec, leaf in zip(flat_s, flat_a):
        entries = tuple(spec)
        assert len(entries) <= leaf.ndim, (spec, leaf.shape)
        for i, entry in enumerate(entries):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            denom = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[i] % denom == 0, \
                f"dim {i} of {leaf.shape} not divisible by {axes} ({spec})"


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    model = build_model(cfg)
    with shard_api.use_mesh(mesh):
        p_abs = specs_mod.params_specs(model)
        p_spec = rules.param_pspecs(cfg, p_abs)
        assert_divisible(p_spec, p_abs, mesh)
        # optimizer moments follow params
        opt_abs = jax.eval_shape(adamw.init, p_abs)
        opt_spec = rules.opt_pspecs(p_spec, opt_abs)
        assert_divisible(opt_spec["m"], opt_abs["m"], mesh)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        pytest.skip("full-attention arch skips long context")
    model = build_model(cfg)
    with shard_api.use_mesh(SINGLE):
        cache_abs = specs_mod.cache_specs(model, shape)
        cache_spec = rules.cache_pspecs(cfg, cache_abs, shape.global_batch)
        assert_divisible(cache_spec, cache_abs, SINGLE)


def test_kv_cache_never_replicated_over_model_axis():
    """KV-head-limited archs must shard seq over model instead (memory!)."""
    cfg = get_config("qwen3-32b")     # kv=8 < 16
    with shard_api.use_mesh(SINGLE):
        spec = rules._kv_spec((64, 128, 32768, 8, 128), cfg, 128)
        flat = []
        for e in tuple(spec):
            flat.extend(e if isinstance(e, tuple) else [e])
        assert "model" in flat, f"cache replicated over TP group: {spec}"


def test_long_context_cache_seq_sharded():
    cfg = get_config("zamba2-2.7b")
    with shard_api.use_mesh(SINGLE):
        spec = rules._kv_spec((9, 1, 524288, 32, 80), cfg, 1)
        assert tuple(spec)[2] is not None, f"seq dim not sharded: {spec}"


def test_batch_specs_divisibility_guard():
    with shard_api.use_mesh(SINGLE):
        sds = jax.ShapeDtypeStruct((1, 128), jnp.int32)
        spec = rules.batch_pspecs({"t": sds})["t"]
        assert tuple(spec)[0] is None          # batch=1: replicated
        sds = jax.ShapeDtypeStruct((256, 128), jnp.int32)
        spec = rules.batch_pspecs({"t": sds})["t"]
        assert tuple(spec)[0] is not None


def test_zero1_respec_adds_data_axis():
    with shard_api.use_mesh(SINGLE):
        specs = {"a": P(None, "model"), "b": P("model", None)}
        shapes = {"a": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  "b": jax.ShapeDtypeStruct((32, 7), jnp.float32)}
        out = rules.zero1_respec(specs, shapes)
        assert tuple(out["a"]) == ("data", "model")
        assert tuple(out["b"])[0] == "model" and tuple(out["b"])[1] is None


def test_constrain_noop_without_mesh():
    shard_api.set_mesh(None)
    x = jnp.ones((4, 4))
    assert shard_api.constrain(x, "batch", None) is x
