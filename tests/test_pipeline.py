"""Pipeline parallelism: GPipe schedule equals sequential application."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.sharding.pipeline import bubble_fraction

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding import api as shard_api
    from repro.sharding.pipeline import pipeline_apply, sequential_apply

    mesh = jax.make_mesh((4, 2), ("stage", "data"))
    n_stages, b, d = 4, 8, 16
    key = jax.random.key(0)
    ws = 0.3 * jax.random.normal(key, (n_stages, d, d))
    bs = 0.1 * jax.random.normal(jax.random.key(1), (n_stages, d))
    params = {"w": ws, "b": bs}
    x = jax.random.normal(jax.random.key(2), (b, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    with shard_api.use_mesh(mesh):
        y_pipe = jax.jit(lambda pp, xx: pipeline_apply(
            stage_fn, pp, xx, axis="stage", n_micro=4))(params, x)
    y_seq = sequential_apply(stage_fn, params, x)
    err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
    assert err < 1e-5, f"pipeline != sequential: {err}"
    print("PIPELINE_OK", err)
""")


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ)
    # pin the subprocess to CPU: the fake 8-device mesh is a host-platform
    # feature, and autodetect hangs probing TPU metadata in network-isolated
    # containers
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=300, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "PIPELINE_OK" in out.stdout


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 4) - 3 / 7) < 1e-9
    assert bubble_fraction(4, 28) < 0.1      # planner sizing rule
