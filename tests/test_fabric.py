"""Multi-client serving fabric: listener, reactor, cross-client batching.

In-process tests drive the real shared-memory protocol with both endpoints
mapped into one address space (identical memory semantics, deterministic
scheduling); the spawn tests then put clients in real processes: gated
concurrent submission so cross-client batch formation is provable, and a
full BatchedServer round trip through ``serve_over_ipc``.
"""
import multiprocessing as mp
import threading
import time

import numpy as np
import pytest
from conftest import wait_until

from repro.core.dispatcher import RequestDispatcher
from repro.core.policy import OffloadPolicy
from repro.ipc import (
    Listener,
    RemoteDispatcherClient,
    ServingFabric,
    ShmMutex,
    TransportSpec,
    connect,
)

TIGHT = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0)
SMALL = TransportSpec(data_slots=4, data_slot_bytes=1 << 20,
                      ctrl_slots=4, ctrl_slot_bytes=4 << 10)


def _echo_dispatcher(policy=TIGHT, **kw) -> RequestDispatcher:
    d = RequestDispatcher(policy, **kw)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    return d


# ---------------------------------------------------------------------------
# cross-process mutex (the registration lock primitive)
# ---------------------------------------------------------------------------

def test_shm_mutex_mutual_exclusion():
    a = ShmMutex("rocket-test-mutex")
    b = ShmMutex("rocket-test-mutex")
    a.acquire(timeout_s=2)
    try:
        with pytest.raises(TimeoutError):
            b.acquire(timeout_s=0.2)
    finally:
        a.release()
    b.acquire(timeout_s=2)          # free again after release
    b.release()
    a.release()                     # idempotent


def test_shm_mutex_breaks_stale_holder():
    dead = ShmMutex("rocket-test-stale", stale_s=0.1)
    dead.acquire(timeout_s=2)
    dead._held.close()
    dead._held = None               # holder "dies": segment left behind
    time.sleep(0.15)
    survivor = ShmMutex("rocket-test-stale", stale_s=0.1)
    survivor.acquire(timeout_s=2)   # breaks the stale lock instead of hanging
    survivor.release()


# ---------------------------------------------------------------------------
# dispatcher: submit/callback path + error containment
# ---------------------------------------------------------------------------

def test_dispatcher_submit_callbacks_all_modes():
    with _echo_dispatcher() as d:
        done = {}
        ev = threading.Event()

        def cb(jid, out):
            done[jid] = out
            if len(done) == 3:
                ev.set()

        jids = [d.submit("double", np.full((4,), i, np.float32),
                         mode=m, on_complete=cb)
                for i, m in enumerate(["sync", "async", "pipelined"])]
        assert ev.wait(timeout=10)
        for i, jid in enumerate(jids):
            np.testing.assert_array_equal(done[jid],
                                          np.full((4,), 2.0 * i, np.float32))


def test_dispatcher_handler_error_contained():
    with RequestDispatcher(TIGHT) as d:
        d.register_handler("boom", lambda x: 1 / 0)
        d.register_handler("ok", lambda x: x + 1)
        jid = d.request("boom", np.zeros(2), mode="async")
        with pytest.raises(ZeroDivisionError):
            d.query(jid, timeout=10)
        # the worker loop survived the handler failure
        jid = d.request("ok", np.zeros(2), mode="async")
        np.testing.assert_array_equal(d.query(jid, timeout=10), np.ones(2))
        # the callback path carries the exception object
        got = {}
        ev = threading.Event()
        d.submit("boom", np.zeros(2), mode="async",
                 on_complete=lambda j, out: (got.update(out=out), ev.set()))
        assert ev.wait(timeout=10)
        assert isinstance(got["out"], ZeroDivisionError)


def test_dispatcher_batch_length_mismatch_surfaces():
    with RequestDispatcher(TIGHT, max_batch_wait_s=0.2) as d:
        d.register_handler("bad", lambda x: x, batch_fn=lambda xs: xs[:-1])
        jids = [d.request("bad", np.zeros(2), mode="pipelined")
                for _ in range(3)]
        # every request in the batch fails loudly (no silent zip truncation
        # leaving the tail uncompleted until its query times out)
        for j in jids:
            with pytest.raises(RuntimeError, match="returned 2 results"):
                d.query(j, timeout=10)


# ---------------------------------------------------------------------------
# listener: registration handshake, refusal, dead-listener connects
# ---------------------------------------------------------------------------

def test_listener_accept_and_refuse():
    with Listener(spec=SMALL, policy=TIGHT, max_clients=1) as lsn:
        got = []
        lsn.on_accept = got.append
        t = threading.Thread(
            target=lambda: got.append(connect(lsn.name, policy=TIGHT)))
        t.start()
        wait_until(lsn.pending, 10, desc="pending registration")
        assert lsn.accept_once() is not None
        t.join(timeout=10)
        server_side, client_side = got
        # the pair really is connected: ping across it
        client_side.send({"x": np.arange(8)}, mode="sync")
        tree, _ = server_side.recv(timeout_s=10)
        np.testing.assert_array_equal(tree["x"], np.arange(8))

        lsn.start()                     # accept loop for the refusal path
        with pytest.raises(ConnectionError, match="full"):
            connect(lsn.name, policy=TIGHT, timeout_s=10)
        client_side.close()
        server_side.close()
    with pytest.raises((ConnectionError, TimeoutError, FileNotFoundError)):
        connect(lsn.name, policy=TIGHT, timeout_s=0.5)


# ---------------------------------------------------------------------------
# reactor fairness + churn (in-process endpoints, real protocol)
# ---------------------------------------------------------------------------

def test_reactor_fairness_flood_does_not_starve():
    d = RequestDispatcher(TIGHT)
    d.register_handler("work", lambda x: (time.sleep(0.003), x * 2)[1])
    with ServingFabric(d, spec=SMALL, policy=TIGHT, own_dispatcher=True,
                       max_inflight=4).start() as fab:
        flooder = RemoteDispatcherClient.connect(fab.name, policy=TIGHT)
        slow = RemoteDispatcherClient.connect(fab.name, policy=TIGHT)
        n_flood, flood_jids = 60, []

        def flood():
            for i in range(n_flood):
                flood_jids.append(flooder.request(
                    "work", np.full((64,), i, np.float32), mode="pipelined"))

        t = threading.Thread(target=flood)
        t.start()
        time.sleep(0.03)                       # flood is well underway
        t0 = time.perf_counter()
        out = slow.request("work", np.ones((64,), np.float32), mode="sync")
        slow_latency = time.perf_counter() - t0
        np.testing.assert_array_equal(out, 2 * np.ones((64,), np.float32))
        # round-robin + admission cap: the slow client was served while the
        # flooder still had a backlog, not behind its entire queue
        conns = {c.cid: c for c in fab.reactor.connections()}
        assert conns[0].replied < n_flood, \
            f"slow client waited out the whole flood ({slow_latency:.3f}s)"
        t.join(timeout=30)
        for j in flood_jids:
            flooder.query(j, timeout=30)
        assert fab.reactor.stats.throttled > 0    # admission cap engaged
        flooder.close()
        slow.close()


def test_client_churn_reaps_connections_and_arenas():
    from multiprocessing import shared_memory

    d = _echo_dispatcher()
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        names = []
        for i in range(3):                     # attach/detach, serially
            c = RemoteDispatcherClient.connect(fab.name, policy=TIGHT)
            names.append(c.transport.name)
            out = c.request("double", np.full((16,), i, np.float32),
                            mode="sync")
            assert float(out[0]) == 2.0 * i
            c.close()
            wait_until(lambda: len(fab.reactor) == 0, 10,
                       desc="connection reap")  # reaped, not leaked
        assert fab.listener.accepted == 3
        assert fab.reactor.stats.disconnects == 3
    for name in names:                         # arenas are unlinked
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name, create=False).close()


def _leaky_fabric_client_entry(name: str) -> None:
    """Connect, leak heap extents as if killed mid-send, raise the closed
    flag (what a crash handler / the OS-level liveness probe would do),
    then die without any orderly teardown."""
    import os
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    heap = client.transport.heap
    assert heap.try_alloc(3 * heap.spec.extent_bytes) is not None
    client.transport.announce_close()
    os._exit(0)


def test_reactor_reaps_leaked_heap_extents_of_dead_client():
    """A client that dies holding allocated extents is crash-reaped by the
    reactor sweep: connection gone, extents counted in stats.heap_reaped,
    arena + heap segment unlinked."""
    from multiprocessing import shared_memory

    d = _echo_dispatcher()
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=_leaky_fabric_client_entry, args=(fab.name,))
        p.start()
        p.join(timeout=60)
        assert p.exitcode == 0
        wait_until(lambda: len(fab.reactor) == 0, 10, desc="crash reap")
        assert fab.reactor.stats.disconnects == 1
        assert fab.reactor.stats.heap_reaped == 4     # 3 extents -> class 4
        name = fab.listener.name
    with pytest.raises(FileNotFoundError):            # heap segment unlinked
        shared_memory.SharedMemory(f"{name}.c0-{p.pid}.h",
                                   create=False).close()


HEAPY = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                      heap_threshold_bytes=1 << 20)


def test_fabric_large_requests_and_replies_ride_the_heap():
    """2 MB requests and replies flow through the fabric on the heap path
    (slots are 1 MB), batch formation gathers straight from extent-backed
    leases, and extents drain back to FREE afterwards."""
    d = RequestDispatcher(HEAPY, max_batch_wait_s=0.02)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    with ServingFabric(d, spec=SMALL, policy=HEAPY,
                       own_dispatcher=True).start() as fab:
        client = RemoteDispatcherClient.connect(fab.name, policy=HEAPY)
        sent = [np.arange(1 << 19, dtype=np.float32) + i for i in range(6)]
        jids = [client.request("double", a, mode="pipelined") for a in sent]
        for a, jid in zip(sent, jids):
            out = client.query(jid, timeout=60)
            assert out.tobytes() == (a * 2).tobytes()
        conn = fab.reactor.connections()[0]
        assert conn.transport.data.stats.heap_recvs == 6   # requests
        assert conn.transport.data.stats.heap_sends == 6   # replies
        assert fab.reactor.stats.zero_copy_recvs == 6
        # lease-based reclamation drained every extent back to FREE
        heap = conn.transport.heap
        wait_until(lambda: (heap.free_extents(heap.rx_dir)
                            == heap.spec.n_extents), 10,
                   desc="rx extents drained to FREE")
        assert heap.free_extents(heap.tx_dir) == heap.spec.n_extents
        client.close()


# ---------------------------------------------------------------------------
# crash soak: clients die mid-datapath under load, sharded reactors reap
# ---------------------------------------------------------------------------

def _soak_victim_heap_entry(name: str, out_q) -> None:
    """Victim A: dies mid-heap-fill — extents allocated (never published),
    closed flag raised (the OS-level liveness signal), no teardown."""
    import os
    client = RemoteDispatcherClient.connect(name, policy=HEAPY, timeout_s=60)
    heap = client.transport.heap
    assert heap.try_alloc(2 * heap.spec.extent_bytes) is not None
    out_q.put(client.transport.name)
    out_q.close()
    out_q.join_thread()                 # flush before dying: put() is async
    client.transport.announce_close()
    os._exit(0)


def _soak_victim_frame_entry(name: str, out_q) -> None:
    """Victim B: dies mid-coalesced-frame — pipelined sends parked in an
    open (unpublished) frame, then the process vanishes."""
    import os
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    for i in range(3):
        client.request("work", np.full((64,), i, np.float32),
                       mode="pipelined")
    out_q.put(client.transport.name)
    out_q.close()
    out_q.join_thread()                 # flush before dying: put() is async
    client.transport.announce_close()
    os._exit(0)


@pytest.mark.slow
def test_crash_soak_sharded_reactors_reap_survivors_hold_slo():
    """Kill clients mid-heap-fill and mid-frame under sustained load on a
    2-shard fabric: every victim is reaped on its shard (connections gone,
    leaked extents reclaimed, shm segments unlinked) while the surviving
    client's lane keeps meeting its deadline — zero sheds, zero misses,
    zero errors."""
    from multiprocessing import shared_memory

    d = RequestDispatcher(HEAPY, max_batch_wait_s=0.005, workers=2)
    d.register_handler("work", lambda x: x + 1,
                       batch_fn=lambda xs: [x + 1 for x in xs])
    with ServingFabric(d, spec=SMALL, policy=HEAPY, own_dispatcher=True,
                       reactors=2).start() as fab:
        survivor = RemoteDispatcherClient.connect(fab.name, policy=HEAPY,
                                                  timeout_s=60, lane=0)
        stop = threading.Event()
        failures: list = []
        served = [0]

        def sustained_load():
            x = np.ones((64,), np.float32)
            while not stop.is_set():
                try:
                    out = survivor.request("work", x, mode="sync",
                                           deadline_ms=5000.0)
                    assert float(out[0]) == 2.0
                    served[0] += 1
                except Exception as e:          # noqa: BLE001 - recorded
                    failures.append(e)
                    return
                time.sleep(0.001)

        loader = threading.Thread(target=sustained_load)
        loader.start()
        ctx = mp.get_context("spawn")
        out_q = ctx.Queue()
        victims = []
        for _ in range(2):                      # 2 rounds x 2 crash modes
            for entry in (_soak_victim_heap_entry, _soak_victim_frame_entry):
                p = ctx.Process(target=entry, args=(fab.name, out_q),
                                daemon=True)
                p.start()
                victims.append(p)
        victim_names = [out_q.get(timeout=120) for _ in victims]
        for p in victims:
            p.join(timeout=60)
            assert p.exitcode == 0
        # both shards reap their dead; only the survivor remains
        wait_until(lambda: sum(len(r) for r in fab.reactors) == 1, 20,
                   desc="victim connections reaped")
        assert sum(r.stats.disconnects for r in fab.reactors) == 4
        assert sum(r.stats.heap_reaped for r in fab.reactors) >= 2
        stop.set()
        loader.join(timeout=30)
        assert not failures, failures
        assert served[0] > 0
        # the survivor's lane never shed or missed through the churn
        assert fab.dispatcher.stats.shed == 0
        snap = fab.slo.snapshot()
        assert snap["deadline_misses"] == 0
        assert snap["lane0"]["misses"] == 0
        # survivor heap state words all back to FREE after sustained load
        heap = survivor.transport.heap
        assert heap.free_extents(heap.rx_dir) == heap.spec.n_extents
        assert heap.free_extents(heap.tx_dir) == heap.spec.n_extents
        survivor.close()
    # no leaked shm: every victim arena AND its heap segment are unlinked
    for nm in victim_names:
        for seg in (nm, f"{nm}.h"):
            with pytest.raises(FileNotFoundError):
                shared_memory.SharedMemory(seg, create=False).close()


# ---------------------------------------------------------------------------
# cross-client batching across real processes
# ---------------------------------------------------------------------------

N_PER_CLIENT = 8


def _batching_client_entry(name: str, marker: int) -> None:
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    # gate: wait until the server says every client is connected, so the
    # pipelined bursts below genuinely overlap across processes
    while int(client.request("gate", np.zeros(1, np.float32),
                             mode="sync")[0]) == 0:
        time.sleep(0.002)
    sent = [np.full((512,), marker * 100 + i, np.float32)
            for i in range(N_PER_CLIENT)]
    jids = [client.request("double", a, mode="pipelined") for a in sent]
    for a, jid in zip(sent, jids):
        out = client.query(jid, timeout=60)
        assert out.tobytes() == (a * 2).tobytes()      # byte-identical, mine
    client.close()


@pytest.mark.slow
def test_cross_client_batching_byte_identical():
    gate = [0.0]
    seen_batches: list[set] = []

    def batch_double(xs):
        seen_batches.append({int(x[0]) // 100 for x in xs})
        time.sleep(0.002)
        return [x * 2 for x in xs]

    # max_batch must exceed one client's burst or its own requests fill
    # every batch before the other client's can mix in
    policy = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                           max_batch=2 * N_PER_CLIENT)
    # a wide window only bounds the *worst* case: the batch executes as soon
    # as max_batch requests are in, so the wait stays short when both client
    # bursts arrive promptly — but a loaded CI box gets 300ms of slack
    d = RequestDispatcher(policy, max_batch_wait_s=0.3)
    d.register_handler("gate", lambda x: np.float32(gate[0]) + x)
    d.register_handler("double", lambda x: x * 2, batch_fn=batch_double)
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        ctx = mp.get_context("spawn")
        procs = [ctx.Process(target=_batching_client_entry,
                             args=(fab.name, m)) for m in (1, 2)]
        for p in procs:
            p.start()
        wait_until(lambda: fab.listener.accepted >= 2, 120,
                   desc="both clients accepted")
        gate[0] = 1.0                          # release both clients at once
        for p in procs:
            p.join(timeout=120)
            assert p.exitcode == 0
        stats = fab.dispatcher.stats
        assert stats.batched_requests >= 2 * N_PER_CLIENT
        # requests from *different processes* were packed into one call
        assert any(len(s) > 1 for s in seen_batches), seen_batches
        assert stats.mean_batch > 1.0


# ---------------------------------------------------------------------------
# docs gate: repro.ipc docstring coverage cannot rot silently
# ---------------------------------------------------------------------------

def test_ipc_docstring_coverage_gate():
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "check_docstrings.py"),
         str(root / "src" / "repro" / "ipc"), "--fail-under", "95"],
        capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# serve_over_ipc: one with-block, real model, client in another process
# ---------------------------------------------------------------------------

def _serve_client_entry(name: str, vocab: int) -> None:
    client = RemoteDispatcherClient.connect(
        name, policy=OffloadPolicy(offload_threshold_bytes=1), timeout_s=60)
    prompts = [np.arange(1, 6, dtype=np.int32) * (i + 1) % vocab
               for i in range(3)]
    jids = [client.request("generate", p, mode="pipelined") for p in prompts]
    outs = [client.query(j, timeout=300) for j in jids]
    assert all(o.shape == (4,) for o in outs)
    client.close()


@pytest.mark.slow
def test_serve_over_ipc_context_manager(rng_key):
    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.serve import BatchedServer, ServeConfig

    cfg = get_smoke_config("qwen3-32b")
    model = build_model(cfg)
    params = model.init(rng_key)
    srv = BatchedServer(model, params,
                        ServeConfig(max_len=32, max_new_tokens=4),
                        OffloadPolicy(max_batch=4))
    with srv.serve_over_ipc(data_slot_bytes=1 << 20) as fabric:
        proc = mp.get_context("spawn").Process(
            target=_serve_client_entry, args=(fabric.name, cfg.vocab_size))
        proc.start()
        proc.join(timeout=300)
        assert proc.exitcode == 0
        assert srv.stats["requests"] == 3
        name = fabric.name
    # one with-block tore everything down: the rendezvous is gone
    with pytest.raises((ConnectionError, TimeoutError, FileNotFoundError)):
        connect(name, timeout_s=0.5)
    srv.close()
