"""Warm-standby failover tests: diskless replication, promotion, reclaim.

Covers the failover plane end to end, in-process where possible (the
replication datapath — manifest/shard/delta pulls, CRC containment and
re-pull, delta-log exactly-once import, byte-identical restore) and in
spawned supervised processes for the headline drills (kill the primary
mid-snapshot under load → promotion preserves exactly-once; a stalled
promotion falls back to a cold restart).  Also pins the supervisor's
reclaim dot-boundary (a sibling fabric whose name merely extends ours
must survive), the dead-rendezvous ALIVE-word fail-fast, and the typed
:class:`~repro.ipc.worker.ReconnectTimeout` deadline bound.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from multiprocessing import shared_memory

import numpy as np
import pytest
from conftest import wait_until

from repro.core.dispatcher import RequestDispatcher
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.ft import inject
from repro.ft.inject import FaultPlane, FaultSpec
from repro.ft.standby import StandbyReplica, _cold_params, param_echo_factory
from repro.ft.supervisor import (SHM_DIR, FabricSupervisor,
                                 _mark_rendezvous_dead, reclaim_segments)
from repro.ipc.listener import Listener, connect as listener_connect
from repro.ipc.transport import TransportSpec
from repro.ipc.worker import (ReconnectTimeout, RemoteDispatcherClient,
                              ServingFabric)

FAST = RetryPolicy(heartbeat_interval_s=0.05, heartbeat_stale_s=0.3,
                   connect_timeout_s=5.0, max_reconnects=6)
POL = OffloadPolicy(mode="pipelined", retry=FAST)
SMALL = TransportSpec(data_slots=8, data_slot_bytes=1 << 16,
                      heap_extent_bytes=1 << 16, heap_extents=8)
FACTORY = "repro.ft.standby:param_echo_factory"


@pytest.fixture(autouse=True)
def _no_leftover_plane():
    inject.uninstall()
    yield
    inject.uninstall()


def _name(tag: str) -> str:
    return f"rocket-{tag}-{os.getpid()}"


# ---------------------------------------------------------------------------
# in-process replication datapath
# ---------------------------------------------------------------------------

def test_standby_mirrors_primary_byte_identical_and_restores():
    """One sync round mirrors the full snapshot (CRC-gated shards +
    delta) byte-identically, and a factory restore from that state
    serves the identical params (psum witness + digest)."""
    name = _name("fo-sync")
    fab = param_echo_factory(name, POL)
    try:
        replica = StandbyReplica(name, POL, interval_s=0.05)
        try:
            assert replica.sync_once()
        finally:
            replica.close()
    finally:
        fab.close()
    st = replica.state()
    assert st["seq"] == 1
    assert replica.stats["snapshots_applied"] == 1
    assert replica.stats["shard_pulls"] == len(st["manifest"]["sizes"])
    cold = _cold_params()
    for k, w in cold["layers"].items():
        got = st["tree"]["layers"][k]
        assert got.dtype == w.dtype and np.array_equal(got, w)

    fab2 = param_echo_factory(_name("fo-restored"), POL, state=st)
    try:
        cli = RemoteDispatcherClient.connect(fab2.name, policy=POL)
        try:
            expect = sum(float(w.sum()) for w in cold["layers"].values())
            assert float(cli.request("psum", np.zeros(1),
                                     mode="sync")) == expect
        finally:
            cli.close()
        # the restored source re-serves the same payload bytes
        assert (fab2.replication.snapshot_now()["digest"]
                == st["manifest"]["digest"])
    finally:
        fab2.close()


def test_shard_corruption_contained_by_crc_and_repulled():
    """``ckpt.shard.corrupt`` damages pulled shards; the replica's CRC
    gate catches each one and re-pulls only that shard — the applied
    snapshot is still byte-identical."""
    inject.install(FaultPlane(3, {
        "ckpt.shard.corrupt": FaultSpec(at=(1, 5))}))
    name = _name("fo-crc")
    fab = param_echo_factory(name, POL)
    try:
        replica = StandbyReplica(name, POL, interval_s=0.05)
        try:
            assert replica.sync_once()
        finally:
            replica.close()
        assert replica.stats["shard_corrupt"] == 2
        assert replica.stats["snapshots_applied"] == 1
        # two damaged pulls cost exactly two extra shard requests
        n = len(replica.state()["manifest"]["sizes"])
        assert replica.stats["shard_pulls"] == n + 2
        assert (replica.state()["manifest"]["digest"]
                == fab.replication._manifest["digest"])
    finally:
        fab.close()


def test_standby_lag_site_skips_sync_rounds():
    inject.install(FaultPlane(4, {
        "standby.lag": FaultSpec(rate=1.0, max_fires=2, stall_s=0.01)}))
    name = _name("fo-lag")
    fab = param_echo_factory(name, POL)
    try:
        replica = StandbyReplica(name, POL, interval_s=0.02)
        stop = threading.Event()
        t = threading.Thread(target=replica.run, args=(stop,), daemon=True)
        t.start()
        try:
            wait_until(lambda: replica.stats["lag_skips"] == 2
                       and replica.stats["syncs"] >= 1,
                       desc="lag skips then sync")
        finally:
            stop.set()
            t.join(timeout=10.0)
    finally:
        fab.close()
    assert replica.lag_ms() < float("inf")


def test_dispatcher_delta_import_preserves_exactly_once():
    """The delta log (export_state → import_state) carries settled dedup
    entries across a promotion: a replayed request on the importing
    dispatcher is answered from the window, never re-executed."""
    calls: list = []
    d1 = RequestDispatcher(POL)
    d1.register_handler("inc", lambda x: calls.append(1) or x + 1)
    first: list = []
    d1.submit_many([{"op": "inc", "data": np.arange(4.0), "dedup": 99,
                     "mode": "async",
                     "on_complete": lambda _j, r: first.append(r)}])
    wait_until(lambda: first, desc="original reply")
    out = first[0]
    delta = d1.export_state()
    d1.close()
    assert calls == [1]

    d2 = RequestDispatcher(POL)
    d2.register_handler("inc", lambda x: calls.append(2) or x + 1)
    landed = d2.import_state(delta)
    assert landed["dedup_entries"] >= 1
    replayed: list = []
    jids = d2.submit_many([{"op": "inc", "data": np.arange(4.0),
                            "dedup": 99, "mode": "async",
                            "on_complete":
                                lambda _j, r: replayed.append(r)}])
    assert jids == [-1]                   # resolved from the window
    wait_until(lambda: replayed, desc="replayed reply")
    assert np.array_equal(replayed[0], out)
    d2.close()
    assert calls == [1]                   # never re-executed


# ---------------------------------------------------------------------------
# supervisor reclaim + failure-detection edges
# ---------------------------------------------------------------------------

def test_reclaim_respects_dot_boundary_and_zeroes_alive_word():
    """Reclaim takes the exact name + ``name.``-prefixed segments only —
    a sibling fabric whose name merely extends ours survives — and
    zeroes the dead rendezvous ALIVE word before unlinking, which
    surviving mappings observe."""
    base = _name("rcl")
    segs = {n: shared_memory.SharedMemory(name=n, create=True, size=256)
            for n in (base, f"{base}.c0-1", f"{base}.c0-1.h")}
    sibling = shared_memory.SharedMemory(name=base + "x", create=True,
                                         size=256)
    try:
        segs[base].buf[64:72] = b"\x01" * 8       # "alive"
        counts = reclaim_segments(base)
        assert counts == {"arenas": 2, "heaps": 1}
        # the surviving mapping sees the fail-fast word flip
        assert bytes(segs[base].buf[64:72]) == b"\x00" * 8
        assert os.path.exists(os.path.join(SHM_DIR, base + "x"))
        assert not os.path.exists(os.path.join(SHM_DIR, base))
        assert not os.path.exists(os.path.join(SHM_DIR, f"{base}.c0-1.h"))
    finally:
        for seg in segs.values():
            seg.close()
            try:
                seg.unlink()              # already reclaimed: unregister
            except FileNotFoundError:
                pass
        sibling.close()
        sibling.unlink()


def test_connect_fails_fast_on_dead_rendezvous():
    """A client arriving at (or caught mid-registration in) a rendezvous
    whose owner died fails in milliseconds on the zeroed ALIVE word
    instead of burning its whole connect timeout."""
    with Listener(None, SMALL, POL) as lsn:     # never started: no ACKs
        _mark_rendezvous_dead(lsn.name)
        t0 = time.perf_counter()
        with pytest.raises(ConnectionError):
            listener_connect(lsn.name, policy=POL, timeout_s=30.0)
        assert time.perf_counter() - t0 < 5.0


def test_reconnect_deadline_raises_typed_error():
    """``reconnect(deadline=...)`` bounds the cumulative backoff by the
    caller's budget and raises :class:`ReconnectTimeout` — catchable as
    either ConnectionError or TimeoutError."""
    d = RequestDispatcher(POL)
    d.register_handler("echo", lambda x: x)
    fab = ServingFabric(d, spec=SMALL, policy=POL,
                        own_dispatcher=True).start()
    cli = None
    try:
        cli = RemoteDispatcherClient.connect(fab.name, policy=POL)
        assert cli.request("echo", np.arange(3),
                           mode="sync").tolist() == [0, 1, 2]
    finally:
        fab.close()                       # server gone, name unlinked
    try:
        t0 = time.perf_counter()
        with pytest.raises(ReconnectTimeout) as ei:
            cli.reconnect(deadline=time.perf_counter() + 0.5)
        assert time.perf_counter() - t0 < 5.0
        assert isinstance(ei.value, ConnectionError)
        assert isinstance(ei.value, TimeoutError)
    finally:
        cli.close()


# ---------------------------------------------------------------------------
# spawned drills
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_warm_failover_mid_snapshot_preserves_exactly_once():
    """Headline: SIGKILL the primary mid-replication under client load;
    the supervisor promotes the warm standby under the same rendezvous
    name and the client rides through with zero lost, zero duplicated
    replies and byte-identical state."""
    name = _name("fo-soak")
    sup = FabricSupervisor(name, FACTORY, policy=POL, max_restarts=2,
                           standby_factory=FACTORY,
                           standby_interval_s=0.05,
                           promote_timeout_s=20.0).start()
    try:
        assert sup.wait_alive(30.0)
        cli = RemoteDispatcherClient.connect(name, policy=POL,
                                             timeout_s=30.0)
        try:
            wait_until(lambda: (sup.standby_stats(timeout_s=5.0) or {})
                       .get("snapshots_applied", 0) >= 1,
                       timeout_s=60.0, desc="first applied snapshot")
            expect = sum(float(w.sum())
                         for w in _cold_params()["layers"].values())
            assert float(cli.request("psum", np.zeros(1),
                                     mode="sync")) == expect
            vec = np.arange(32, dtype=np.float64)
            for i in range(24):
                if i == 8:          # standby syncs at 50ms: mid-snapshot
                    os.kill(sup._proc.pid, signal.SIGKILL)
                out = cli.request("double", vec + i, mode="sync")
                assert np.array_equal(out, (vec + i) * 2), f"request {i}"
            # promoted state is the primary's, byte-identical
            assert float(cli.request("psum", np.zeros(1),
                                     mode="sync")) == expect
            assert cli.reconnects >= 1
            assert cli.lost_replies == 0 and cli.dup_replies == 0
            assert not cli._unacked
        finally:
            cli.close()
        s = sup.stats()
        assert s["crashes"] == 1
        assert s["promotions"] == 1 and s["restarts"] == 0
        assert s["last_promotion"]["seq"] >= 1
        assert s["last_promotion"]["digest"]
        assert s["state"] == "running" and s["standby_alive"]
    finally:
        sup.close()
    assert [f for f in os.listdir(SHM_DIR) if f.startswith(name)] == []


@pytest.mark.slow
def test_stalled_promotion_falls_back_to_cold_restart():
    """``standby.promote.stall`` wedges the promotion past the
    supervisor's timeout: the standby is killed (it must never race the
    replacement for the rendezvous), a cold restart recovers, and the
    client still completes every request exactly once."""
    name = _name("fo-stall")
    crash = FaultPlane(9, {"worker.crash": FaultSpec(at=(3,))})
    stall = FaultPlane(9, {"standby.promote.stall":
                           FaultSpec(rate=1.0, max_fires=1, stall_s=10.0)})
    sup = FabricSupervisor(name, FACTORY, policy=POL, max_restarts=2,
                           plane_json=crash.spec_json(),
                           standby_factory=FACTORY,
                           standby_interval_s=0.05,
                           promote_timeout_s=0.5,
                           standby_plane_json=stall.spec_json()).start()
    try:
        assert sup.wait_alive(30.0)
        cli = RemoteDispatcherClient.connect(name, policy=POL,
                                             timeout_s=30.0)
        try:
            vec = np.arange(16, dtype=np.int64)
            for i in range(8):
                out = cli.request("double", vec + i, mode="sync")
                assert np.array_equal(out, (vec + i) * 2), f"request {i}"
            assert cli.reconnects >= 1
            assert cli.lost_replies == 0 and cli.dup_replies == 0
        finally:
            cli.close()
        s = sup.stats()
        assert s["crashes"] == 1
        assert s["promote_stalls"] == 1 and s["promotions"] == 0
        assert s["restarts"] == 1          # the cold fallback
        assert s["state"] == "running"
    finally:
        sup.close()
    assert [f for f in os.listdir(SHM_DIR) if f.startswith(name)] == []
