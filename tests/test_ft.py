"""Fault-injection plane + crash-recovery tests.

Covers the robustness layer end to end: deterministic replay of seeded
:class:`~repro.ft.inject.FaultPlane` schedules, per-op circuit breakers
(open → half-open → close, queued *and* sync-inline paths), the
exactly-once dedup window, CRC quarantine of corrupted wire meta,
heartbeat-based liveness (stale/orphan reaping that never falsely reaps
a legacy non-stamping peer), handshake-leak reclamation in the listener,
and the headline chaos drill: a supervised fabric killed mid-batch whose
clients reconnect and replay with zero lost and zero duplicated replies.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np
import pytest
from conftest import wait_until
from hypothesis import given, settings, strategies as st

from repro.core.dispatcher import CircuitOpen, RequestDispatcher
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.ft import inject
from repro.ft.inject import FaultPlane, FaultSpec, InjectedFault
from repro.ft.supervisor import SHM_DIR, FabricSupervisor
from repro.ipc.listener import (Listener, _REQ_OFF, _W_REQ, _W_REQ_LOCK,
                                _write_mailbox, connect as listener_connect)
from repro.ipc.transport import ShmTransport, TransportSpec
from repro.ipc.worker import RemoteDispatcherClient, ServingFabric

# fast failure detection for test-sized scenarios
FAST = RetryPolicy(heartbeat_interval_s=0.05, heartbeat_stale_s=0.3,
                   connect_timeout_s=5.0, max_reconnects=6)
POL = OffloadPolicy(mode="pipelined", retry=FAST)
SMALL = TransportSpec(data_slots=8, data_slot_bytes=1 << 16,
                      heap_extent_bytes=1 << 16, heap_extents=8)


@pytest.fixture(autouse=True)
def _no_leftover_plane():
    """Every test starts and ends with no process-global plane installed."""
    inject.uninstall()
    yield
    inject.uninstall()


# ---------------------------------------------------------------------------
# fault plane determinism
# ---------------------------------------------------------------------------

def _drive(plane: FaultPlane, n: int) -> bytes:
    for _ in range(n):
        plane.should("ring.publish.drop")
        plane.should("heap.exhausted")
        plane.should("channel.meta.corrupt")
    return plane.schedule_bytes()


@settings(deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       rate=st.floats(0.0, 1.0, allow_nan=False),
       n=st.integers(1, 200))
def test_fault_plane_replays_byte_identical(seed, rate, n):
    """Property: the same seed + spec + hit sequence produces a
    byte-identical fired schedule on every replay."""
    faults = {"ring.publish.drop": FaultSpec(rate=rate, at=(3,)),
              "heap.exhausted": FaultSpec(rate=rate / 2),
              "channel.meta.corrupt": FaultSpec(rate=rate, max_fires=5)}
    a = _drive(FaultPlane(seed, faults), n)
    b = _drive(FaultPlane(seed, faults), n)
    assert a == b


def test_fault_plane_spec_json_roundtrip_preserves_schedule():
    plane = FaultPlane(7, {"worker.crash": FaultSpec(at=(2, 9)),
                           "ring.poll.stall": FaultSpec(rate=0.3,
                                                        stall_s=0.01)})
    clone = FaultPlane.from_spec_json(plane.spec_json())
    assert _drive(plane, 64) == _drive(clone, 64)
    for n in range(64):
        assert (plane.would_fire("ring.poll.stall", n)
                == clone.would_fire("ring.poll.stall", n))


def test_fault_plane_max_fires_caps_and_counts():
    plane = FaultPlane(0, {"heap.exhausted": FaultSpec(rate=1.0,
                                                       max_fires=2)})
    fired = sum(plane.should("heap.exhausted") is not None
                for _ in range(10))
    assert fired == 2
    assert plane.fired("heap.exhausted") == 2
    assert plane.hits("heap.exhausted") == 10


def test_fault_plane_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlane(0, {"no.such.site": FaultSpec(rate=1.0)})


# ---------------------------------------------------------------------------
# circuit breakers (queued + sync inline) and the dedup window
# ---------------------------------------------------------------------------

def _failing(_):
    raise RuntimeError("boom")


def test_breaker_opens_fast_fails_and_recovers_sync_inline():
    d = RequestDispatcher(OffloadPolicy(mode="sync"),
                          breaker_threshold=3, breaker_cooldown_s=0.1)
    d.register_handler("op", _failing)
    for _ in range(3):
        with pytest.raises(RuntimeError):
            d.request("op", 1)
    assert d.breaker_state("op") == "open"
    assert d.stats.breaker_opened == 1
    # quarantined: inline callers fast-fail without touching the handler
    with pytest.raises(CircuitOpen):
        d.request("op", 1)
    assert d.stats.breaker_fast_fails == 1
    # after cooldown the half-open probe runs the (fixed) handler and the
    # breaker closes again
    time.sleep(0.15)
    d.register_handler("op", lambda x: x + 1)
    assert d.request("op", 1) == 2
    assert d.breaker_state("op") == "closed"
    assert d.stats.breaker_recovered == 1
    d.close()


def test_breaker_half_open_probe_failure_reopens():
    d = RequestDispatcher(OffloadPolicy(mode="sync"),
                          breaker_threshold=2, breaker_cooldown_s=0.05)
    d.register_handler("op", _failing)
    for _ in range(2):
        with pytest.raises(RuntimeError):
            d.request("op", 1)
    time.sleep(0.08)
    with pytest.raises(RuntimeError):   # the probe itself runs the handler
        d.request("op", 1)
    assert d.breaker_state("op") == "open"     # ... and reopens on failure
    with pytest.raises(CircuitOpen):
        d.request("op", 1)
    d.close()


def test_breaker_fast_fails_queued_batches_with_error_replies():
    d = RequestDispatcher(OffloadPolicy(mode="async"),
                          breaker_threshold=2, breaker_cooldown_s=60.0)
    d.register_handler("op", _failing)
    results: list = []
    for _ in range(2):
        d.submit("op", 1, mode="async",
                 on_complete=lambda _j, out: results.append(out))
    wait_until(lambda: len(results) == 2, desc="handler failures")
    assert d.breaker_state("op") == "open"
    d.submit("op", 1, mode="async",
             on_complete=lambda _j, out: results.append(out))
    wait_until(lambda: len(results) == 3, desc="fast-fail reply")
    assert isinstance(results[2], CircuitOpen)
    assert d.stats.breaker_fast_fails == 1
    d.close()


def test_handler_error_injection_raises_injected_fault():
    inject.install(FaultPlane(0, {
        "dispatcher.handler.error": FaultSpec(rate=1.0, max_fires=1)}))
    d = RequestDispatcher(OffloadPolicy(mode="sync"))
    d.register_handler("op", lambda x: x)
    with pytest.raises(InjectedFault):
        d.request("op", 1)
    assert d.request("op", 5) == 5      # single fire: next call is clean
    d.close()


def test_dedup_window_executes_once_and_replays_cached_result():
    calls = []
    d = RequestDispatcher(OffloadPolicy(mode="async"))
    d.register_handler("op", lambda x: calls.append(x) or x * 10)
    got: list = []
    d.submit("op", 4, mode="async", dedup=("cli", 1),
             on_complete=lambda _j, out: got.append(out))
    wait_until(lambda: len(got) == 1, desc="original completion")
    # replay: same idempotent id — no re-execution, cached result replied
    d.submit("op", 4, mode="async", dedup=("cli", 1),
             on_complete=lambda _j, out: got.append(out))
    wait_until(lambda: len(got) == 2, desc="replayed completion")
    assert got == [40, 40]
    assert calls == [4]
    assert d.stats.dedup_hits == 1
    d.close()


def test_dedup_window_attaches_replay_to_inflight_original():
    release = threading.Event()

    def slow(x):
        release.wait(5.0)
        return x + 1

    d = RequestDispatcher(OffloadPolicy(mode="async"))
    d.register_handler("op", slow)
    got: list = []
    d.submit("op", 1, mode="async", dedup="k",
             on_complete=lambda _j, out: got.append(("orig", out)))
    time.sleep(0.05)                    # original now in flight
    d.submit("op", 1, mode="async", dedup="k",
             on_complete=lambda _j, out: got.append(("replay", out)))
    assert not got                      # nothing completed yet
    release.set()
    wait_until(lambda: len(got) == 2, desc="both callbacks")
    assert {out for _tag, out in got} == {2}
    assert d.stats.dedup_hits == 1
    d.close()


# ---------------------------------------------------------------------------
# heartbeats + CRC quarantine on a raw transport pair
# ---------------------------------------------------------------------------

def test_heartbeat_staleness_and_legacy_peer_never_stale():
    server = ShmTransport.create(None, SMALL, policy=POL)
    client = ShmTransport.attach(server.name, policy=POL)
    try:
        # nobody stamped yet: a legacy (non-stamping) peer is NEVER stale
        assert not server.peer_heartbeat_stamped
        assert not server.peer_stale()
        client.heartbeat(force=True)
        assert wait_until(lambda: server.peer_heartbeat_stamped,
                          desc="stamp visible")
        assert not server.peer_stale()
        assert server.peer_heartbeat_age_s() < 1.0
        # silence for > heartbeat_stale_s: now (and only now) stale
        time.sleep(POL.retry.heartbeat_stale_s + 0.1)
        assert server.peer_stale()
        client.heartbeat(force=True)
        assert not server.peer_stale()
    finally:
        client.close()
        server.close()


def test_meta_crc_quarantines_corrupt_slot_and_counts():
    pol = OffloadPolicy(mode="sync", meta_checksum=True, retry=FAST)
    server = ShmTransport.create(None, SMALL, policy=pol)
    client = ShmTransport.attach(server.name, policy=pol)
    inject.install(FaultPlane(3, {
        "channel.meta.corrupt": FaultSpec(rate=1.0, max_fires=1)}))
    try:
        client.send({"x": np.arange(4)}, header={"n": 0})   # corrupted
        client.send({"x": np.arange(4)}, header={"n": 1})   # clean
        tree, header = server.recv(timeout_s=5.0)
        # the corrupt slot was quarantined (released + counted), never
        # surfaced: the first delivered message is the clean one
        assert header["n"] == 1
        assert server.data.stats.corrupt_drops == 1
        with pytest.raises(TimeoutError):
            server.recv(timeout_s=0.1)
    finally:
        inject.uninstall()
        client.close()
        server.close()


def test_meta_checksum_off_means_no_crc_overhead_flags():
    server = ShmTransport.create(None, SMALL, policy=POL)
    client = ShmTransport.attach(server.name, policy=POL)
    try:
        client.send({"x": np.arange(8)}, header={"k": 1})
        _tree, header = server.recv(timeout_s=5.0)
        assert header["k"] == 1
        assert server.data.stats.corrupt_drops == 0
    finally:
        client.close()
        server.close()


# ---------------------------------------------------------------------------
# handshake-leak reclamation
# ---------------------------------------------------------------------------

def test_listener_reclaims_stale_registration_without_minting_arena():
    with Listener(None, SMALL, POL) as lsn:
        # a registration whose client-side deadline already passed: the
        # client gave up — answering it with a fresh arena would leak
        _write_mailbox(lsn._arena, _W_REQ_LOCK, _REQ_OFF,
                       {"pid": 0, "meta": None,
                        "deadline_ns": time.perf_counter_ns() - 1})
        lsn._words[_W_REQ] += 1
        assert lsn.accept_once() is None
        assert lsn.stale_registrations == 1
        assert lsn.accepted == 0


def test_failed_connect_flags_minted_transport_for_reaping(monkeypatch):
    minted: list = []
    with Listener(None, SMALL, POL, on_accept=minted.append) as lsn:
        lsn.start()
        monkeypatch.setattr(ShmTransport, "attach",
                            classmethod(lambda *a, **k: (_ for _ in ())
                                        .throw(RuntimeError("attach died"))))
        with pytest.raises(RuntimeError, match="attach died"):
            listener_connect(lsn.name, policy=POL, timeout_s=5.0)
        # the half-created transport was flagged attacher-closed, so the
        # reactor reaps (and unlinks) it instead of idling on an orphan
        assert wait_until(lambda: minted and minted[0].peer_closed,
                          desc="attacher-closed flag")
        minted[0].close()


# ---------------------------------------------------------------------------
# reactor liveness reaping on the fabric
# ---------------------------------------------------------------------------

def test_reactor_reaps_stale_client_and_never_a_legacy_idle_one():
    short = OffloadPolicy(mode="pipelined", retry=RetryPolicy(
        heartbeat_interval_s=0.05, heartbeat_stale_s=0.3,
        connect_timeout_s=120.0))
    d = RequestDispatcher(short)
    d.register_handler("echo", lambda x: x)
    with ServingFabric(d, spec=SMALL, policy=short,
                       own_dispatcher=True).start() as fab:
        cli = RemoteDispatcherClient.connect(fab.name, policy=short)
        out = cli.request("echo", np.arange(3), mode="sync")
        assert out.tolist() == [0, 1, 2]
        # stop the receiver thread: heartbeats cease but the transport
        # stays open — exactly what a hung client looks like
        cli._stop.set()
        cli._recv_thread.join(timeout=5)
        wait_until(lambda: fab._reactor_stats().get("stale_reaped", 0) == 1,
                   desc="stale reap")
        assert len(fab.reactor) == 0
        cli._stop.clear()               # close() cleanly (send will fail)
        cli.close()


def test_reactor_orphan_reaps_never_stamping_silent_connection():
    quick = OffloadPolicy(mode="pipelined", retry=RetryPolicy(
        heartbeat_interval_s=0.05, heartbeat_stale_s=60.0,
        connect_timeout_s=0.3))
    d = RequestDispatcher(quick)
    with ServingFabric(d, spec=SMALL, policy=quick,
                       own_dispatcher=True).start() as fab:
        # a raw transport that registers but never sends, never stamps:
        # indistinguishable from a client that died mid-handshake
        t = listener_connect(fab.name, policy=quick, timeout_s=10.0)
        wait_until(lambda: fab._reactor_stats().get("orphan_reaped", 0) == 1,
                   desc="orphan reap")
        t.close()


# ---------------------------------------------------------------------------
# the chaos drill: kill the fabric mid-batch, recover exactly-once
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_supervised_fabric_crash_mid_batch_recovers_exactly_once():
    """Headline acceptance: worker.crash kills the serving process while
    a batch drains; the supervisor reclaims the orphaned segments and
    restarts under the same name; the client reconnects and replays its
    unacked request — every request completes exactly once (lost=0,
    dup=0) and nothing is left in /dev/shm."""
    name = f"rocket-ft-{os.getpid()}"
    plane = FaultPlane(8, {"worker.crash": FaultSpec(at=(4,))})
    sup = FabricSupervisor(name, "repro.ft.supervisor:echo_fabric_factory",
                           policy=POL, max_restarts=2,
                           plane_json=plane.spec_json()).start()
    try:
        assert sup.wait_alive(30.0)
        cli = RemoteDispatcherClient.connect(name, policy=POL)
        try:
            vec = np.arange(16, dtype=np.int64)
            for i in range(12):
                out = cli.request("double", vec + i, mode="sync")
                assert np.array_equal(out, (vec + i) * 2), f"request {i}"
            assert cli.reconnects >= 1      # the crash really happened
            assert cli.lost_replies == 0
            assert cli.dup_replies == 0
            assert not cli._unacked         # exactly-once id accounting
        finally:
            cli.close()
        stats = sup.stats()
        assert stats["crashes"] == 1 and stats["restarts"] == 1
        assert stats["arenas_reclaimed"] >= 1
    finally:
        sup.close()
    assert [f for f in os.listdir(SHM_DIR) if f.startswith(name)] == []


@pytest.mark.slow
def test_client_resubmit_rides_dedup_window_when_reply_lost():
    """Server alive but one request quarantined in transit (corrupt
    meta): the client's bounded resubmit replays under the same dedup id
    and the request executes exactly once."""
    pol = OffloadPolicy(mode="pipelined", meta_checksum=True, retry=FAST)
    calls: list = []
    d = RequestDispatcher(pol)
    d.register_handler("once", lambda x: calls.append(int(x[0])) or x * 3)
    inject.install(FaultPlane(5, {
        "channel.meta.corrupt": FaultSpec(rate=1.0, max_fires=1)}))
    try:
        with ServingFabric(d, spec=SMALL, policy=pol,
                           own_dispatcher=True).start() as fab:
            cli = RemoteDispatcherClient.connect(fab.name, policy=pol)
            try:
                out = cli.request("once", np.full(4, 7.0), mode="sync")
                assert np.all(out == 21.0)
                assert cli.retries == 1          # one resubmit happened
                assert cli.lost_replies == 0 and cli.dup_replies == 0
                drops = sum(c.transport.data.stats.corrupt_drops
                            for c in fab._all_connections())
                assert drops == 1
            finally:
                cli.close()
    finally:
        inject.uninstall()
    assert calls == [7]                          # executed exactly once
