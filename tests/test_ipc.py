"""repro.ipc: arenas/seqlocks, slot rings, typed channels, real processes.

Single-process tests exercise the shared-memory protocol by opening two
endpoints on one arena (creator + attacher in the same address space — the
memory semantics are identical).  The spawn tests then cross a real process
boundary: producer→consumer byte identity, the mode matrix, seek/restore,
and the dispatcher bridge, all with bounded timeouts.
"""
import multiprocessing as mp
import threading
import time

import numpy as np
import pytest

from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ipc import (
    ChannelClosed,
    RemoteDispatcherClient,
    Ring,
    RingSpec,
    SeqLock,
    SharedMemoryArena,
    ShmTransport,
    TransportSpec,
    start_producer,
)

from conftest import wait_until

TIGHT = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0)
SMALL = TransportSpec(data_slots=3, data_slot_bytes=1 << 20,
                      ctrl_slots=4, ctrl_slot_bytes=4 << 10)


def _pair(spec=SMALL, policy=TIGHT):
    a = ShmTransport.create(spec=spec, policy=policy)
    b = ShmTransport.attach(a.name, policy=policy)
    return a, b


# ---------------------------------------------------------------------------
# arena + seqlock
# ---------------------------------------------------------------------------

def test_arena_create_attach_views():
    a = SharedMemoryArena("rocket-test-arena", size=1 << 16, create=True)
    try:
        b = SharedMemoryArena("rocket-test-arena", create=False)
        arr = a.ndarray(128, (64,), np.int32)
        arr[:] = np.arange(64)
        seen = b.ndarray(128, (64,), np.int32)
        np.testing.assert_array_equal(seen, np.arange(64))
        # control words are shared too
        a.control_words()[7] = 42
        assert int(b.control_words()[7]) == 42
        del arr, seen
        b.close()
    finally:
        a.close()
        a.unlink()


def test_arena_rejects_wrong_magic():
    from multiprocessing import shared_memory
    raw = shared_memory.SharedMemory("rocket-test-bogus", create=True,
                                     size=4096)
    try:
        with pytest.raises(ValueError, match="magic"):
            SharedMemoryArena("rocket-test-bogus", create=False)
    finally:
        raw.close()
        raw.unlink()


def test_seqlock_blocks_torn_reads():
    word = np.zeros(1, np.int64)
    lock = SeqLock(word)
    payload = np.zeros(2, np.int64)

    with lock.write():
        payload[:] = (1, 1)
    assert lock.read(lambda: tuple(payload)) == (1, 1)

    # a reader entering mid-write must not return the half-updated payload
    lock.write_begin()
    payload[0] = 2              # torn state: (2, 1)
    reader_out = {}

    def reader():
        reader_out["v"] = lock.read(lambda: tuple(payload))

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.05)
    assert "v" not in reader_out          # still spinning on odd sequence
    payload[1] = 2
    lock.write_end()
    t.join(timeout=5)
    assert reader_out["v"] == (2, 2)


def test_seqlock_retries_on_sequence_change():
    word = np.zeros(1, np.int64)
    lock = SeqLock(word)
    calls = []

    def racy_read():
        calls.append(1)
        if len(calls) == 1:
            # simulate a writer completing a full publish mid-read
            word[0] += 2
        return "ok"

    assert lock.read(racy_read) == "ok"
    assert len(calls) == 2                # first read was discarded as torn


# ---------------------------------------------------------------------------
# rings: acquire/release, wraparound, backpressure
# ---------------------------------------------------------------------------

def _ring_pair(n_slots=3, slot_bytes=4096):
    arena = SharedMemoryArena("rocket-test-ring", size=1 << 20, create=True)
    spec = RingSpec(n_slots, slot_bytes, meta_bytes=128)
    prod = Ring(arena, 0, spec, TIGHT)
    cons = Ring(arena, 0, spec, TIGHT)
    return arena, prod, cons


def test_ring_acquire_release_wraparound():
    arena, prod, cons = _ring_pair(n_slots=3)
    try:
        n_msgs = 10                        # > 3 slots: forces wraparound
        for i in range(n_msgs):
            w = prod.acquire(timeout_s=5)
            w.payload[:8] = np.int64(i).tobytes()
            w.publish(8)
            r = cons.wait_recv(timeout_s=5)
            assert r.seq == i + 1          # seq survives slot reuse
            assert np.frombuffer(r.payload, np.int64)[0] == i
            r.release()
        assert prod.produced == n_msgs
        assert cons.consumed == n_msgs
    finally:
        prod.drop_views(); cons.drop_views()
        arena.close(); arena.unlink()


def test_ring_full_gives_backpressure():
    arena, prod, cons = _ring_pair(n_slots=2)
    try:
        for i in range(2):
            prod.acquire(timeout_s=1).publish(0)
        assert prod.try_acquire() is None              # ring full
        with pytest.raises(TimeoutError):
            prod.acquire(timeout_s=0.2)
        assert prod.stats.full_waits >= 1
        cons.wait_recv(timeout_s=1).release()          # free one slot
        assert prod.try_acquire() is not None
    finally:
        prod.drop_views(); cons.drop_views()
        arena.close(); arena.unlink()


def test_ring_wait_raises_when_peer_closes():
    arena, prod, cons = _ring_pair()
    closed = np.zeros(1, np.int64)
    cons.bind_shutdown_word(closed)
    try:
        t = threading.Timer(0.1, lambda: closed.__setitem__(0, 1))
        t.start()
        with pytest.raises(ChannelClosed):
            cons.wait_recv(timeout_s=10)
        t.join()
    finally:
        prod.drop_views(); cons.drop_views()
        arena.close(); arena.unlink()


# ---------------------------------------------------------------------------
# channels: mode matrix, zero copy, size guards (in-process pair)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sync", "async", "pipelined"])
def test_channel_mode_matrix(mode):
    policy = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1,
                           pipeline_depth=2)
    a, b = _pair(policy=policy)
    try:
        trees = [{"x": np.full((2048,), i, np.int64),
                  "nested": {"y": np.float32(i) * np.ones((3, 5), np.float32)}}
                 for i in range(7)]
        recvd = []

        def consume():
            for _ in trees:
                tree, header = b.recv(timeout_s=20)
                recvd.append((tree, header))

        t = threading.Thread(target=consume)
        t.start()
        handles = [a.send(tr, header={"i": i}) for i, tr in enumerate(trees)]
        for h in handles:
            h.wait(timeout_s=20)
        a.data.flush(timeout_s=20)
        t.join(timeout=30)
        assert not t.is_alive()
        assert [h["i"] for _, h in recvd] == list(range(7))  # FIFO survives
        for i, (tree, _) in enumerate(recvd):
            np.testing.assert_array_equal(tree["x"], trees[i]["x"])
            np.testing.assert_array_equal(tree["nested"]["y"],
                                          trees[i]["nested"]["y"])
        if mode == "sync":
            assert a.data.stats.offloaded == 0
        else:
            assert a.data.stats.offloaded == 7
    finally:
        b.close(); a.close()


def test_channel_zero_copy_views():
    a, b = _pair()
    try:
        payload = {"x": np.arange(4096, dtype=np.int32)}
        a.send(payload, mode="sync")
        lease = b.recv(copy=False)
        assert lease.tree["x"].base is not None        # a view, not a copy
        np.testing.assert_array_equal(lease.tree["x"], payload["x"])
        lease.release()
        assert lease.tree is None                      # views dropped
    finally:
        b.close(); a.close()


def test_channel_oversize_message_raises_without_heap():
    """With the bulk heap disabled, slot capacity is still a hard cap."""
    import dataclasses
    a, b = _pair(spec=dataclasses.replace(SMALL, heap_extents=0))
    try:
        with pytest.raises(ValueError, match="slot capacity"):
            a.send({"x": np.zeros(SMALL.data_slot_bytes + 1, np.uint8)},
                   mode="sync")
    finally:
        b.close(); a.close()


def test_channel_oversize_message_rides_the_heap():
    """The same over-slot message on a heap-enabled transport (the
    default spec) goes through: the ring carries only the extent
    descriptor and the payload round-trips byte-identically."""
    a, b = _pair()
    try:
        msg = {"x": np.arange(SMALL.data_slot_bytes + 1, dtype=np.uint8)}
        a.send(msg, mode="sync")
        tree, _ = b.recv(timeout_s=10)
        np.testing.assert_array_equal(tree["x"], msg["x"])
        assert a.data.stats.heap_sends == 1
        assert b.data.stats.heap_recvs == 1
    finally:
        b.close(); a.close()


@pytest.mark.parametrize("mode", ["sync", "async", "pipelined"])
def test_spawn_heap_messages_byte_identical(mode):
    """Large (heap-routed) batches from a producer *process* arrive
    byte-identical in every send mode, interleaved with small slot-path
    messages (the mark leaf stays tiny; tokens exceed the slot)."""
    policy = OffloadPolicy(mode=ExecutionMode(mode),
                           offload_threshold_bytes=1,
                           heap_threshold_bytes=1 << 19,
                           heap_chunk_bytes=1 << 19)
    handle = start_producer(_counting_spec(seed=11), policy=policy,
                            spec=SMALL, n_batches=4)
    try:
        ref = make_counting_source(seed=11)
        for i in range(4):
            batch, header = handle.recv_batch(timeout_s=60)
            expect = next(ref)
            assert header["step"] == i
            for k in expect:
                assert batch[k].tobytes() == expect[k].tobytes()
        _, header = handle.recv_batch(timeout_s=60)
        assert header.get("eof")
        # tokens are 64*1024*8 B = 512 KB >= heap threshold: heap-routed
        assert handle.transport.data.stats.heap_recvs == 4
    finally:
        handle.stop()
    assert handle.process.exitcode == 0


def test_control_channel_roundtrip():
    a, b = _pair()
    try:
        a.send_msg({"cmd": "seek", "step": 3})
        assert b.recv_msg(timeout_s=5) == {"cmd": "seek", "step": 3}
        assert b.ctrl.try_recv_msg() is None
    finally:
        b.close(); a.close()


def test_transport_geometry_from_descriptor():
    """The attacher learns ring geometry from the arena, not from args."""
    spec = TransportSpec(data_slots=5, data_slot_bytes=1 << 18,
                         ctrl_slots=3, ctrl_slot_bytes=1 << 12)
    a = ShmTransport.create(spec=spec)
    b = ShmTransport.attach(a.name)
    try:
        assert b.spec == spec
        assert b.data.rx.spec.n_slots == 5
    finally:
        b.close(); a.close()


# ---------------------------------------------------------------------------
# real process boundary (spawn)
# ---------------------------------------------------------------------------

def make_counting_source(seed=0, rows=64, cols=1024):
    """Deterministic numpy-only source (spawn-importable from this module)."""

    class CountingSource:
        def __init__(self):
            self.seed, self.step = seed, 0

        def state(self):
            return {"seed": self.seed, "step": self.step}

        def restore(self, st):
            self.seed, self.step = int(st["seed"]), int(st["step"])

        def __iter__(self):
            return self

        def __next__(self):
            rng = np.random.default_rng((self.seed, self.step))
            self.step += 1
            return {"tokens": rng.integers(0, 1 << 30, (rows, cols),
                                           dtype=np.int64),
                    "mark": np.full((4,), self.step - 1, np.int32)}

    return CountingSource()


def _counting_spec(seed=0):
    return {"kind": "factory", "path": "test_ipc:make_counting_source",
            "kwargs": {"seed": seed}}


@pytest.mark.parametrize("mode", ["sync", "async", "pipelined"])
def test_spawn_producer_consumer_byte_identical(mode):
    policy = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1)
    handle = start_producer(_counting_spec(seed=9), policy=policy,
                            spec=SMALL, n_batches=6)
    try:
        ref = make_counting_source(seed=9)
        for i in range(6):
            batch, header = handle.recv_batch(timeout_s=60)
            expect = next(ref)
            assert header["step"] == i
            for k in expect:
                assert batch[k].tobytes() == expect[k].tobytes()   # bytes!
        _, header = handle.recv_batch(timeout_s=60)
        assert header.get("eof")
    finally:
        handle.stop()
    assert handle.process.exitcode == 0


def test_spawn_producer_seek_restores_stream():
    handle = start_producer(_counting_spec(seed=4), spec=SMALL,
                            policy=TIGHT, n_batches=None)
    try:
        for i in range(3):
            batch, header = handle.recv_batch(timeout_s=60)
            assert header["step"] == i
        gen = handle.seek(1)
        ref = make_counting_source(seed=4)
        ref.restore({"seed": 4, "step": 1})
        expect = next(ref)
        # drain stale in-flight batches (old generation), then verify replay;
        # a stale slot may even carry step==1, so the gen check is the gate
        deadline = time.perf_counter() + 60
        while True:
            batch, header = handle.recv_batch(timeout_s=60)
            if header.get("gen") == gen and header.get("step") == 1:
                break
            assert time.perf_counter() < deadline
        np.testing.assert_array_equal(batch["tokens"], expect["tokens"])
    finally:
        handle.stop()


def test_spawn_producer_seek_after_eof_restarts_stream():
    """restore() on a finished finite stream must restart production,
    not strand the consumer until the producer's linger expires."""
    handle = start_producer(_counting_spec(seed=2), spec=SMALL,
                            policy=TIGHT, n_batches=2)
    try:
        for _ in range(2):
            handle.recv_batch(timeout_s=60)
        _, header = handle.recv_batch(timeout_s=60)
        assert header.get("eof")
        gen = handle.seek(0)
        expect = next(make_counting_source(seed=2))
        deadline = time.perf_counter() + 60
        while True:
            batch, header = handle.recv_batch(timeout_s=60)
            if header.get("gen") == gen and header.get("step") == 0:
                break
            assert time.perf_counter() < deadline
        np.testing.assert_array_equal(batch["tokens"], expect["tokens"])
    finally:
        handle.stop()


def test_spawn_consumer_close_unblocks_producer():
    """Producer blocked on a full ring must exit on close, not deadlock."""
    handle = start_producer(_counting_spec(), spec=SMALL,
                            policy=TIGHT, n_batches=None)
    try:
        handle.recv_batch(timeout_s=60)        # producer is alive + streaming
        rx = handle.transport.data.rx
        wait_until(lambda: rx.produced - rx.consumed >= rx.spec.n_slots,
                   10, desc="producer to fill the data ring")
    finally:
        t0 = time.perf_counter()
        handle.stop(timeout_s=15)
    assert time.perf_counter() - t0 < 15, "producer had to be terminated"
    assert not handle.process.is_alive()


# -- dispatcher bridge --------------------------------------------------------

def _rpc_client_entry(name: str) -> None:
    policy = OffloadPolicy(offload_threshold_bytes=1)
    t = ShmTransport.attach(name, policy=policy)
    client = RemoteDispatcherClient(t)
    out = client.request("double", np.arange(16, dtype=np.float32),
                         mode="sync")
    np.testing.assert_array_equal(out, 2 * np.arange(16, dtype=np.float32))
    jids = [client.request("double", np.full((512,), i, np.float32), mode=m)
            for i, m in enumerate(["async", "pipelined", "pipelined"])]
    for i, jid in reversed(list(enumerate(jids))):     # out-of-order queries
        assert float(client.query(jid, timeout=30)[0]) == 2.0 * i
    with pytest.raises(RuntimeError, match="KeyError"):
        client.request("no-such-op", np.zeros(4), mode="sync")
    client.close()
    t.close()


def test_remote_dispatcher_across_processes():
    from repro.core.dispatcher import RequestDispatcher
    from repro.ipc import DispatcherServer

    policy = OffloadPolicy(offload_threshold_bytes=1)
    transport = ShmTransport.create(spec=SMALL, policy=policy)
    dispatcher = RequestDispatcher(policy)
    dispatcher.register_handler("double", lambda x: x * 2,
                                batch_fn=lambda xs: [x * 2 for x in xs])
    server = DispatcherServer(dispatcher, transport).start()
    proc = mp.get_context("spawn").Process(target=_rpc_client_entry,
                                           args=(transport.name,))
    proc.start()
    proc.join(timeout=120)
    try:
        assert proc.exitcode == 0
        assert dispatcher.stats.requests >= 4
    finally:
        server.close()
        dispatcher.close()
        transport.close()


# -- acceptance: pipeline determinism across the process boundary -------------

@pytest.mark.slow
def test_input_pipeline_ipc_matches_in_process_source():
    """InputPipeline fed by an IPC producer process yields batches identical
    to the in-process SyntheticLMSource for the same seed."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.data import InputPipeline, SyntheticLMSource, make_source

    cfg = get_smoke_config("granite-8b")
    shape = ShapeConfig("ipc-test", "train", 8, 32)
    policy = OffloadPolicy(mode=ExecutionMode.PIPELINED,
                           offload_threshold_bytes=1)
    src = make_source(cfg, shape, source="ipc", seed=123, policy=policy)
    pipe = InputPipeline(src, policy)
    ref = InputPipeline(SyntheticLMSource(cfg, shape, seed=123), policy)
    try:
        for _ in range(4):
            got, expect = next(pipe), next(ref)
            assert set(got) == set(expect)
            for k in expect:
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(expect[k]))
    finally:
        pipe.close()
        ref.close()
