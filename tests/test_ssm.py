"""Mamba2/SSD invariants: chunked == recurrent, chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.base import ModelConfig
from repro.models import ssm


def mk_cfg(chunk=8, state=8, p=8):
    return ModelConfig(name="t", family="hybrid", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                       ssm_state=state, ssm_head_dim=p, ssm_chunk=chunk,
                       dtype="float32", param_dtype="float32")


def rand_inputs(key, b=2, s=24, nh=4, p=8, g=2, n=8):
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, nh, p))
    bm = 0.5 * jax.random.normal(ks[1], (b, s, g, n))
    cm = 0.5 * jax.random.normal(ks[2], (b, s, g, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, nh)))
    a = -jnp.exp(jax.random.normal(ks[4], (nh,)))
    da = dt * a
    dsk = jnp.linspace(0.5, 1.5, nh)
    return xh, bm, cm, dt, da, dsk


def recurrence(xh, bm, cm, dt, da, dsk):
    from repro.kernels import ref
    return ref.ssd_scan(xh, bm, cm, dt, da, dsk)


def test_chunked_equals_recurrence(rng_key):
    xh, bm, cm, dt, da, dsk = rand_inputs(rng_key)
    y_ref, h_ref = recurrence(xh, bm, cm, dt, da, dsk)
    y, h = ssm.ssd_chunked(xh, bm, cm, dt, da, dsk, mk_cfg(chunk=8))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@given(st.sampled_from([2, 3, 4, 6, 8, 12, 24]), st.integers(10, 30))
def test_chunk_size_invariance(chunk, s):
    """y must not depend on the chunking (incl. the padded tail path)."""
    xh, bm, cm, dt, da, dsk = rand_inputs(jax.random.key(chunk * 100 + s), s=s)
    y1, h1 = ssm.ssd_chunked(xh, bm, cm, dt, da, dsk, mk_cfg(chunk=chunk))
    y2, h2 = ssm.ssd_chunked(xh, bm, cm, dt, da, dsk, mk_cfg(chunk=max(s, 2)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_block_prefill_state_matches_decode_continuation(rng_key):
    """Prefill s tokens, then decode one == apply s+1 tokens at once."""
    cfg = mk_cfg(chunk=8, state=8, p=8)
    params = ssm.ssm_init(rng_key, cfg)
    b, s = 2, 11
    x = 0.5 * jax.random.normal(jax.random.key(1), (b, s + 1, cfg.d_model))
    full = ssm.ssm_block_apply(params, x, cfg)
    out, (conv_state, h_state) = ssm.ssm_block_prefill(params, x[:, :s], cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :s]),
                               rtol=1e-4, atol=1e-4)
    step_out, _, _ = ssm.ssm_block_decode(params, x[:, s:], cfg,
                                          conv_state, h_state)
    np.testing.assert_allclose(np.asarray(step_out[:, 0]),
                               np.asarray(full[:, s]), rtol=1e-4, atol=1e-4)


def test_decay_stability():
    """All decay factors must be <= 1 (A < 0): states cannot blow up."""
    xh, bm, cm, dt, da, dsk = rand_inputs(jax.random.key(0), s=64)
    assert bool(jnp.all(da <= 0))
    y, h = ssm.ssd_chunked(xh, bm, cm, dt, da, dsk, mk_cfg(chunk=16))
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(h)))
