"""Per-architecture smoke tests (reduced configs) + serving consistency.

Every assigned architecture: instantiate the reduced config, run one
forward/train step on CPU, assert output shapes and no NaNs; then assert the
recurrent/cached decode path agrees with the parallel prefill path exactly.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import build_model
from repro.optim import adamw
from repro.train import TrainConfig, init_train_state, make_train_step

ARCHS = list_archs()


def make_batch(cfg, b=2, s=24, key=None, with_labels=True):
    key = key or jax.random.key(7)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch = {"frame_embeds": jax.random.normal(key, (b, 12, cfg.d_model)),
                 "tokens": toks}
    elif cfg.family == "vlm":
        batch = {"tokens": toks,
                 "patch_embeds": jax.random.normal(
                     key, (b, cfg.num_patches, cfg.d_model))}
    else:
        batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, opt_state = init_train_state(model, rng_key)
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    step = jax.jit(make_train_step(model, TrainConfig(
        opt=adamw.AdamWConfig(warmup_steps=1, total_steps=10))))
    params2, opt2, m = step(params, opt_state, batch)
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["grad_norm"])
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(moved)), f"{arch}: no parameter moved"
    # no NaNs anywhere in the updated state
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{arch}: NaN in params"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng_key):
    """prefill(n) last-token logits == prefill(n-1) + decode_step(token n)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng_key)
    b, s = 2, 17
    batch = make_batch(cfg, b=b, s=s, with_labels=False)
    maxlen = s + cfg.num_patches + 4
    toks = batch["tokens"]

    def sub(tokens):
        out = dict(batch)
        out["tokens"] = tokens
        return out

    la, _ = model.prefill(params, sub(toks), max_len=maxlen)
    _, cache = model.prefill(params, sub(toks[:, : s - 1]), max_len=maxlen)
    lb, _ = model.decode_step(params, cache, toks[:, s - 1: s])
    assert la.shape == lb.shape == (b, 1, cfg.vocab_size)
    err = float(jnp.max(jnp.abs(la.astype(jnp.float32) - lb.astype(jnp.float32))))
    assert err < 2e-3, f"{arch}: decode diverges from prefill ({err:.2e})"


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_token_decode(arch, rng_key):
    """Three chained decode steps stay finite and advance the cache index."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(rng_key)
    batch = make_batch(cfg, b=2, s=8, with_labels=False)
    logits, cache = model.prefill(params, batch, max_len=32)
    idx0 = int(cache["index"][0])
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["index"][0]) == idx0 + 3
