"""Diskless checkpoint shard codec tests.

The codec (:class:`repro.checkpoint.manager.ShardCodec`) is the byte
layer under warm-standby replication: arbitrary host pytrees in,
size-classed CRC-stamped shards out, and back — exactly one counted copy
per byte per direction.  Property tests (hypothesis, skipped cleanly
under the no-hypothesis stub) drive arbitrary pytrees — nested
dict/list/tuple nodes, every wire dtype, 0-d and zero-size leaves —
through encode → decode and require bit-exact reconstruction;
deterministic tests pin the edges: shard-boundary straddlers, exact
corrupt-shard indices in :class:`ShardCorrupt`, the pickled ``extra``
tail, no-``like`` reconstruction, and the ``ckpt`` copy-tag accounting.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import ShardCodec, ShardCorrupt

DTYPES = ("uint8", "int32", "int64", "float16", "float32", "float64",
          "bool")


def _tree_equal(a, b) -> None:
    """Assert two pytrees match structurally and bit-exactly."""
    import jax

    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"treedef mismatch: {ta} != {tb}"
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, f"dtype {x.dtype} != {y.dtype}"
        assert x.shape == y.shape, f"shape {x.shape} != {y.shape}"
        assert np.array_equal(x, y)


@st.composite
def leaf_arrays(draw):
    dtype = np.dtype(draw(st.sampled_from(DTYPES)))
    shape = tuple(draw(st.lists(st.integers(0, 5), min_size=0,
                                max_size=3)))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    if dtype == np.bool_:
        return rng.integers(0, 2, size=shape).astype(bool)
    if dtype.kind == "f":
        return rng.standard_normal(shape).astype(dtype)
    return rng.integers(0, 100, size=shape).astype(dtype)


@st.composite
def pytrees(draw, depth: int = 2):
    """Arbitrary pytrees: dict/list/tuple nodes over wire-dtype leaves
    (0-d and zero-size shapes included)."""
    if depth == 0 or draw(st.booleans()):
        return draw(leaf_arrays())
    kind = draw(st.sampled_from(("dict", "list", "tuple")))
    children = [draw(pytrees(depth=depth - 1))
                for _ in range(draw(st.integers(1, 3)))]
    if kind == "dict":
        return {f"k{i}": c for i, c in enumerate(children)}
    return children if kind == "list" else tuple(children)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@settings(deadline=None)
@given(tree=pytrees(), seq=st.integers(0, 2**31 - 1))
def test_roundtrip_arbitrary_pytrees(tree, seq):
    """Property: encode → decode with ``like`` reconstructs any pytree
    bit-exactly — structure, shapes (0-d included), dtypes, bytes —
    and the manifest carries the seq + a stable payload digest."""
    codec = ShardCodec(shard_bytes=1 << 12)
    manifest, shards = codec.encode(tree, seq=seq)
    assert manifest["seq"] == seq
    assert sum(manifest["sizes"]) == manifest["payload_bytes"]
    out, extra = codec.decode(manifest, shards, like=tree)
    _tree_equal(tree, out)
    assert extra == {}
    # the digest is a pure function of the payload bytes
    manifest2, _ = ShardCodec(shard_bytes=1 << 12).encode(tree, seq=seq)
    assert manifest2["digest"] == manifest["digest"]


@settings(deadline=None)
@given(tree=pytrees(depth=1), n_corrupt=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1))
def test_corruption_always_detected(tree, n_corrupt, seed):
    """Property: flipping one byte in any subset of shards is always
    caught by CRC, with the damaged indices reported exactly."""
    codec = ShardCodec(shard_bytes=1 << 12)
    manifest, shards = codec.encode(tree)
    rng = np.random.default_rng(seed)
    picks = sorted(set(int(rng.integers(0, len(shards)))
                       for _ in range(n_corrupt)))
    for i in picks:
        shards[i] = shards[i].copy()
        shards[i][int(rng.integers(0, manifest["sizes"][i]))] ^= 0xFF
    with pytest.raises(ShardCorrupt) as ei:
        codec.decode(manifest, shards, like=tree)
    assert ei.value.indices == picks


# ---------------------------------------------------------------------------
# deterministic edges
# ---------------------------------------------------------------------------

def test_zero_dim_and_dtype_preservation():
    """0-d leaves keep shape ``()`` (not ``(1,)``) and every dtype is
    preserved bit-for-bit through the uint8 wire view."""
    tree = {"a": np.array(7, np.int64),
            "b": np.array(1.5, np.float16),
            "c": np.arange(6, dtype=np.uint8).reshape(2, 3),
            "d": np.array(True),
            "e": np.array([], np.float32)}
    codec = ShardCodec()
    manifest, shards = codec.encode(tree)
    out, _ = codec.decode(manifest, shards, like=tree)
    _tree_equal(tree, out)
    assert out["a"].shape == () and out["d"].shape == ()


def test_shard_boundary_straddlers_roundtrip():
    """A leaf far larger than the (floored, power-of-two) shard size is
    split across many shards by the SG fill and reassembled exactly."""
    rng = np.random.default_rng(0)
    tree = {"big": rng.standard_normal(3000),          # 24000 B of f64
            "tail": rng.integers(0, 9, 7, dtype=np.int64)}
    codec = ShardCodec(shard_bytes=1)      # floors to the 4 KB class
    assert codec.shard_bytes == 4096
    manifest, shards = codec.encode(tree)
    assert len(shards) >= 6                # straddling is actually exercised
    assert all(s.nbytes == 4096 for s in shards)  # size-classed buffers
    out, _ = codec.decode(manifest, shards, like=tree)
    _tree_equal(tree, out)


def test_extra_blob_rides_payload_tail():
    extra = {"stats": {"requests": 11, "tokens_out": 42}, "note": "hi"}
    tree = {"w": np.arange(10, dtype=np.float32)}
    codec = ShardCodec()
    manifest, shards = codec.encode(tree, extra=extra)
    assert manifest["extra_offset"] == 40  # params first, extra after
    out, got = codec.decode(manifest, shards, like=tree)
    _tree_equal(tree, out)
    assert got == extra


def test_decode_without_like():
    """No-``like`` decode: a bare array comes back as an array, nested
    dicts are rebuilt from the ``/``-joined leaf names."""
    codec = ShardCodec()
    bare = np.arange(5, dtype=np.int32)
    manifest, shards = codec.encode(bare)
    out, _ = codec.decode(manifest, shards)
    assert isinstance(out, np.ndarray) and np.array_equal(out, bare)
    nested = {"layers": {"w0": np.ones(3, np.float32),
                         "w1": np.zeros(2, np.float64)},
              "step": np.array(3)}
    manifest, shards = codec.encode(nested)
    out, _ = codec.decode(manifest, shards)
    _tree_equal(nested, out)


def test_verify_gates_single_shards():
    """``verify`` is the puller's per-shard re-pull gate: exact on both
    the intact and the damaged copy, and on truncation."""
    codec = ShardCodec(shard_bytes=1 << 12)
    tree = {"w": np.random.default_rng(1).standard_normal(2000)}
    manifest, shards = codec.encode(tree)
    assert all(codec.verify(manifest, i, s)
               for i, s in enumerate(shards))
    bad = shards[2].copy()
    bad[10] ^= 0x01
    assert not codec.verify(manifest, 2, bad)
    assert not codec.verify(manifest, 0,
                            shards[0][:manifest["sizes"][0] - 1])


def test_shard_fills_counted_under_ckpt_tag():
    """Every shard fill is one *logical* copy on the process engine,
    tagged ``ckpt`` — however many straddle segments it took — so the
    replication datapath shows up in the copies-per-request metric."""
    from repro.core.copyengine import get_engine

    engine = get_engine()
    codec = ShardCodec(shard_bytes=1 << 12)
    tree = {"w": np.random.default_rng(2).standard_normal(3000)}
    before = engine.stats.tagged.get("ckpt", 0)
    manifest, shards = codec.encode(tree)
    assert engine.stats.tagged.get("ckpt", 0) - before == len(shards)
    assert codec.stats["shard_copies"] == len(shards)
    before = engine.stats.tagged.get("ckpt", 0)
    codec.decode(manifest, shards, like=tree)
    assert engine.stats.tagged.get("ckpt", 0) > before
