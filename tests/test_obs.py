"""Observability plane: trace rings, cross-process join, metrics registry.

The trace tests drive the real shared-memory span rings (enable → emit →
collect) inside one process first — wraparound loss accounting, span
nesting, Chrome export — then prove the headline property end to end: a
request issued by a *spawned client process* produces spans on both sides
of the fabric that join into one timeline on the request id, and the
client-side phase spans sum to the measured end-to-end latency.

The disabled-path test is the counted zero-overhead gate: tracing off
must write exactly 0 records (``emitted_count()``), not "few".
"""
import json
import multiprocessing as mp
import time

import numpy as np
import pytest

from repro.core.dispatcher import RequestDispatcher
from repro.core.policy import OffloadPolicy
from repro.ipc import RemoteDispatcherClient, ServingFabric, TransportSpec
from repro.obs import hist as obs_hist
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from conftest import wait_until

TIGHT = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0)
SMALL = TransportSpec(data_slots=4, data_slot_bytes=1 << 20,
                      ctrl_slots=4, ctrl_slot_bytes=4 << 10)


@pytest.fixture
def traced():
    """Fresh trace session; everything unlinked afterwards no matter what."""
    session = obs_trace.enable(capacity=1 << 12)
    try:
        yield session
    finally:
        obs_trace.collect(session, unlink=True)
        obs_trace.disable(unlink=True)


# ---------------------------------------------------------------------------
# disabled = zero records (the counted gate)
# ---------------------------------------------------------------------------

def test_disabled_tracing_writes_exactly_zero_records():
    assert not obs_trace.TRACE.enabled
    before = obs_trace.emitted_count()
    t0 = obs_trace.now()
    obs_trace.emit(obs_trace.HANDLER, t0, rid=1, arg=2)
    obs_trace.instant(obs_trace.GOV_OBSERVE)
    with obs_trace.span(obs_trace.GATHER):
        pass
    assert obs_trace.emitted_count() == before == 0
    assert obs_trace.dropped_count() == 0


def test_disabled_fabric_roundtrip_writes_zero_records_and_clean_wire():
    """An instrumented end-to-end request with tracing off: no records,
    and no rid key smuggled into reply headers."""
    assert not obs_trace.TRACE.enabled
    d = RequestDispatcher(TIGHT)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        client = RemoteDispatcherClient.connect(fab.name, policy=TIGHT)
        out = client.request("double", np.arange(8, dtype=np.float32),
                             mode="sync")
        np.testing.assert_array_equal(out, np.arange(8, dtype=np.float32) * 2)
        client.close()
    assert obs_trace.emitted_count() == 0


# ---------------------------------------------------------------------------
# single-process ring mechanics
# ---------------------------------------------------------------------------

def test_span_nesting_and_collection(traced):
    rid = obs_trace.mint_rid()
    with obs_trace.span(obs_trace.HANDLER, rid=rid, arg=3):
        time.sleep(0.002)
        with obs_trace.span(obs_trace.GATHER, rid=rid):
            time.sleep(0.001)
    view = obs_trace.collect(traced)
    assert view.total_records == 2 and view.total_drops == 0
    outer = view.records_of(obs_trace.HANDLER)[0]
    inner = view.records_of(obs_trace.GATHER)[0]
    # nested span sits strictly inside its parent on the shared timebase
    assert outer["t0"] <= inner["t0"] <= inner["t1"] <= outer["t1"]
    assert int(outer["rid"]) == int(inner["rid"]) == rid
    assert int(outer["arg"]) == 3
    totals = view.phase_totals()
    assert totals["dispatcher.handler"][0] == 1
    assert totals["dispatcher.handler"][1] >= totals["dispatcher.gather"][1]
    assert view.kinds_for_rid(rid).keys() == {obs_trace.HANDLER,
                                              obs_trace.GATHER}


def test_wraparound_overwrites_oldest_and_counts_drops():
    cap = 64
    session = obs_trace.enable(capacity=cap)
    try:
        n = 3 * cap + 7
        for i in range(n):
            t = obs_trace.now()
            obs_trace.emit(obs_trace.COPY_JOB, t, arg=i, t1=t)
        assert obs_trace.emitted_count() == n
        assert obs_trace.dropped_count() == n - cap
        view = obs_trace.collect(session)
        assert view.total_records == cap          # ring holds the newest cap
        assert view.total_drops == n - cap        # loss is counted, not silent
        args = view.records_of(obs_trace.COPY_JOB)["arg"]
        # survivors are exactly the newest records, oldest → newest order
        assert list(args) == list(range(n - cap, n))
    finally:
        obs_trace.collect(session, unlink=True)
        obs_trace.disable(unlink=True)


def test_collect_unlink_destroys_rings(traced):
    obs_trace.instant(obs_trace.GOV_OBSERVE)
    assert obs_trace.discover(traced)
    view = obs_trace.collect(traced, unlink=True)
    assert view.total_records == 1
    assert obs_trace.discover(traced) == []


def test_chrome_trace_export_is_valid_json(traced, tmp_path):
    rid = obs_trace.mint_rid()
    with obs_trace.span(obs_trace.CLIENT_SEND, rid=rid, arg=4096):
        time.sleep(0.001)
    view = obs_trace.collect(traced)
    path = tmp_path / "trace.json"
    view.save_chrome(str(path))
    doc = json.loads(path.read_text())          # must round-trip as JSON
    events = doc["traceEvents"]
    assert len(events) == 1
    ev = events[0]
    assert ev["ph"] == "X" and ev["name"] == "client.send"
    assert ev["dur"] >= 1000.0                  # µs; slept 1 ms inside
    assert ev["args"]["rid"] == rid and ev["args"]["arg"] == 4096
    assert doc["otherData"]["drops"] == 0


# ---------------------------------------------------------------------------
# cross-process: spawned client's spans join the server's on the rid
# ---------------------------------------------------------------------------

def _traced_client_entry(name: str, out_q) -> None:
    """Spawn-child: tracing auto-enabled by the inherited environment; one
    pipelined request, report (rid, measured e2e ns)."""
    from repro.obs import trace as child_trace
    assert child_trace.TRACE.enabled           # env inheritance worked
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    data = np.arange(1 << 14, dtype=np.float32)
    t0 = child_trace.now()
    jid = client.request("slow", data, mode="pipelined")
    rid = client._rids[jid]                    # query() pops it; grab it now
    out = client.query(jid, timeout=60)
    e2e_ns = child_trace.now() - t0
    client.close()
    ok = bool(np.array_equal(out, data * 2))
    out_q.put((rid, e2e_ns, ok))


def test_cross_process_rid_join_and_phase_sum(tmp_path):
    def slow(x):
        time.sleep(0.02)
        return x * 2

    d = RequestDispatcher(TIGHT)
    d.register_handler("slow", slow, batch_fn=lambda xs: [slow(x) for x in xs])
    session = obs_trace.enable(capacity=1 << 14)
    try:
        with ServingFabric(d, spec=SMALL, policy=TIGHT,
                           own_dispatcher=True).start() as fab:
            ctx = mp.get_context("spawn")
            out_q = ctx.Queue()
            proc = ctx.Process(target=_traced_client_entry,
                               args=(fab.name, out_q))
            proc.start()
            rid, e2e_ns, ok = out_q.get(timeout=120)
            proc.join(timeout=120)
            assert proc.exitcode == 0 and ok
        view = obs_trace.collect(session)
        assert view.total_drops == 0
        # spans from BOTH processes landed in one session
        child_pid = proc.pid
        assert child_pid in view.pids and len(view.pids) >= 2
        joined = view.kinds_for_rid(rid)
        # client side of the request…
        assert obs_trace.CLIENT_SEND in joined
        assert obs_trace.QUERY_WAIT in joined
        # …joins the server side on the same rid (byte-exact through the wire)
        assert obs_trace.HANDLER in joined
        assert obs_trace.REPLY_FILL in joined
        client_kinds = {k for k, spans in joined.items()
                        if any(pid == child_pid for pid, _, _ in spans)}
        server_kinds = {k for k, spans in joined.items()
                        if any(pid != child_pid for pid, _, _ in spans)}
        assert obs_trace.CLIENT_SEND in client_kinds
        assert obs_trace.HANDLER in server_kinds

        # the client's phase spans decompose its measured e2e latency: send
        # + completion-wait cover everything but sub-µs bookkeeping, so the
        # sum lands within 10% of the wall clock the child itself measured
        client_ns = sum(t1 - t0 for kind in (obs_trace.CLIENT_SEND,
                                             obs_trace.QUERY_WAIT)
                        for pid, t0, t1 in joined[kind] if pid == child_pid)
        assert abs(client_ns - e2e_ns) <= 0.10 * e2e_ns, (client_ns, e2e_ns)

        # and the joined timeline exports as loadable Chrome-trace JSON
        path = tmp_path / "xproc.json"
        view.save_chrome(str(path))
        doc = json.loads(path.read_text())
        assert {e["pid"] for e in doc["traceEvents"]} >= {child_pid}
    finally:
        obs_trace.collect(session, unlink=True)
        obs_trace.disable(unlink=True)


# ---------------------------------------------------------------------------
# metrics registry + SLO tracker
# ---------------------------------------------------------------------------

class _SnapStats:
    def snapshot(self):
        return {"a": 1, "nested": {"b": 2.5}}


def test_metrics_registry_snapshot_shapes_and_delta():
    reg = obs_metrics.MetricsRegistry()
    reg.register("dict", {"x": 1})
    reg.register("call", lambda: {"y": 2})
    reg.register("snap", _SnapStats())
    assert reg.names() == ["call", "dict", "snap"]
    snap = reg.snapshot()
    assert snap == {"dict.x": 1, "call.y": 2,
                    "snap.a": 1, "snap.nested.b": 2.5}
    later = dict(snap, **{"call.y": 10, "snap.nested.b": 3.0, "tag": "v"})
    delta = obs_metrics.MetricsRegistry.delta(snap, later)
    assert delta["call.y"] == 8
    assert delta["snap.nested.b"] == 0.5
    assert delta["dict.x"] == 0
    assert delta["tag"] == "v"                 # non-numeric passes through
    reg.unregister("dict")
    assert "dict.x" not in reg.snapshot()


def test_slo_tracker_observes_and_rates_model():
    from repro.core.latency import LatencyModel
    model = LatencyModel(l_fixed_us=10.0, alpha_us_per_mb=100.0)
    slo = obs_metrics.SLOTracker(model, window=16)
    for _ in range(8):
        slo.observe(0.001, nbytes=1 << 20)     # 1 ms on 1 MB
    snap = slo.snapshot()
    assert snap["requests"] == 8
    assert snap["mb_in"] == pytest.approx(8.0)
    assert snap["p50_ms"] == pytest.approx(1.0, rel=0.2)
    # predicted 110 µs vs observed 1 ms → ratio ≈ 9.09, EWMA of a constant
    assert snap["model_ratio"] == pytest.approx(1000.0 / 110.0, rel=0.05)


def test_fabric_exposes_unified_metrics_and_slo():
    d = RequestDispatcher(TIGHT)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        client = RemoteDispatcherClient.connect(fab.name, policy=TIGHT)
        for _ in range(3):
            client.request("double", np.ones(16, np.float32), mode="sync")
        # reply send and observe() race: wait for the bookkeeping to land
        wait_until(lambda: fab.slo.requests >= 3, 10,
                   desc="3 slo observations")
        snap = fab.metrics.snapshot()
        full = fab.stats()
        client.close()
    assert snap["slo.requests"] >= 3
    assert snap["slo.p50_ms"] > 0
    assert snap["listener.accepted"] == 1
    assert any(k.startswith("reactor.") for k in snap)
    assert any(k.startswith("dispatcher.") for k in snap)
    assert full["slo"]["requests"] >= 3
    assert full["metrics"]["slo.requests"] >= 3


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

def test_histogram_buckets_merge_and_percentile():
    h = obs_hist.Histogram()
    h.add(0)
    h.add(1)
    h.add(1000)
    assert h.counts[0] == 1                    # zeros live in bucket 0
    assert h.counts[1] == 1                    # 2^0 <= 1 < 2^1
    assert h.counts[10] == 1                   # 2^9 <= 1000 < 2^10
    assert h.n == 3 and h.total == 1001
    assert h.mean == pytest.approx(1001 / 3)

    g = obs_hist.Histogram.from_durations(np.full(97, 1000, np.int64))
    g.merge(h)
    assert g.n == 100 and g.total == 97 * 1000 + 1001
    # 100 values, 97 of them 1000 → p95 falls in the 1000s bucket
    assert 512 <= g.percentile(95) <= 1023
    assert g.percentile(1) == 0

    rt = obs_hist.Histogram.from_dict(g.to_dict())
    assert rt.n == g.n and rt.total == g.total
    assert np.array_equal(rt.counts, g.counts)


def test_phase_histograms_and_report_from_view(traced):
    for _ in range(4):
        with obs_trace.span(obs_trace.RING_WAIT):
            time.sleep(0.001)
    view = obs_trace.collect(traced)
    hists = obs_hist.phase_histograms(view)
    assert set(hists) == {"ring.wait"}
    assert hists["ring.wait"].n == 4
    assert hists["ring.wait"].mean >= 1e6      # slept ≥ 1 ms per span
    report = obs_hist.phase_report(view, per=4)
    assert "ring.wait" in report and "us/item" in report
