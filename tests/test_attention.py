"""Attention invariants: blockwise == direct, GQA grouping, masks, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import apply_rope


def mk_cfg(h=4, kh=2, hd=16, **kw):
    return ModelConfig(name="t", family="dense", num_layers=1, d_model=h * hd,
                       num_heads=h, num_kv_heads=kh, head_dim=hd, d_ff=32,
                       vocab_size=64, dtype="float32", param_dtype="float32",
                       **kw)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
def test_blockwise_matches_direct(causal, h, kh, rng_key):
    cfg = mk_cfg(h=h, kh=kh)
    b, s, t, hd = 2, 64, 64, 16
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, t, kh, hd))
    v = jax.random.normal(ks[2], (b, t, kh, hd))
    mask = attn.causal_mask(s, t) if causal else attn.full_mask(s, t)
    ref = attn.attend(q, k, v, cfg, mask)
    out = attn.attend_blockwise(q, k, v, cfg, causal=causal,
                                q_block=16, k_block=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 4), st.integers(2, 6))
def test_blockwise_block_size_invariance(qb_pow, s_pow):
    """Result must not depend on block decomposition."""
    cfg = mk_cfg()
    s = 2 ** s_pow
    qb = 2 ** min(qb_pow, s_pow)
    key = jax.random.key(s * 7 + qb)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, s, 4, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    a = attn.attend_blockwise(q, k, v, cfg, causal=True, q_block=qb, k_block=qb)
    b = attn.attend_blockwise(q, k, v, cfg, causal=True, q_block=s, k_block=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_decode_matches_prefill_attention(rng_key):
    """Cached single-token attention equals the last row of full attention."""
    cfg = mk_cfg()
    b, s, d = 2, 9, cfg.d_model
    params = attn.attn_init(rng_key, cfg)
    x = jax.random.normal(jax.random.key(3), (b, s, d))
    positions = jnp.arange(s)[None, :]
    full = attn.self_attention(params, x, cfg, positions=positions)
    # replay through the cache
    q, k, v = attn.project_qkv(params, x[:, : s - 1], cfg,
                               jnp.arange(s - 1)[None, :])
    layer_k = jnp.zeros((b, s + 2, cfg.num_kv_heads, cfg.resolved_head_dim()))
    layer_v = jnp.zeros_like(layer_k)
    layer_k, layer_v = attn.cache_insert_prefill(layer_k, layer_v, k, v)
    index = jnp.full((b,), s - 1, jnp.int32)
    out, _, _ = attn.self_attention_decode(
        params, x[:, s - 1:], cfg, layer_k=layer_k, layer_v=layer_v, index=index)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_rope_relative_shift_invariance(rng_key):
    """RoPE: <q_i, k_j> depends only on i - j (within one head)."""
    hd = 32
    q = jax.random.normal(rng_key, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.key(5), (1, 1, 1, hd))
    def score(qpos, kpos):
        qr = apply_rope(q, jnp.array([[qpos]]), 1e4)
        kr = apply_rope(k, jnp.array([[kpos]]), 1e4)
        return float(jnp.sum(qr * kr))
    a = score(3, 1)
    b = score(10, 8)
    assert abs(a - b) < 1e-4


def test_cache_insert_token_per_batch_positions():
    b, t, kh, hd = 3, 8, 2, 4
    lk = jnp.zeros((b, t, kh, hd))
    lv = jnp.zeros((b, t, kh, hd))
    k = jnp.ones((b, 1, kh, hd))
    v = 2 * jnp.ones((b, 1, kh, hd))
    index = jnp.array([0, 3, 7], jnp.int32)
    lk, lv = attn.cache_insert_token(lk, lv, k, v, index)
    for i, pos in enumerate([0, 3, 7]):
        assert float(lk[i, pos].sum()) == kh * hd
        assert float(lk[i].sum()) == kh * hd, "wrote outside the slot"


def test_gqa_head_grouping_semantics(rng_key):
    """GQA must equal MHA with KV heads repeated per group."""
    cfg_gqa = mk_cfg(h=4, kh=2)
    cfg_mha = mk_cfg(h=4, kh=4)
    b, s, hd = 1, 8, 16
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, s, 4, hd))
    k = jax.random.normal(ks[1], (b, s, 2, hd))
    v = jax.random.normal(ks[2], (b, s, 2, hd))
    mask = attn.causal_mask(s)
    out_gqa = attn.attend(q, k, v, cfg_gqa, mask)
    out_mha = attn.attend(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2),
                          cfg_mha, mask)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)
