"""SLO serving tests: lane queue, shedding, misses, monitor, sharded fabric.

Covers the deadline/priority datapath end to end — unit level (the
``_LaneQueue`` ordering contract, the ``ServiceTimeModel`` predictor, the
``SLOMonitor`` rule kinds) and integration level (a real
``ServingFabric`` with 2 reactor shards serving in-process
``RemoteDispatcherClient``s: lane partitioning, per-request deadlines,
counted sheds surfacing as client-side ``DeadlineExceeded`` errors, and
the per-lane metrics plane)."""
from __future__ import annotations

import queue
import time

import numpy as np
import pytest

from repro.core.dispatcher import (DeadlineExceeded, RequestDispatcher,
                                   Request, _LaneQueue)
from repro.core.latency import LatencyModel, ServiceTimeModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ft.monitor import SLOMonitor
from repro.ipc import (DEADLINE_KEY, PRIO_KEY, RemoteDispatcherClient,
                       ServingFabric, TransportSpec)
from repro.obs.metrics import SLOTracker


def _req(job_id, priority=0, deadline_ns=0, op="op"):
    return Request(job_id, op, None, ExecutionMode.PIPELINED,
                   priority=priority, deadline_ns=deadline_ns)


# -- _LaneQueue: the lane-ordering contract ---------------------------------

class TestLaneQueue:
    def test_priority_order(self):
        q = _LaneQueue()
        for jid, prio in [(1, 2), (2, 0), (3, 1)]:
            q.put(_req(jid, priority=prio))
        assert [q.get().job_id for _ in range(3)] == [2, 3, 1]

    def test_deadline_tiebreak_within_lane(self):
        q = _LaneQueue()
        q.put(_req(1, deadline_ns=300))
        q.put(_req(2, deadline_ns=100))
        q.put(_req(3))                       # no deadline: last in its lane
        q.put(_req(4, deadline_ns=200))
        assert [q.get().job_id for _ in range(4)] == [2, 4, 1, 3]

    def test_fifo_inside_equal_urgency(self):
        q = _LaneQueue()
        for jid in (1, 2, 3):
            q.put(_req(jid))
        assert [q.get().job_id for _ in range(3)] == [1, 2, 3]

    def test_match_closes_window_without_popping(self):
        """A mismatched front stays queued (the batch window closes); it
        must not be reordered past or silently consumed."""
        q = _LaneQueue()
        q.put(_req(1, priority=1))
        q.put(_req(2, priority=0))           # more urgent: now the front
        with pytest.raises(queue.Empty):
            q.get(match=lambda r: r.priority == 1)
        assert q.get().job_id == 2           # urgency order intact
        assert q.get().job_id == 1

    def test_sentinel_stops_regardless_of_match(self):
        q = _LaneQueue()
        q.put(None)
        assert q.get(match=lambda r: False) is None

    def test_timeout_raises_empty(self):
        with pytest.raises(queue.Empty):
            _LaneQueue().get(timeout=0.01)


# -- ServiceTimeModel: the shed predictor -----------------------------------

def test_service_time_model_floor_and_ewma():
    m = ServiceTimeModel(LatencyModel(l_fixed_us=100.0, alpha_us_per_mb=0.0))
    floor = m.predict_s("op")
    assert floor == pytest.approx(100e-6)
    m.observe("op", 0.05)
    assert m.predict_s("op") >= 0.05 * 0.2   # EWMA pulled above the floor
    m.observe("other", 1e-9)
    assert m.predict_s("other") == pytest.approx(floor)  # floored
    assert "op_ms" in m.snapshot()


# -- dispatcher: shed + miss counting ---------------------------------------

@pytest.fixture()
def dispatcher():
    d = RequestDispatcher(OffloadPolicy(offload_threshold_bytes=1,
                                        max_batch=4))
    d.register_handler("echo", lambda x: x,
                       batch_fn=lambda xs: list(xs))
    yield d
    d.close()


def test_shed_is_counted_error_reply(dispatcher):
    """An already-expired deadline sheds: counted per lane, and the
    submitter gets DeadlineExceeded — never a silent drop or a hang."""
    x = np.zeros(4, np.float32)
    with pytest.raises(DeadlineExceeded):
        dispatcher.request("echo", x, mode="sync", priority=2,
                           deadline_ns=time.perf_counter_ns() - 1)
    assert dispatcher.stats.shed == 1
    assert dispatcher.stats.lane_shed == {2: 1}
    assert dispatcher.stats.lane_requests[2] == 1


def test_no_deadline_never_sheds(dispatcher):
    x = np.arange(4, dtype=np.float32)
    out = dispatcher.request("echo", x, mode="sync")
    np.testing.assert_array_equal(out, x)
    assert dispatcher.stats.shed == 0


def test_completed_late_counts_deadline_miss():
    d = RequestDispatcher(OffloadPolicy(offload_threshold_bytes=1))
    d.register_handler("slow", lambda x: (time.sleep(0.03), x)[1])
    try:
        out = d.request("slow", np.ones(2, np.float32), mode="sync",
                        deadline_ns=time.perf_counter_ns() + int(5e6))
        assert out is not None               # ran to completion (late)
        assert d.stats.deadline_miss == 1
        assert d.stats.shed == 0
    finally:
        d.close()


def test_worker_pool_drains_shared_lane_queue():
    d = RequestDispatcher(OffloadPolicy(offload_threshold_bytes=1),
                          workers=3)
    d.register_handler("echo", lambda x: x)
    try:
        jobs = [d.request("echo", np.full(2, i, np.float32),
                          mode="async") for i in range(12)]
        for i, jid in enumerate(jobs):
            np.testing.assert_array_equal(d.query(jid),
                                          np.full(2, i, np.float32))
        assert d.stats.requests == 12
    finally:
        d.close()


# -- SLOTracker lanes + SLOMonitor rules ------------------------------------

def test_slo_tracker_per_lane():
    t = SLOTracker()
    t.observe(0.010, lane=0)
    t.observe(0.050, lane=1, miss=True)
    snap = t.snapshot()
    assert snap["deadline_misses"] == 1
    assert snap["lane0"]["requests"] == 1 and snap["lane0"]["misses"] == 0
    assert snap["lane1"]["misses"] == 1
    assert snap["lane1"]["p99_ms"] == pytest.approx(50.0)


def test_slo_monitor_max_and_rate_rules():
    metrics = {"slo.p95_ms": 10.0, "dispatcher.shed": 0}

    class Src:
        def snapshot(self):
            return dict(metrics)

    mon = SLOMonitor(Src())
    mon.add_rule("slo.p95_ms", 50.0)                 # level bound
    mon.add_rule("dispatcher.shed", 2, kind="rate")  # growth bound
    assert mon.check() == []
    metrics["dispatcher.shed"] = 2                   # +2: at the bound
    assert mon.check() == []
    metrics["slo.p95_ms"] = 80.0                     # level blown
    metrics["dispatcher.shed"] = 9                   # +7: rate blown
    new = mon.check()
    assert {v["key"] for v in new} == {"slo.p95_ms", "dispatcher.shed"}
    assert mon.snapshot()["violations"] == 2
    with pytest.raises(ValueError):
        mon.add_rule("x", 1, kind="bogus")


# -- sharded fabric + client deadline API (in-process integration) ----------

@pytest.fixture()
def fabric():
    d = RequestDispatcher(OffloadPolicy(offload_threshold_bytes=1,
                                        max_batch=4), workers=2)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    spec = TransportSpec(data_slots=4, data_slot_bytes=1 << 16,
                         heap_extents=0)
    with ServingFabric(d, spec=spec, own_dispatcher=True,
                       reactors=2).start() as f:
        yield f


def test_clients_partition_across_shards(fabric):
    c0 = RemoteDispatcherClient.connect(fabric.name, timeout_s=10, lane=0)
    c1 = RemoteDispatcherClient.connect(fabric.name, timeout_s=10, lane=1)
    try:
        assert all(len(r) == 1 for r in fabric.reactors)  # round-robin
        stats = fabric.stats()
        assert stats["reactor"]["shards"] == 2
        # multi-shard client keys are shard-qualified; lanes were seeded
        # from the accept-time registration meta before any request
        assert set(stats["clients"]) == {"s0c0", "s1c0"}
        lanes = sorted(c["lane"] for c in stats["clients"].values())
        assert lanes == [0, 1]
    finally:
        c0.close()
        c1.close()


def test_deadline_api_end_to_end(fabric):
    with RemoteDispatcherClient.connect(fabric.name, timeout_s=10,
                                        lane=1) as client:
        x = np.arange(4, dtype=np.float32)
        out = client.request("double", x, mode="sync", deadline_ms=2000.0)
        np.testing.assert_array_equal(out, x * 2)
        # generous deadline met: observed per-lane, no miss, no shed
        snap = fabric.metrics.snapshot()
        assert snap["slo.lane1.requests"] == 1
        assert snap["slo.lane1.misses"] == 0
        assert snap["dispatcher.lane_requests.1"] == 1

        # expired deadline: server sheds, client sees the counted error
        with pytest.raises(RuntimeError, match="DeadlineExceeded"):
            client.request("double", x, mode="sync", deadline_ms=-10.0)
        assert fabric.dispatcher.stats.shed == 1
        assert fabric.dispatcher.stats.lane_shed == {1: 1}


def test_priority_override_and_wire_keys(fabric):
    """Explicit per-request priority overrides the client lane, and the
    reserved keys are stripped before headers reach handlers."""
    seen = {}

    def spy(x):
        seen["header_free"] = True       # handler only ever sees the data
        return x

    fabric.dispatcher.register_handler("spy", spy)
    with RemoteDispatcherClient.connect(fabric.name, timeout_s=10,
                                        lane=1) as client:
        client.request("spy", np.ones(2, np.float32), mode="sync",
                       priority=3, deadline_ms=2000.0)
        assert seen["header_free"]
        assert fabric.dispatcher.stats.lane_requests.get(3) == 1
        assert "slo.lane3.requests" in fabric.metrics.snapshot()


def test_default_deadline_arms_monitor():
    d = RequestDispatcher(OffloadPolicy(offload_threshold_bytes=1))
    d.register_handler("echo", lambda x: x)
    spec = TransportSpec(data_slots=4, data_slot_bytes=1 << 16,
                         heap_extents=0)
    with ServingFabric(d, spec=spec, own_dispatcher=True,
                       default_deadline_ms=5000.0).start() as f:
        assert "slo.p95_ms" in f.monitor.rules
        with RemoteDispatcherClient.connect(f.name, timeout_s=10) as c:
            c.request("echo", np.ones(2, np.float32), mode="sync")
            assert f.monitor.check() == []   # well under the default SLO
            assert f.slo.snapshot()["lane0"]["requests"] == 1
