"""Adaptive governor + small-message fast path (coalesced frames).

Governor units drive :class:`~repro.core.governor.ChannelGovernor` with
synthetic size/occupancy/cost traces — no clocks, no processes — and
assert the decision flips exactly at the recorded break-evens.  The
frame tests cover the coalesced wire format end to end: K-message
round-trips across spawned processes (byte-identical, headers in order),
partial-frame flush on idle via ``handle.wait()``/``flush()``, lease
independence on the shared slot, shutdown mid-frame, and the
pickle-free binary meta path (counted, not timed).
"""
import multiprocessing as mp
import time

import numpy as np
import pytest

from repro.core.governor import (
    COALESCE,
    HEAP,
    INLINE,
    OFFLOAD,
    ChannelGovernor,
    size_class,
)
from repro.core.policy import OffloadPolicy
from repro.ipc import ChannelClosed, RecvLease, Reactor, ShmTransport, TransportSpec


def _gov(**kw):
    """A governor with exploration/caching disabled unless asked: decisions
    become a pure deterministic function of the observed costs."""
    kw.setdefault("explore_every", 0)
    kw.setdefault("refresh_every", 1)
    kw.setdefault("min_samples", 1)
    kw.setdefault("explore_burst", 1)
    kw.setdefault("occupancy_alpha", 1.0)   # occupancy = last observation
    return ChannelGovernor(OffloadPolicy(), **kw)


# ---------------------------------------------------------------------------
# governor units (synthetic traces)
# ---------------------------------------------------------------------------

def test_size_class_buckets():
    assert size_class(1) == 10          # sub-KB shares one class
    assert size_class(1 << 10) == 10
    assert size_class((1 << 10) + 1) == 11
    assert size_class(64 << 10) == 16
    assert size_class((64 << 10) + 1) == 17


def test_decides_cheapest_measured_route():
    gov = _gov()
    for _ in range(4):
        gov.observe(INLINE, 4096, 50.0)
        gov.observe(OFFLOAD, 4096, 120.0)
    assert gov.decide(4096, (INLINE, OFFLOAD)) == INLINE


def test_break_even_flip_on_synthetic_trace():
    """The decision flips when the measured costs cross the recorded
    break-even — the static threshold replaced by feedback."""
    gov = _gov(alpha=0.5)
    for _ in range(4):
        gov.observe(INLINE, 64 << 10, 40.0)
        gov.observe(OFFLOAD, 64 << 10, 200.0)
    assert gov.decide(64 << 10, (INLINE, OFFLOAD)) == INLINE
    # offload gets drastically cheaper (e.g. a queue drained): EWMA crosses
    for _ in range(16):
        gov.observe(OFFLOAD, 64 << 10, 5.0)
        gov.observe(INLINE, 64 << 10, 40.0)
    assert gov.decide(64 << 10, (INLINE, OFFLOAD)) == OFFLOAD
    assert gov.stats.flips >= 1


def test_hysteresis_blocks_jitter_flips():
    """A challenger inside the switch margin does not displace the
    incumbent — measurement jitter cannot cause route flapping."""
    gov = _gov(switch_margin=0.75)
    for _ in range(4):
        gov.observe(INLINE, 4096, 100.0)
        gov.observe(COALESCE, 4096, 110.0)
    gov.observe_occupancy(8.0)
    assert gov.decide(4096, (INLINE, COALESCE)) == INLINE
    # coalesce now *slightly* cheaper (90 vs 100): within margin, no flip
    for _ in range(8):
        gov.observe(COALESCE, 4096, 90.0)
    assert gov.decide(4096, (INLINE, COALESCE)) == INLINE
    assert gov.stats.flips == 0
    # decisively cheaper: flips
    for _ in range(16):
        gov.observe(COALESCE, 4096, 20.0)
    assert gov.decide(4096, (INLINE, COALESCE)) == COALESCE
    assert gov.stats.flips == 1


def test_occupancy_gates_coalesce():
    """Coalescing needs queue depth: a depth-1 request/reply stream never
    coalesces no matter how cheap it measured (load-aware coordination)."""
    gov = _gov(min_coalesce_occupancy=1.5)
    for _ in range(4):
        gov.observe(INLINE, 4096, 100.0)
        gov.observe(COALESCE, 4096, 10.0)
    gov.observe_occupancy(0.2)          # idle channel
    assert gov.decide(4096, (INLINE, COALESCE)) == INLINE
    for _ in range(50):
        gov.observe_occupancy(4.0)      # stream built up a backlog
    assert gov.decide(4096, (INLINE, COALESCE)) == COALESCE


def test_cold_start_explores_every_route_in_bursts():
    gov = _gov(min_samples=2, explore_burst=2, refresh_every=1)
    seen = []
    for _ in range(12):
        pick = gov.decide(4096, (INLINE, OFFLOAD, COALESCE))
        seen.append(pick)
        gov.observe(pick, 4096, 50.0)
        gov.observe_occupancy(8.0)
    assert {INLINE, OFFLOAD, COALESCE} <= set(seen)
    # bursts: the cold probes come in consecutive pairs, not interleaved
    assert seen[0] == seen[1] and seen[2] == seen[3] and seen[4] == seen[5]


def test_reprobe_backoff_scales_with_cost_ratio():
    """A 60x-worse route is re-probed ~60x more rarely than a near-cost
    one, so confirming a terrible route costs a vanishing stream share."""
    gov = _gov(explore_every=50, explore_burst=1, refresh_every=1,
               min_samples=1)
    gov.observe(INLINE, 4096, 10.0)
    gov.observe(OFFLOAD, 4096, 600.0)   # 60x worse
    gov.observe(COALESCE, 4096, 12.0)   # near-cost
    gov.observe_occupancy(8.0)
    picks = []
    for _ in range(300):
        pick = gov.decide(4096, (INLINE, OFFLOAD, COALESCE))
        picks.append(pick)
        gov.observe(pick, 4096, {INLINE: 10.0, OFFLOAD: 600.0,
                                 COALESCE: 12.0}[pick])
    assert picks.count(OFFLOAD) == 0          # due at ~50*60 decisions
    assert picks.count(COALESCE) >= 2         # due every ~50-60 decisions


def test_winsorized_ewma_survives_one_outlier():
    """One 100x scheduling outlier on the incumbent must not flip the
    route (coarse-timer kernels: a stray quantum sleep is ~1 ms)."""
    gov = _gov()
    for _ in range(8):
        gov.observe(INLINE, 4096, 30.0)
        gov.observe(OFFLOAD, 4096, 60.0)
    gov.observe(INLINE, 4096, 3000.0)   # one stray sleep
    assert gov.decide(4096, (INLINE, OFFLOAD)) == INLINE


def test_prior_seeding_matches_static_policy():
    """Before any measurement, the governor's priors reproduce the static
    Table III choice: small below-threshold messages go inline."""
    gov = _gov(min_samples=0)
    assert gov.decide(4096, (INLINE, OFFLOAD)) == INLINE


def test_snapshot_is_plain_data():
    gov = _gov()
    gov.observe(INLINE, 4096, 30.0)
    gov.decide(4096, (INLINE, OFFLOAD))
    snap = gov.snapshot()
    assert snap["decisions"] == 1
    assert snap["classes"][size_class(4096)][INLINE]["samples"] == 1
    assert isinstance(snap["occupancy"], float)


# ---------------------------------------------------------------------------
# coalesced frames (single-process pair)
# ---------------------------------------------------------------------------

WIDE = OffloadPolicy(coalesce_bytes=256 << 10, coalesce_max=4,
                     coalesce_window_us=10e6,     # never flush on time
                     offload_threshold_bytes=1 << 62)
SPEC = TransportSpec(data_slots=4, data_slot_bytes=1 << 20, heap_extents=0)


def _pair(policy=WIDE):
    a = ShmTransport.create(spec=SPEC, policy=policy)
    b = ShmTransport.attach(a.name, policy=policy)
    return a, b


def test_frames_amortize_doorbells_and_roundtrip():
    a, b = _pair()
    try:
        arrs = [np.arange(64, dtype=np.int64) * (i + 1) for i in range(8)]
        handles = [a.send({"x": arr}, header={"i": i}, mode="pipelined")
                   for i, arr in enumerate(arrs)]
        a.data.flush()
        for i, arr in enumerate(arrs):
            tree, header = b.recv(timeout_s=10)
            assert header["i"] == i
            np.testing.assert_array_equal(tree["x"], arr)
        assert all(h.done() for h in handles)
        assert a.data.stats.sends == 8
        assert a.data.stats.coalesced_sends == 8
        assert a.data.stats.frames_sent == 2       # K=4: two frames
        assert a._rings["tx_data"].produced == 2   # doorbells/msg = 0.25
        assert b.data.stats.frames_recv == 2
        assert b.data.stats.coalesced_recvs == 8
    finally:
        b.close()
        a.close()


def test_partial_frame_flush_on_wait_and_flush():
    a, b = _pair()
    try:
        h1 = a.send({"x": np.arange(8)}, mode="pipelined")
        h2 = a.send({"x": np.arange(8) + 1}, mode="pipelined")
        assert not h1.done() and not h2.done()     # frame still open
        assert b.data.try_recv() is None           # nothing published yet
        h1.wait()                                  # pull-flush: whole frame
        assert h1.done() and h2.done()
        for off in (0, 1):
            tree, _ = b.recv(timeout_s=10)
            np.testing.assert_array_equal(tree["x"], np.arange(8) + off)
        # explicit flush() publishes an open partial frame too
        a.send({"x": np.arange(4)}, mode="pipelined")
        a.data.flush()
        tree, _ = b.recv(timeout_s=10)
        np.testing.assert_array_equal(tree["x"], np.arange(4))
    finally:
        b.close()
        a.close()


def test_frame_lease_independence_slot_recycles_on_last_release():
    a, b = _pair()
    try:
        for i in range(4):
            a.send({"x": np.full(16, i)}, mode="pipelined")
        a.data.flush()
        ring = b.data.rx
        leases = [b.recv(timeout_s=10, copy=False) for _ in range(4)]
        consumed0 = ring.consumed
        # release out of order; the shared slot must survive until the last
        leases[2].release()
        leases[0].release()
        leases[3].release()
        assert ring.consumed == consumed0          # still held by lease 1
        np.testing.assert_array_equal(leases[1].tree["x"], np.full(16, 1))
        leases[1].release()
        assert ring.consumed == consumed0 + 1      # now recycled
    finally:
        b.close()
        a.close()


def test_mixed_copy_modes_on_one_frame():
    """A frame drained under one copy mode can be consumed under the
    other (the pending queue adapts per recv call)."""
    a, b = _pair()
    try:
        for i in range(4):
            a.send({"x": np.full(16, i)}, mode="pipelined")
        a.data.flush()
        t0, _ = b.recv(timeout_s=10, copy=True)        # polls the frame
        lease = b.recv(timeout_s=10, copy=False)       # pending -> lease
        t2, _ = b.recv(timeout_s=10, copy=True)        # pending -> copy
        np.testing.assert_array_equal(t0["x"], np.full(16, 0))
        np.testing.assert_array_equal(lease.tree["x"], np.full(16, 1))
        np.testing.assert_array_equal(t2["x"], np.full(16, 2))
        lease.release()
        b.recv(timeout_s=10)
    finally:
        b.close()
        a.close()


def test_try_recv_many_drains_frame_in_one_poll():
    a, b = _pair()
    try:
        for i in range(4):
            a.send({"x": np.full(8, i)}, header={"i": i}, mode="pipelined")
        a.data.flush()
        polls0 = b.data.rx.stats.consumed
        items = b.data.try_recv_many(16)
        assert [h["i"] for _, h in items] == [0, 1, 2, 3]
    finally:
        b.close()
        a.close()


def test_sync_send_flushes_open_frame_first():
    """FIFO: a sync send behind pending coalesced messages publishes the
    frame before claiming its own slot."""
    a, b = _pair()
    try:
        a.send({"x": np.arange(8)}, header={"i": 0}, mode="pipelined")
        a.send({"x": np.arange(8)}, header={"i": 1}, mode="sync")
        for expect in (0, 1):
            _, header = b.recv(timeout_s=10)
            assert header["i"] == expect
    finally:
        b.close()
        a.close()


def test_unencodable_header_fails_cleanly_without_wedging_ring():
    """A header the meta encoder cannot serialize (binary codec AND
    pickle both refuse) must abort the claimed slot as a skip sentinel —
    a leaked WRITING slot would wedge the in-order SPSC ring forever."""
    import threading
    a, b = _pair()
    try:
        with pytest.raises(TypeError):
            a.send({"x": np.arange(8)}, header={"bad": threading.Lock()},
                   mode="sync")
        a.send({"x": np.arange(8)}, header={"i": 1}, mode="sync")
        tree, header = b.recv(timeout_s=10)
        assert header["i"] == 1
        np.testing.assert_array_equal(tree["x"], np.arange(8))
    finally:
        b.close()
        a.close()


def test_coalesced_frame_never_overtakes_offloaded_send():
    """FIFO across routes: a frame opened behind an in-flight offloaded
    send must not publish its slot first."""
    pol = OffloadPolicy(coalesce_bytes=16 << 10, coalesce_max=4,
                        coalesce_window_us=10e6,
                        offload_threshold_bytes=64 << 10)
    a = ShmTransport.create(spec=SPEC, policy=pol)
    b = ShmTransport.attach(a.name, policy=pol)
    try:
        # 4 messages = 4 ring slots (no concurrent drain in this test)
        for i in range(4):
            if i % 2 == 0:       # 128 KB: offloaded on the engine thread
                a.send({"x": np.full(16 << 10, i, np.int64)},
                       header={"i": i}, mode="async")
            else:                # 4 KB: coalesce-eligible
                a.send({"x": np.full(512, i, np.int64)},
                       header={"i": i}, mode="async")
        a.data.flush()
        order = [b.recv(timeout_s=10)[1]["i"] for _ in range(4)]
        assert order == list(range(4))
    finally:
        b.close()
        a.close()


def test_shutdown_with_open_frame_delivers_then_closes():
    a, b = _pair()
    try:
        a.send({"x": np.arange(8)}, mode="pipelined")
        a.send({"x": np.arange(8) + 1}, mode="pipelined")
        a.close()                  # flushes the open frame, raises the flag
        for off in (0, 1):
            tree, _ = b.recv(timeout_s=10)
            np.testing.assert_array_equal(tree["x"], np.arange(8) + off)
        with pytest.raises(ChannelClosed):
            b.data.try_recv()
            b.ctrl.try_recv_msg()      # flag up + drained -> ChannelClosed
    finally:
        b.close()
        a.close()


def test_binary_meta_is_pickle_free_steady_state():
    """Counted, not timed: after the first descriptor-cache miss, sends
    and recvs with flat headers perform ZERO meta pickle calls; a rich
    header transparently falls back (and is counted)."""
    a, b = _pair()
    try:
        header = {"step": 7, "name": "x", "f": 1.5, "blob": b"ab",
                  "pair": (1, 2), "none": None, "flag": True}
        a.send({"x": np.arange(8)}, header=header, mode="sync")
        tree, got = b.recv(timeout_s=10)
        assert got == header
        base_tx = a.data.stats.meta_pickles       # 1: descriptor miss
        base_rx = b.data.stats.meta_unpickles
        for i in range(10):
            a.send({"x": np.arange(8) + i}, header=header, mode="sync")
            b.recv(timeout_s=10)
        assert a.data.stats.meta_pickles == base_tx
        assert b.data.stats.meta_unpickles == base_rx
        # rich header: per-message pickle fallback, counted on both ends
        a.send({"x": np.arange(8)}, header={"obj": {"nested": [1]}},
               mode="sync")
        _, got = b.recv(timeout_s=10)
        assert got == {"obj": {"nested": [1]}}
        assert a.data.stats.meta_pickles == base_tx + 1
        assert b.data.stats.meta_unpickles == base_rx + 1
    finally:
        b.close()
        a.close()


def test_adaptive_governor_end_to_end_converges():
    """An adaptive channel under a deep pipelined stream converges to a
    coherent route and moves every byte correctly."""
    pol = OffloadPolicy(governor="adaptive", coalesce_max=4,
                        coalesce_window_us=10e6)
    a = ShmTransport.create(spec=SPEC, policy=pol)
    b = ShmTransport.attach(a.name, policy=OffloadPolicy())
    try:
        assert a.data.governor is not None
        rng = np.random.default_rng(0)
        arrs = [rng.integers(0, 1 << 30, 256).astype(np.int64)
                for _ in range(60)]
        got = []
        for arr in arrs:
            a.send({"x": arr}, mode="pipelined")
            while True:                       # drain opportunistically
                item = b.data.try_recv()
                if item is None:
                    break
                got.append(item[0]["x"])
        a.data.flush()
        while len(got) < len(arrs):
            tree, _ = b.recv(timeout_s=10)
            got.append(tree["x"])
        for sent, recvd in zip(arrs, got):
            np.testing.assert_array_equal(sent, recvd)
        snap = a.data.governor.snapshot()
        assert snap["decisions"] == len(arrs)
        assert sum(snap["picks"].values()) == len(arrs)
        assert "governor" in a.stats()
    finally:
        b.close()
        a.close()


def test_reactor_batched_drain_delivers_frame_as_one_list():
    """The reactor's on_messages handoff receives a whole coalesced frame
    from one poll sweep (no K separate callback iterations)."""
    batches = []

    def on_messages(conn, leases):
        batches.append(len(leases))
        for lease in leases:
            lease.release()
            conn.done()

    reactor = Reactor(policy=WIDE, on_messages=on_messages,
                      max_drain_per_sweep=16)
    server = ShmTransport.create(spec=SPEC, policy=WIDE)
    client = ShmTransport.attach(server.name, policy=WIDE)
    try:
        reactor.add(server)
        for i in range(4):
            client.send({"x": np.full(8, i)}, mode="pipelined")
        client.data.flush()
        deadline = time.perf_counter() + 10
        while sum(batches) < 4 and time.perf_counter() < deadline:
            reactor.poll_once()
            time.sleep(0.001)
        assert sum(batches) == 4
        assert max(batches) == 4          # the frame arrived as ONE batch
        assert reactor.stats.batched_drains >= 1
    finally:
        client.close()
        reactor.close()


# ---------------------------------------------------------------------------
# spawned-process round-trip (module-level child: spawn-safe)
# ---------------------------------------------------------------------------

def _frame_producer(name: str, n: int) -> None:
    pol = OffloadPolicy(coalesce_bytes=256 << 10, coalesce_max=4,
                        coalesce_window_us=10e6,
                        offload_threshold_bytes=1 << 62)
    t = ShmTransport.attach(name, policy=pol)
    for i in range(n):
        arr = (np.arange(512, dtype=np.int64) * 7919 + i)
        t.send({"x": arr}, header={"i": i}, mode="pipelined")
    t.data.flush()
    t.recv_msg(timeout_s=30)      # hold the mapping until the parent is done
    t.close()


def test_spawn_coalesced_frames_byte_identical():
    n = 11                        # deliberately not a multiple of K
    ctx = mp.get_context("spawn")
    t = ShmTransport.create(spec=SPEC, policy=WIDE)
    p = ctx.Process(target=_frame_producer, args=(t.name, n), daemon=True)
    p.start()
    try:
        for i in range(n):
            tree, header = t.recv(timeout_s=30)
            assert header["i"] == i
            np.testing.assert_array_equal(
                tree["x"], np.arange(512, dtype=np.int64) * 7919 + i)
        stats = t.data.stats
        assert stats.recvs == n
        assert stats.coalesced_recvs == n
        assert stats.frames_recv == 3          # 4+4+3
        assert stats.meta_unpickles == 1       # descriptor miss only
        t.send_msg("done", timeout_s=30)
    finally:
        p.join(timeout=30)
        t.close()
