import os
import sys

# Tests must see the single real CPU device (the dry-run subprocess sets its
# own device count); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is an *optional* test dependency: in network-isolated containers
# it may be missing.  Install the stub (tests/_hypothesis_compat.py) before
# any test module does `from hypothesis import given, ...` so collection
# survives and property tests skip instead of erroring.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _hypothesis_compat

_HYPOTHESIS_STUBBED = _hypothesis_compat.install()

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,
    max_examples=20,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


def wait_until(pred, timeout_s: float = 10.0, interval_s: float = 0.002,
               desc: str = "condition"):
    """Poll ``pred`` until truthy or ``timeout_s`` elapses; returns the
    truthy value.  The shared de-flake helper for the multi-process spawn
    suites: one bounded, uniform poll loop instead of ad-hoc
    ``time.sleep`` chains that either flake on slow CI or oversleep."""
    import time
    deadline = time.perf_counter() + timeout_s
    while True:
        value = pred()
        if value:
            return value
        if time.perf_counter() > deadline:
            raise TimeoutError(f"timed out after {timeout_s}s waiting "
                               f"for {desc}")
        time.sleep(interval_s)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
