import os
import sys

# Tests must see the single real CPU device (the dry-run subprocess sets its
# own device count); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is an *optional* test dependency: in network-isolated containers
# it may be missing.  Install the stub (tests/_hypothesis_compat.py) before
# any test module does `from hypothesis import given, ...` so collection
# survives and property tests skip instead of erroring.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _hypothesis_compat

_HYPOTHESIS_STUBBED = _hypothesis_compat.install()

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,
    max_examples=20,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
