import os

# Tests must see the single real CPU device (the dry-run subprocess sets its
# own device count); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,
    max_examples=20,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
