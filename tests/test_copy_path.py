"""Unified copy engine + single-copy serving datapath.

Covers the CopyEngine itself (SG descriptors, work-queue FIFO, batched
doorbells, injection selection), the channel descriptor cache (hit/miss,
mid-stream invalidation), reserve-then-fill tx slots (including abort
sentinels), ControlChannel ChannelClosed consistency, and — the
acceptance assertion — the counted copies-per-request of the pipelined
serving path: exactly one payload memcpy server-side per request
(slot → batch buffer) and zero receive-side staging copies, read from the
process-wide engine counters rather than timed.
"""
import multiprocessing as mp
import threading
import time

import numpy as np
import pytest

from repro.core.copyengine import (
    CopyEngine,
    Descriptor,
    HybridPollStats,
    SGList,
    WouldBlock,
    get_engine,
)
from repro.core.dispatcher import RequestDispatcher
from repro.core.engine import EngineStats
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ipc import (
    ChannelClosed,
    ChannelStats,
    RemoteDispatcherClient,
    ServingFabric,
    ShmTransport,
    TransportSpec,
)

TIGHT = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0)
SMALL = TransportSpec(data_slots=4, data_slot_bytes=1 << 20,
                      ctrl_slots=4, ctrl_slot_bytes=4 << 10)


def _pair(spec=SMALL, policy=TIGHT):
    a = ShmTransport.create(spec=spec, policy=policy)
    b = ShmTransport.attach(a.name, policy=policy)
    return a, b


def _tag_delta(before: dict, after: dict) -> dict:
    return {k: after.get(k, 0) - before.get(k, 0)
            for k in set(before) | set(after)}


# ---------------------------------------------------------------------------
# copy engine: descriptors, work queues, doorbells, injection
# ---------------------------------------------------------------------------

def test_copyengine_sg_submission_and_completion():
    with CopyEngine(workers=2) as eng:
        src = np.arange(4096, dtype=np.int64)
        dst = np.zeros_like(src)
        sg = SGList()
        sg.add_array(src, dst)
        stats = HybridPollStats()
        job = eng.submit(Descriptor(sg=sg, nbytes=src.nbytes, tag="t"),
                         policy=TIGHT, stats=stats)
        assert job.wait(timeout_s=10) is None
        np.testing.assert_array_equal(dst, src)
        assert eng.stats.tagged["t"] == 1
        assert eng.stats.tagged_bytes["t"] == src.nbytes
        assert eng.stats.submitted == eng.stats.completed == 1


def test_copyengine_wq_fifo_order_and_unordered_keys():
    with CopyEngine(workers=3) as eng:
        order = []
        lock = threading.Lock()

        def make(i, delay):
            def complete(_sg):
                time.sleep(delay)
                with lock:
                    order.append(i)
            return Descriptor(complete=complete)

        # same wq: strictly FIFO even though the first item is slowest
        jobs = [eng.submit(make(0, 0.05), wq="q"),
                eng.submit(make(1, 0.0), wq="q"),
                eng.submit(make(2, 0.0), wq="q")]
        for j in jobs:
            j.wait(timeout_s=10)
        assert order == [0, 1, 2]

        # a slow descriptor on one key must not block another key
        t0 = time.perf_counter()
        slow = eng.submit(make(9, 0.25), wq="slow")
        fast = eng.submit(make(8, 0.0), wq="fast")
        fast.wait(timeout_s=10)
        assert time.perf_counter() - t0 < 0.2    # did not wait for "slow"
        slow.wait(timeout_s=10)


def test_copyengine_batched_doorbells():
    with CopyEngine(workers=1) as eng:
        gate = threading.Event()
        first = eng.submit(Descriptor(complete=lambda sg: gate.wait(5)),
                           wq="q")
        # these land behind the busy worker: no extra doorbell rings
        rest = [eng.submit(Descriptor(complete=lambda sg: None), wq="q")
                for _ in range(5)]
        gate.set()
        for j in [first] + rest:
            j.wait(timeout_s=10)
        assert eng.stats.submitted == 6
        assert eng.stats.doorbells == 1          # one ring served all six


def test_copyengine_injection_selects_temporal_vs_streaming():
    with CopyEngine(workers=1) as eng:
        big = np.ones(1 << 19, np.uint8)          # > streaming chunk
        for inject in (True, False):
            sg = SGList()
            sg.add(big, np.zeros(1 << 19, np.uint8))
            eng.run_sg(sg, injection=inject, tag="x")
        assert eng.stats.temporal == 1
        assert eng.stats.streaming == 1
        assert eng.stats.tagged["x"] == 2


def test_copyengine_error_contained_in_completion():
    with CopyEngine(workers=1) as eng:
        def boom():
            raise RuntimeError("no slot")
        bad = eng.submit(Descriptor(build=boom), wq="q")
        good = eng.submit(Descriptor(complete=lambda sg: 7), wq="q")
        with pytest.raises(RuntimeError, match="no slot"):
            bad.wait(timeout_s=10)
        assert good.wait(timeout_s=10) == 7      # queue survived the failure
        assert eng.stats.failed == 1


def test_copyengine_wouldblock_parks_instead_of_blocking():
    """A stalled queue (build raises WouldBlock) must not occupy a worker:
    with a SINGLE worker, another queue's work still completes while the
    stalled one retries, and the stalled job finishes once its resource
    frees — no head-of-line blocking across channels."""
    with CopyEngine(workers=1) as eng:
        ready = threading.Event()
        attempts = []

        def build():
            attempts.append(time.perf_counter())
            if not ready.is_set():
                raise WouldBlock(0.001)
            return SGList()

        stalled = eng.submit(Descriptor(build=build, complete=lambda sg: "s"),
                             wq="stalled")
        other = eng.submit(Descriptor(complete=lambda sg: "o"), wq="other")
        # the single worker serves "other" while "stalled" is parked
        assert other.wait(timeout_s=5) == "o"
        assert not stalled.done()
        assert len(attempts) >= 1
        ready.set()
        assert stalled.wait(timeout_s=5) == "s"
        assert eng.stats.parked >= 1


def test_offloaded_send_full_ring_does_not_block_other_channels():
    """Channel integration of the parking path: channel A's consumer stalls
    with async sends outstanding; channel B (same shared engine) still
    streams at full speed, and A completes once its consumer drains."""
    eng = CopyEngine(workers=1)
    policy = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                           mode=ExecutionMode.ASYNC)
    a_tx = ShmTransport.create(spec=SMALL, policy=policy)
    a_rx = ShmTransport.attach(a_tx.name, policy=policy)
    b_tx = ShmTransport.create(spec=SMALL, policy=policy)
    b_rx = ShmTransport.attach(b_tx.name, policy=policy)
    for t in (a_tx, a_rx, b_tx, b_rx):
        t.data._engine = eng
    try:
        payload = {"x": np.arange(8192, dtype=np.int64)}
        # stall channel A: fill every slot plus extras queued in the engine
        handles = [a_tx.send(payload, mode="async")
                   for _ in range(SMALL.data_slots + 2)]
        # channel B must make progress despite A's parked queue (1 worker!)
        for i in range(6):
            b_tx.send({"i": np.full((64,), i, np.int32)}, mode="async")
            tree, _ = b_rx.recv(timeout_s=10)
            assert int(tree["i"][0]) == i
        # drain A: its parked sends now complete in order
        for _ in handles:
            a_rx.recv(timeout_s=10)
        for h in handles:
            h.wait(timeout_s=10)
        assert eng.stats.parked >= 1
    finally:
        for t in (a_rx, a_tx, b_rx, b_tx):
            t.close()
        eng.close()


def test_lease_release_after_transport_reaped_is_safe():
    """Regression: releasing a RecvLease after its transport was closed
    (reaped connection with requests still queued) must be a no-op, not a
    TypeError that would kill the dispatcher's serve loop."""
    a, b = _pair()
    a.send({"x": np.arange(1024, dtype=np.int32)}, mode="sync")
    lease = b.recv(copy=False)
    assert lease.held
    b.close()          # teardown while the lease is still held
    a.close()
    lease.release()    # must not raise
    # and the dispatcher funnel survives a hostile lease too
    class Hostile:
        held = True
        def release(self):
            raise RuntimeError("transport gone")
    from repro.core.dispatcher import Request
    req = Request(0, "op", None, ExecutionMode.SYNC, lease=Hostile())
    req._release_lease()               # swallowed, not fatal


def test_shared_stats_dataclass_deduplicates_counters():
    # the satellite: Engine/Channel stats share one hybrid-polling base
    assert issubclass(EngineStats, HybridPollStats)
    assert issubclass(ChannelStats, HybridPollStats)
    snap = ChannelStats().snapshot()
    for field in ("inline", "offloaded", "polls", "deferred_sleep_s",
                  "blocked_wait_s"):
        assert field in snap and field in EngineStats().snapshot()


# ---------------------------------------------------------------------------
# descriptor cache: steady-state sends skip descriptor pickling
# ---------------------------------------------------------------------------

def test_descriptor_cache_hits_and_midstream_invalidation():
    a, b = _pair()
    try:
        tree_a = {"x": np.arange(2048, dtype=np.int64),
                  "y": (np.ones((3, 5), np.float32),)}
        tree_b = {"x": np.arange(512, dtype=np.int64),     # shape changed
                  "y": (np.ones((3, 5), np.float32),)}
        tree_c = {"x": np.arange(2048, dtype=np.int64)}    # structure changed
        seq = [tree_a, tree_a, tree_a, tree_b, tree_a, tree_c, tree_b]
        got = []
        for t in seq:                      # interleave: 4-slot ring
            a.send(t, mode="sync")
            got.append(b.recv(timeout_s=10)[0])
        for sent, (rec) in zip(seq, got):
            assert sent["x"].tobytes() == rec["x"].tobytes()
            if "y" in sent:
                assert sent["y"][0].tobytes() == rec["y"][0].tobytes()
        # 3 distinct structures -> 3 misses; everything else hits
        assert a.data.stats.descr_cache_misses == 3
        assert a.data.stats.descr_cache_hits == len(seq) - 3
    finally:
        b.close(); a.close()


def test_descriptor_cache_dtype_change_invalidates():
    a, b = _pair()
    try:
        a.send({"x": np.arange(64, dtype=np.int64)}, mode="sync")
        a.send({"x": np.arange(64, dtype=np.int32)}, mode="sync")
        t1, _ = b.recv(timeout_s=10)
        t2, _ = b.recv(timeout_s=10)
        assert t1["x"].dtype == np.int64 and t2["x"].dtype == np.int32
        assert a.data.stats.descr_cache_misses == 2
    finally:
        b.close(); a.close()


# ---------------------------------------------------------------------------
# reserve-then-fill tx slots
# ---------------------------------------------------------------------------

def test_reserve_then_fill_roundtrip_and_meta_cache():
    a, b = _pair()
    try:
        payload = np.arange(4096, dtype=np.float32)
        for i in range(3):
            slot = a.data.reserve({"result": payload},
                                  header={"job_id": i})
            np.copyto(slot.tree["result"], payload * i)
            slot.publish()
        for i in range(3):
            tree, header = b.recv(timeout_s=10)
            assert header["job_id"] == i
            np.testing.assert_array_equal(tree["result"], payload * i)
        # same structure every time: one descriptor pickle total
        assert a.data.stats.descr_cache_misses == 1
        assert a.data.stats.descr_cache_hits == 2
    finally:
        b.close(); a.close()


def test_reserve_abort_sentinel_is_skipped_by_receiver():
    a, b = _pair()
    try:
        slot = a.data.reserve({"x": np.zeros(16, np.float32)})
        slot.abort()                       # unfillable: give the slot back
        a.send({"x": np.full(16, 7.0, np.float32)}, mode="sync")
        tree, _ = b.recv(timeout_s=10)     # sentinel invisible to the caller
        np.testing.assert_array_equal(tree["x"],
                                      np.full(16, 7.0, np.float32))
        assert b.data.try_recv() is None
    finally:
        b.close(); a.close()


def test_reserve_context_manager_aborts_on_exception():
    a, b = _pair()
    try:
        with pytest.raises(RuntimeError, match="fill failed"):
            with a.data.reserve({"x": np.zeros(8, np.float32)}) as slot:
                raise RuntimeError("fill failed")
        assert slot.tree is None
        a.send({"x": np.ones(8, np.float32)}, mode="sync")
        tree, _ = b.recv(timeout_s=10)
        np.testing.assert_array_equal(tree["x"], np.ones(8, np.float32))
    finally:
        b.close(); a.close()


# ---------------------------------------------------------------------------
# control channel: ChannelClosed surfaces consistently
# ---------------------------------------------------------------------------

def test_control_try_recv_raises_after_peer_close_and_drain():
    a, b = _pair()
    try:
        a.send_msg({"cmd": "last"})
        a.announce_close()
        # drain-first: the in-flight message is still delivered...
        assert b.ctrl.recv_msg(timeout_s=5) == {"cmd": "last"}
        # ...then the drained ring surfaces the shutdown
        with pytest.raises(ChannelClosed):
            b.ctrl.try_recv_msg()
    finally:
        b.close(); a.close()


def test_control_blocked_recv_unblocks_on_shutdown():
    """Regression: a thread blocked in recv_msg while the peer shuts down
    must raise ChannelClosed promptly, not wait out its full timeout."""
    a, b = _pair()
    try:
        out = {}

        def blocked():
            t0 = time.perf_counter()
            try:
                b.ctrl.recv_msg(timeout_s=30.0)
            except ChannelClosed:
                out["elapsed"] = time.perf_counter() - t0

        t = threading.Thread(target=blocked)
        t.start()
        time.sleep(0.1)                    # let it enter the blocking wait
        a.announce_close()
        t.join(timeout=10)
        assert not t.is_alive()
        assert out["elapsed"] < 5.0        # nowhere near the 30s timeout
    finally:
        b.close(); a.close()


# ---------------------------------------------------------------------------
# dispatcher batch formation: gather into pooled buffers, lease ordering
# ---------------------------------------------------------------------------

class _StubLease:
    held = True

    def __init__(self):
        self.released = False
        self.release_t = None

    def release(self):
        self.released = True
        self.release_t = time.perf_counter()


def test_leases_released_after_gather_before_handler():
    policy = OffloadPolicy(offload_threshold_bytes=1, max_batch=4)
    leases = [_StubLease() for _ in range(3)]
    seen = {}
    done = threading.Event()
    results = {}

    def batch_fn(rows):
        # the gather already happened: every lease must be released and the
        # rows must be *gathered* copies, not the original client views
        seen["released_at_handler"] = [l.released for l in leases]
        seen["rows"] = [r.copy() for r in rows]
        return [r * 2 for r in rows]

    def cb(jid, out):
        results[jid] = out
        if len(results) == 3:
            done.set()

    with RequestDispatcher(policy, max_batch_wait_s=0.2) as d:
        d.register_handler("op", lambda x: x * 2, batch_fn=batch_fn)
        sent = [np.full((256,), i, np.float32) for i in range(3)]
        jids = [d.submit("op", a, mode="pipelined", on_complete=cb,
                         lease=l) for a, l in zip(sent, leases)]
        assert done.wait(timeout=10)
    assert all(seen["released_at_handler"])
    for a, r in zip(sent, seen["rows"]):
        np.testing.assert_array_equal(a, r)
    assert not any(np.may_share_memory(a, r)
                   for a, r in zip(sent, seen["rows"]))
    for a, jid in zip(sent, jids):
        np.testing.assert_array_equal(results[jid], a * 2)
    assert d.stats.gathered_requests == 3
    assert d.stats.gathers >= 1


def test_gather_pads_heterogeneous_lengths():
    policy = OffloadPolicy(offload_threshold_bytes=1, max_batch=4)
    got = {}
    done = threading.Event()

    def slab_fn(slab, shapes):
        got["slab"] = slab.copy()
        got["shapes"] = shapes
        return [slab[i, :shapes[i][0]] * 1 for i in range(len(shapes))]

    results = {}

    def cb(jid, out):
        results[jid] = out
        if len(results) == 2:
            done.set()

    with RequestDispatcher(policy, max_batch_wait_s=0.2) as d:
        d.register_handler("op", lambda x: x, slab_fn=slab_fn)
        a = np.arange(8, dtype=np.int64)
        b = np.arange(3, dtype=np.int64) + 100
        d.submit("op", a, mode="pipelined", on_complete=cb)
        d.submit("op", b, mode="pipelined", on_complete=cb)
        assert done.wait(timeout=10)
    slab = got["slab"]
    assert slab.shape == (2, 8)
    np.testing.assert_array_equal(slab[0], a)
    np.testing.assert_array_equal(slab[1, :3], b)
    np.testing.assert_array_equal(slab[1, 3:], 0)     # zero padding


# ---------------------------------------------------------------------------
# the acceptance assertion: counted copies per request, end to end
# ---------------------------------------------------------------------------

N_REQ = 6
PAYLOAD_ELEMS = 64 << 10          # 256 KB float32 rows


def _counted_client_entry(name: str, n: int) -> None:
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    sent = [np.full((PAYLOAD_ELEMS,), i, np.float32) for i in range(n)]
    jids = [client.request("double", a, mode="pipelined") for a in sent]
    for a, jid in zip(sent, jids):
        out = client.query(jid, timeout=60)
        assert out.tobytes() == (a * 2).tobytes()      # byte-identical reply
    client.close()


def test_pipelined_serving_single_copy_per_request_counted():
    """The tentpole guarantee, verified by engine counters (not timing):
    the pipelined serving path performs exactly ONE server-side payload
    memcpy per request (ring slot → pooled batch buffer via the gather)
    and ZERO receive-side staging copies; replies are packed straight
    into the tx slot (one fill each)."""
    eng = get_engine()
    policy = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                           max_batch=4)
    d = RequestDispatcher(policy, max_batch_wait_s=0.05)
    d.register_handler("double", lambda x: x * 2,
                       batch_fn=lambda xs: [x * 2 for x in xs])
    before = eng.tagged_snapshot()
    with ServingFabric(d, spec=SMALL, policy=policy,
                       own_dispatcher=True).start() as fab:
        proc = mp.get_context("spawn").Process(
            target=_counted_client_entry, args=(fab.name, N_REQ), daemon=True)
        proc.start()
        proc.join(timeout=120)
        assert proc.exitcode == 0
        assert fab.reactor.stats.zero_copy_recvs >= N_REQ
        assert d.stats.gathered_requests == N_REQ
    after = eng.tagged_snapshot()
    copies = _tag_delta(before["copies"], after["copies"])
    nbytes = _tag_delta(before["bytes"], after["bytes"])
    # exactly one payload memcpy per request: the batch-formation gather
    assert copies.get("gather", 0) == N_REQ
    assert nbytes.get("gather", 0) == N_REQ * PAYLOAD_ELEMS * 4
    # zero receive-side staging copies on the serving path
    assert copies.get("recv_copy", 0) == 0
    # each reply packed straight into the destination slot (one fill)
    assert copies.get("reply_fill", 0) == N_REQ
    # nothing went through the legacy tree-staging send path server-side
    assert copies.get("send", 0) == 0


def _zc_batching_client_entry(name: str, marker: int, n: int) -> None:
    client = RemoteDispatcherClient.connect(name, policy=TIGHT, timeout_s=60)
    while int(client.request("gate", np.zeros(1, np.float32),
                             mode="sync")[0]) == 0:
        time.sleep(0.002)
    sent = [np.full((2048,), marker * 1000 + i, np.float32)
            for i in range(n)]
    jids = [client.request("double", a, mode="pipelined") for a in sent]
    for a, jid in zip(sent, jids):
        out = client.query(jid, timeout=60)
        assert out.tobytes() == (a * 2).tobytes()
    client.close()


def test_cross_client_batching_byte_identical_with_leases():
    """Cross-client batch formation over copy=False leases: requests from
    two real processes gathered into one batch buffer, replies
    byte-identical and demuxed to the right client."""
    gate = [0.0]
    seen_batches: list[set] = []

    def batch_double(xs):
        seen_batches.append({int(x[0]) // 1000 for x in xs})
        time.sleep(0.002)
        return [x * 2 for x in xs]

    n = 8
    policy = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                           max_batch=2 * n)
    d = RequestDispatcher(policy, max_batch_wait_s=0.3)
    d.register_handler("gate", lambda x: np.float32(gate[0]) + x)
    d.register_handler("double", lambda x: x * 2, batch_fn=batch_double)
    with ServingFabric(d, spec=SMALL, policy=TIGHT,
                       own_dispatcher=True).start() as fab:
        assert fab.reactor.zero_copy                  # leases are the default
        ctx = mp.get_context("spawn")
        procs = [ctx.Process(target=_zc_batching_client_entry,
                             args=(fab.name, m, n), daemon=True)
                 for m in (1, 2)]
        for p in procs:
            p.start()
        deadline = time.perf_counter() + 120
        while fab.listener.accepted < 2:
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        gate[0] = 1.0
        for p in procs:
            p.join(timeout=120)
            assert p.exitcode == 0
        assert fab.reactor.stats.zero_copy_recvs >= 2 * n
        assert any(len(s) > 1 for s in seen_batches), seen_batches
        assert d.stats.gathered_requests >= 2 * n
