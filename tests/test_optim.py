"""AdamW from scratch: convergence, clipping, schedules, dtype handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                            warmup_steps=1, total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_limits_norm():
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    big = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(cfg, params, big, state)
    assert float(metrics["grad_norm"]) > 1.0          # reported pre-clip


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.array(0.0)))
    lr_w = float(adamw.schedule(cfg, jnp.array(10.0)))
    lr_end = float(adamw.schedule(cfg, jnp.array(100.0)))
    assert lr0 < 0.05
    assert abs(lr_w - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-5


def test_weight_decay_shrinks_params():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1,
                            total_steps=10)
    params = {"w": jnp.full(3, 10.0)}
    state = adamw.init(params)
    params2, _, _ = adamw.update(cfg, params, {"w": jnp.zeros(3)}, state)
    assert float(params2["w"][0]) < 10.0


def test_bf16_params_fp32_moments():
    cfg = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adamw.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    params2, state2, _ = adamw.update(cfg, params, {"w": jnp.ones(8, jnp.bfloat16)},
                                      state)
    assert params2["w"].dtype == jnp.bfloat16
    assert state2["v"]["w"].dtype == jnp.float32


def test_grad_sync_dtype_cast():
    cfg = adamw.AdamWConfig(grad_sync_dtype="bfloat16", warmup_steps=1,
                            total_steps=10, grad_clip=0.0)
    params = {"w": jnp.ones(4)}
    state = adamw.init(params)
    p2, _, _ = adamw.update(cfg, params, {"w": jnp.full(4, 1e-9)}, state)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


@given(st.floats(1e-5, 1e-1), st.integers(1, 5))
def test_update_is_deterministic(lr, seed):
    cfg = adamw.AdamWConfig(lr=lr, warmup_steps=1, total_steps=10)
    key = jax.random.key(seed)
    params = {"w": jax.random.normal(key, (6,))}
    grads = {"w": jax.random.normal(jax.random.key(seed + 1), (6,))}
    s0 = adamw.init(params)
    a, sa, _ = adamw.update(cfg, params, grads, s0)
    b, sb, _ = adamw.update(cfg, params, grads, adamw.init(params))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    assert int(sa["step"]) == int(sb["step"]) == 1
