"""xLSTM invariants: parallel == chunked == recurrent mLSTM; sLSTM state
continuity across segment boundaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.base import ModelConfig
from repro.models import xlstm


def mk_cfg():
    return ModelConfig(name="t", family="ssm", num_layers=2, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                       slstm_every=2, dtype="float32", param_dtype="float32",
                       norm_type="layernorm")


def rand_qkvif(key, b=2, s=12, h=2, hd=8):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    i = jax.random.normal(ks[3], (b, s, h))
    f = jax.random.normal(ks[4], (b, s, h)) + 2.0
    return q, k, v, i, f


def recurrent_rollout(q, k, v, i, f):
    b, s, h, hd = q.shape
    C = jnp.zeros((b, h, hd, hd))
    n = jnp.zeros((b, h, hd))
    m = jnp.full((b, h), -1e30)
    ys = []
    for t in range(s):
        (C, n, m), y = xlstm.mlstm_recurrent_step(
            (C, n, m), q[:, t], k[:, t], v[:, t], i[:, t], f[:, t])
        ys.append(y)
    return jnp.stack(ys, 1), (C, n, m)


def test_parallel_equals_recurrent(rng_key):
    q, k, v, i, f = rand_qkvif(rng_key)
    y_par = xlstm.mlstm_parallel(q, k, v, i, f)
    y_rec, _ = recurrent_rollout(q, k, v, i, f)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=1e-4, atol=1e-4)


@given(st.sampled_from([2, 3, 4, 6, 12]))
def test_chunked_equals_parallel(chunk):
    q, k, v, i, f = rand_qkvif(jax.random.key(chunk))
    y_par = xlstm.mlstm_parallel(q, k, v, i, f)
    y_chk, _ = xlstm.mlstm_chunked(q, k, v, i, f, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_par),
                               rtol=1e-4, atol=1e-4)


def test_chunked_final_state_matches_recurrent(rng_key):
    q, k, v, i, f = rand_qkvif(rng_key, s=10)
    _, (C_r, n_r, m_r) = recurrent_rollout(q, k, v, i, f)
    _, (C_c, n_c, m_c) = xlstm.mlstm_chunked(q, k, v, i, f, chunk=4)
    np.testing.assert_allclose(np.asarray(C_c), np.asarray(C_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n_c), np.asarray(n_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r), rtol=1e-4, atol=1e-4)


def test_mlstm_block_prefill_then_decode(rng_key):
    cfg = mk_cfg()
    params = xlstm.mlstm_init(rng_key, cfg)
    b, s = 2, 9
    x = 0.3 * jax.random.normal(jax.random.key(2), (b, s + 1, cfg.d_model))
    full = xlstm.mlstm_block_apply(params, x, cfg)
    out, state = xlstm.mlstm_block_prefill(params, x[:, :s], cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :s]),
                               rtol=1e-4, atol=1e-4)
    step, _ = xlstm.mlstm_block_decode(params, x[:, s:], cfg, state)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, s]),
                               rtol=1e-4, atol=1e-4)


def test_slstm_segment_continuity(rng_key):
    """Running [x1;x2] at once == running x1 then x2 with carried state."""
    cfg = mk_cfg()
    params = xlstm.slstm_init(rng_key, cfg)
    b, s1, s2 = 2, 6, 5
    x = 0.3 * jax.random.normal(jax.random.key(4), (b, s1 + s2, cfg.d_model))
    full, _ = xlstm.slstm_block_apply(params, x, cfg)
    out1, state = xlstm.slstm_block_apply(params, x[:, :s1], cfg)
    out2, _ = xlstm.slstm_block_apply(params, x[:, s1:], cfg, state)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(full[:, :s1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(full[:, s1:]),
                               rtol=1e-4, atol=1e-4)


def test_stabilizer_prevents_overflow():
    """Large positive input gates must not overflow the exp-gating."""
    q, k, v, i, f = rand_qkvif(jax.random.key(9))
    i = i + 80.0                      # would overflow exp() unstabilized
    y = xlstm.mlstm_parallel(q, k, v, i, f)
    assert bool(jnp.all(jnp.isfinite(y)))
    y2, st_ = xlstm.mlstm_chunked(q, k, v, i, f, chunk=4)
    assert bool(jnp.all(jnp.isfinite(y2)))
