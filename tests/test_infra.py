"""Checkpoint, fault tolerance, data pipeline, HLO/jaxpr cost analysis."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource
from repro.ft import Heartbeat, RestartManager, StepTimer, StragglerMonitor
from repro.launch import hlo as hlo_mod
from repro.launch import jcost


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _state(key):
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros(4)},
            "step": jnp.array(3)}


def test_checkpoint_roundtrip(tmp_path, rng_key):
    cm = CheckpointManager(str(tmp_path))
    state = _state(rng_key)
    cm.save(5, state, {"note": "hi"})
    restored, extra = cm.restore(5, state)
    assert extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path, rng_key):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = _state(rng_key)
    for step in (1, 2, 3, 4):
        cm.save_async(step, state)
    cm.wait()
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_elastic_dtype_cast(tmp_path, rng_key):
    cm = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4, 4), jnp.float32)}
    cm.save(1, state)
    like = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    restored, _ = cm.restore(1, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_restart_manager_resume(tmp_path, rng_key):
    cm = CheckpointManager(str(tmp_path))
    rm = RestartManager(cm, save_every=2)
    state = _state(rng_key)
    rm.maybe_save(2, state, {"data": {"seed": 0, "step": 2}})
    cm.wait()
    restored, extra, step = rm.resume_or_init(lambda: state)
    assert step == 2 and extra["data"]["step"] == 2


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_liveness(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = Heartbeat(path, host_id=3)
    hb.beat(step=7)
    assert Heartbeat.is_alive(path, timeout_s=5.0)
    with open(path) as f:
        assert json.load(f)["host"] == 3
    assert not Heartbeat.is_alive(str(tmp_path / "none.json"), 5.0)


def test_straggler_monitor_threshold():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for _ in range(20):
        mon.record_step(1.0)
    assert not mon.events
    mon.record_step(5.0)
    mon.record_step(5.0)
    assert len(mon.events) == 1
    assert mon.events[0]["ratio"] > 2.0


def test_step_timer_stats():
    t = StepTimer()
    for x in [1.0, 2.0, 3.0]:
        t.record(x)
    assert t.median() == 2.0
    assert t.p95() >= 2.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_source_determinism_and_restore():
    cfg = get_smoke_config("granite-8b")
    shape = ShapeConfig("t", "train", 16, 2)
    s1 = SyntheticLMSource(cfg, shape, seed=5)
    a = next(s1)
    b = next(s1)
    s2 = SyntheticLMSource(cfg, shape, seed=5)
    s2.restore({"seed": 5, "step": 1})
    b2 = next(s2)
    np.testing.assert_array_equal(a["tokens"].shape, (2, 16))
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


@pytest.mark.parametrize("mode", ["sync", "async", "pipelined"])
def test_pipeline_modes_deliver_in_order(mode):
    cfg = get_smoke_config("granite-8b")
    shape = ShapeConfig("t", "train", 16, 2)
    src = SyntheticLMSource(cfg, shape, seed=1)
    ref_batches = [next(SyntheticLMSource(cfg, shape, seed=1))["tokens"]
                   for _ in range(1)]
    pipe = InputPipeline(src, OffloadPolicy(mode=ExecutionMode(mode),
                                            offload_threshold_bytes=1))
    got = next(pipe)
    np.testing.assert_array_equal(np.asarray(got["tokens"]), ref_batches[0])
    pipe.close()


def test_pipeline_checkpoint_replays_prefetch():
    cfg = get_smoke_config("granite-8b")
    shape = ShapeConfig("t", "train", 16, 2)
    pol = OffloadPolicy(mode=ExecutionMode.PIPELINED, pipeline_depth=2,
                        offload_threshold_bytes=1)
    pipe = InputPipeline(SyntheticLMSource(cfg, shape, seed=3), pol)
    first = np.asarray(next(pipe)["tokens"])
    state = pipe.state()
    second = np.asarray(next(pipe)["tokens"])
    # restore: the same "second" batch must come out again
    pipe.restore(state)
    second_replay = np.asarray(next(pipe)["tokens"])
    np.testing.assert_array_equal(second, second_replay)
    pipe.close()


# ---------------------------------------------------------------------------
# jaxpr cost model
# ---------------------------------------------------------------------------

def test_jcost_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    est = jcost.estimate_fn(lambda x, y: x @ y, a, b)
    assert est.flops == 2 * 64 * 32 * 16


def test_jcost_scan_multiplies_by_length():
    x = jax.ShapeDtypeStruct((8, 16, 16), jnp.float32)

    def f(xs):
        def body(c, m):
            return c @ m, None
        init = jnp.eye(16)
        out, _ = jax.lax.scan(body, init, xs)
        return out

    est = jcost.estimate_fn(f, x)
    assert est.flops >= 8 * 2 * 16 * 16 * 16
    assert est.depth_trips.get(1, 0) == 8


def test_jcost_grad_counts_backward():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    fwd = jcost.estimate_fn(lambda a: jnp.sum(a @ a), x)
    bwd = jcost.estimate_fn(jax.grad(lambda a: jnp.sum(a @ a)), x)
    assert bwd.flops >= 2 * fwd.flops * 0.9


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SYNTH_HLO = """
HloModule test

%body (arg: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %arg = (s32[], f32[64,128]) parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%gte), channel_id=1, to_apply=%add
  ROOT %t = (s32[], f32[64,128]) tuple(%iter, %ar)
}

%cond (arg2: (s32[], f32[64,128])) -> pred[] {
  %arg2 = (s32[], f32[64,128]) parameter(0)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[64,128]) -> f32[64,128] {
  %p = f32[64,128] parameter(0)
  %ag = f32[128,128]{1,0} all-gather(%p), channel_id=2, dimensions={0}
  %w = (s32[], f32[64,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[64,128] get-tuple-element(%w), index=1
}
"""


def test_collective_stats_trip_scaling():
    stats = hlo_mod.collective_stats(SYNTH_HLO)
    # all-gather at entry: 128*128*4 bytes once
    assert stats.bytes_by_op["all-gather"] == 128 * 128 * 4
    # all-reduce inside the while: 64*128*4 * 12 trips
    assert stats.bytes_by_op["all-reduce"] == 64 * 128 * 4 * 12
    assert stats.count_by_op["all-reduce"] == 12


# ---------------------------------------------------------------------------
# benchmark gate checker (benchmarks.run --check)
# ---------------------------------------------------------------------------

def _snapshot(tmp_path, rows):
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        json.dump({"schema": 1, "rows": [
            {"bench": name, "us_per_call": 1.0, "derived": derived}
            for name, derived in rows]}, f)
    return path


def test_bench_check_passes_within_limits(tmp_path):
    from benchmarks import run as bench_run
    path = _snapshot(tmp_path, [("fig2/a", "1GB/s;copies/req=1.00"),
                                ("fig15/acct", "n=10;shed_drift=0")])
    rows = ["fig2/a,5.0,1GB/s;copies/req=1.00",
            "fig15/acct,0.0,n=12;shed_drift=0"]
    assert bench_run._check(path, rows) == []


def test_bench_check_flags_regression(tmp_path):
    from benchmarks import run as bench_run
    path = _snapshot(tmp_path, [("fig2/a", "copies/req=1.00")])
    problems = bench_run._check(path, ["fig2/a,5.0,copies/req=3.00"])
    assert len(problems) == 1 and "copies/req=3" in problems[0]


def test_bench_check_disappeared_metric_is_not_vacuous(tmp_path):
    """A produced row that stops emitting a gated token must fail loudly:
    the gate turning itself off silently is the bug this guards against."""
    from benchmarks import run as bench_run
    path = _snapshot(tmp_path, [("fig2/a", "copies/req=1.00"),
                                ("fig15/acct", "shed_drift=0")])
    rows = ["fig2/a,5.0,812MB/s",              # token gone from derived
            "fig15/acct,0.0,shed_drift=0"]     # keeps compared > 0
    problems = bench_run._check(path, rows)
    assert len(problems) == 1
    assert "disappeared" in problems[0] and "copies/req" in problems[0]


def test_bench_check_skips_rows_not_produced(tmp_path):
    """--only subsets simply skip absent baseline rows — no failure."""
    from benchmarks import run as bench_run
    path = _snapshot(tmp_path, [("fig2/a", "copies/req=1.00"),
                                ("fig6/b", "pickle/send=0.00")])
    assert bench_run._check(path, ["fig2/a,5.0,copies/req=1.00"]) == []


def test_bench_check_refuses_zero_overlap(tmp_path):
    from benchmarks import run as bench_run
    path = _snapshot(tmp_path, [("fig2/a", "copies/req=1.00")])
    problems = bench_run._check(path, ["fig9/new,1.0,no counted tokens"])
    assert len(problems) == 1 and "vacuous" in problems[0]


def test_roofline_dominant_term():
    rl = hlo_mod.Roofline(compute_s=1.0, memory_s=2.0, collective_s=0.5,
                          flops_per_device=1, bytes_per_device=1,
                          collective_bytes_per_device=1, chips=256,
                          model_flops=197e12 * 256,
                          ideal_bytes_per_device=0)
    assert rl.dominant == "memory"
    assert abs(rl.roofline_fraction - 0.5) < 1e-9
