"""Hardware-witness plane: perf-counter probing, accounting, degradation.

Exercises every tier of :mod:`repro.obs.hwcounters`'s graceful-degradation
ladder on whatever host runs the suite: the capability probe, delta
accounting under a busy loop with a known floor, the counted
zero-records-when-disabled contract, tier forcing (degrade-only), and
counter records joining the cross-process trace export on the same rings.

Tests that need a live counter tier skip honestly on hosts where even
``getrusage`` misbehaves — the probe itself is still asserted everywhere.
"""
import os
import time

import numpy as np
import pytest

from repro.obs import hwcounters as hw
from repro.obs import trace as obs_trace


@pytest.fixture
def profiled():
    """Enable phase profiling for a test; restore a clean slate after."""
    tier = hw.enable()
    try:
        yield tier
    finally:
        hw.disable()
        hw.reset()
        hw.probe(refresh=True)


@pytest.fixture
def clean_env():
    """Scrub the hwprof env handshake before and after a test."""
    saved = {k: os.environ.pop(k, None) for k in (hw.ENV_FLAG, hw.ENV_TIER)}
    hw.probe(refresh=True)
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        hw.disable()
        hw.reset()
        hw.probe(refresh=True)


def _busy(ms: float = 20.0) -> float:
    """Burn cpu for ~ms of wall clock; returns a sink value."""
    deadline = time.perf_counter() + ms / 1e3
    acc = 0.0
    x = np.arange(4096, dtype=np.float64)
    while time.perf_counter() < deadline:
        acc += float(np.sum(x * 1.0000001))
    return acc


# ---------------------------------------------------------------------------
# capability probe
# ---------------------------------------------------------------------------

def test_probe_reports_a_valid_tier():
    cap = hw.probe(refresh=True)
    assert cap.tier in hw.TIERS
    d = cap.to_dict()
    assert d["tier"] == cap.tier
    assert set(d) >= {"tier", "paranoid", "events", "errors"}
    # events listed must be real counter names
    known = {name for name, *_ in hw.EVENTS} | {"sched_wait_ns"}
    assert set(d["events"]) <= known


def test_probe_is_cached_until_refresh():
    a = hw.probe()
    b = hw.probe()
    assert a is b
    c = hw.probe(refresh=True)
    assert c.tier == a.tier


def test_tier_forcing_is_degrade_only(clean_env):
    base = hw.probe(refresh=True).tier
    os.environ[hw.ENV_TIER] = "perf-hw"
    forced = hw.probe(refresh=True)
    # cannot conjure a PMU: the forced tier never exceeds the real one
    order = {t: i for i, t in enumerate(hw.TIERS)}
    assert order[forced.tier] >= order[base]


def test_forced_rusage_tier_downgrades(clean_env):
    os.environ[hw.ENV_TIER] = "rusage"
    cap = hw.probe(refresh=True)
    assert cap.tier in ("rusage", "none")


def test_forced_none_tier(clean_env):
    os.environ[hw.ENV_TIER] = "none"
    cap = hw.probe(refresh=True)
    assert cap.tier == "none"


def test_unknown_forced_tier_raises(clean_env):
    with pytest.raises(ValueError):
        hw.enable(tier="quantum")


# ---------------------------------------------------------------------------
# the counted zero-records-when-disabled contract
# ---------------------------------------------------------------------------

def test_disabled_profiling_accounts_exactly_zero_scopes():
    assert not hw.PROF.enabled
    hw.reset()
    assert hw.begin() is None
    with hw.CounterScope("handler", nbytes=123):
        _busy(1)
    assert hw.scope_count() == 0
    assert hw.phase_totals() == {}
    snap = hw.snapshot()
    assert snap["enabled"] == 0 and snap["scopes"] == 0


# ---------------------------------------------------------------------------
# delta accounting per tier
# ---------------------------------------------------------------------------

def test_scope_accumulates_counts_bytes_and_wall(profiled):
    hw.reset()
    for _ in range(3):
        with hw.CounterScope("handler", nbytes=1000):
            _busy(5)
    totals = hw.phase_totals()
    assert hw.scope_count() == 3
    acc = totals["handler"]
    assert acc["count"] == 3
    assert acc["bytes"] == 3000
    assert acc["wall_ns"] >= 3 * 4e6   # three ~5ms busy sections


@pytest.mark.skipif(hw.probe(refresh=True).tier == "none",
                    reason="no counter tier on this host")
def test_busy_loop_has_cpu_floor(profiled):
    """~40ms of pure spin must account ≥10ms of cpu on any live tier;
    on perf-hw the same scope must retire a nontrivial instruction
    floor (a 40ms spin is >1e6 instructions on any real core)."""
    hw.reset()
    with hw.CounterScope("handler"):
        _busy(40)
    acc = hw.phase_totals()["handler"]
    clk = acc.get("task_clock_ns", 0)
    assert clk >= 10e6, f"busy loop accounted only {clk}ns cpu"
    if profiled == "perf-hw":
        assert acc.get("instructions", 0) > 1_000_000


@pytest.mark.skipif(hw.probe(refresh=True).tier == "none",
                    reason="no counter tier on this host")
def test_meter_accumulates_across_reentries():
    m = hw.Meter()
    try:
        assert m.tier in hw.TIERS and m.tier != "none"
        for _ in range(4):
            with m:
                _busy(5)
        assert m.entries == 4
        assert m.totals["wall_ns"] >= 4 * 4e6
        assert m.totals.get("task_clock_ns", 0) >= 5e6
    finally:
        m.close()


def test_meter_on_forced_none_tier_reads_nothing(clean_env):
    os.environ[hw.ENV_TIER] = "none"
    hw.probe(refresh=True)
    m = hw.Meter()
    try:
        assert m.tier == "none"
        with m:
            _busy(2)
        assert m.entries == 1
        # wall clock still accumulates; no counter keys appear
        assert m.totals["wall_ns"] > 0
        assert set(m.totals) == {"wall_ns"}
    finally:
        m.close()


def test_forced_none_tier_counts_scopes_as_unavailable(clean_env):
    assert hw.enable(tier="none") == "none"
    hw.reset()
    with hw.CounterScope("publish", nbytes=64):
        _busy(2)
    snap = hw.snapshot()
    # the scope is *counted* (never silent) even though nothing was read
    assert snap["scopes"] == 1
    assert snap["unavailable"] == 1
    assert snap["phases"]["publish"]["count"] == 1
    assert snap["phases"]["publish"]["wall_ns"] > 0


def test_account_wall_is_wall_clock_only(profiled):
    hw.reset()
    t0 = time.perf_counter_ns()
    time.sleep(0.01)
    hw.account_wall("lease_hold", t0, nbytes=256)
    acc = hw.phase_totals()["lease_hold"]
    assert acc["count"] == 1 and acc["bytes"] == 256
    assert acc["wall_ns"] >= 8e6
    assert "task_clock_ns" not in acc


def test_snapshot_derives_per_byte_ratios(profiled):
    hw.reset()
    with hw.CounterScope("sg_gather", nbytes=1 << 20):
        _busy(10)
    phases = hw.snapshot()["phases"]
    acc = phases["sg_gather"]
    if acc.get("instructions"):
        assert acc["insn_per_byte"] == round(acc["instructions"] / (1 << 20), 4)
    if acc.get("llc_misses"):
        assert "llc_miss_per_byte" in acc


# ---------------------------------------------------------------------------
# env handshake (child-process inheritance)
# ---------------------------------------------------------------------------

def test_maybe_enable_from_env_roundtrip(clean_env):
    assert not hw.maybe_enable_from_env()      # flag unset → stays off
    os.environ[hw.ENV_FLAG] = "1"
    assert hw.maybe_enable_from_env()
    assert hw.PROF.enabled


# ---------------------------------------------------------------------------
# counter records join the trace rings
# ---------------------------------------------------------------------------

@pytest.mark.skipif(hw.probe(refresh=True).tier == "none",
                    reason="no counter tier on this host")
def test_counter_records_join_trace_export(clean_env):
    session = obs_trace.enable(capacity=1 << 12)
    hw.enable()
    hw.reset()
    try:
        with hw.CounterScope("handler", nbytes=512, rid=77):
            _busy(10)
        view = obs_trace.collect(session)
        ctr_kinds = [k for k in hw._trace.CTR_KINDS.values()
                     if len(view.records_of(k))]
        assert ctr_kinds, "no counter records landed on the trace rings"
        # every counter record carries the rid and the phase kind
        for kind in ctr_kinds:
            for rec in view.records_of(kind):
                assert int(rec["rid"]) == 77
                assert int(rec["arg"]) == hw.PHASES["handler"]
        # the reducer folds them back to per-phase sums
        folded = hw.counters_from_view(view)
        assert "handler" in folded
        assert any(v > 0 for v in folded["handler"].values())
        # counter records never pollute phase-span aggregation
        assert not any(name.startswith("ctr.")
                       for name in view.phase_totals())
    finally:
        hw.disable()
        hw.reset()
        obs_trace.collect(session, unlink=True)
        obs_trace.disable(unlink=True)
        hw.probe(refresh=True)


def test_no_counter_records_without_tracing(profiled):
    assert not obs_trace.TRACE.enabled
    before = obs_trace.emitted_count()
    with hw.CounterScope("publish", nbytes=64):
        _busy(2)
    assert obs_trace.emitted_count() == before == 0
    assert hw.scope_count() == 1     # profiling still accounted locally


# ---------------------------------------------------------------------------
# end-to-end: phase profile of a real serving fabric
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fabric_serving_accounts_phases(clean_env):
    from repro.core.dispatcher import RequestDispatcher
    from repro.core.policy import OffloadPolicy
    from repro.ipc import RemoteDispatcherClient, ServingFabric, TransportSpec

    hw.enable()
    hw.reset()
    spec = TransportSpec(data_slots=4, data_slot_bytes=1 << 20,
                         ctrl_slots=4, ctrl_slot_bytes=4 << 10)
    tight = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0)
    d = RequestDispatcher(tight)
    d.register_handler("double", lambda a: a * 2.0,
                       batch_fn=lambda xs: [x * 2.0 for x in xs])
    try:
        with ServingFabric(d, spec=spec, policy=tight,
                           own_dispatcher=True).start() as fabric:
            client = RemoteDispatcherClient.connect(fabric.name, policy=tight)
            data = np.ones((64, 64), np.float32)
            for _ in range(4):
                out = client.request("double", data, mode="sync")
                np.testing.assert_allclose(out, data * 2.0)
            client.close()
            reg_snap = fabric.metrics.snapshot()
        snap = hw.snapshot()
        phases = snap["phases"]
        # the serving path must account its core phases
        for phase in ("ring_poll", "handler", "reserve_fill",
                      "publish", "reply_drain"):
            assert phase in phases, f"{phase} missing from {sorted(phases)}"
            assert phases[phase]["count"] > 0
        assert phases["handler"]["bytes"] > 0
        # the fabric registers the profile under the metrics plane
        assert any(k.startswith("hw.") for k in reg_snap)
    finally:
        hw.disable()
        hw.reset()
        hw.probe(refresh=True)
