"""End-to-end behaviour: training learns, checkpoint-resume is exact,
serving round-trips through the dispatcher, dry-run machinery works."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource
from repro.models import build_model
from repro.optim import adamw
from repro.serve import BatchedServer, ServeConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def test_training_reduces_loss(rng_key):
    """~40 steps on the synthetic induction task must clearly reduce loss."""
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params, opt_state = init_train_state(model, rng_key)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=5,
                                             total_steps=60))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    shape = ShapeConfig("t", "train", 32, 8)
    pipe = InputPipeline(SyntheticLMSource(cfg, shape, seed=0),
                         OffloadPolicy(mode=ExecutionMode.PIPELINED,
                                       offload_threshold_bytes=1))
    losses = []
    for _, batch in zip(range(40), pipe):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    pipe.close()
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


def test_microbatched_grads_match_full_batch(rng_key):
    """Gradient accumulation must be numerically equivalent to the full batch."""
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params, opt_state = init_train_state(model, rng_key)
    shape = ShapeConfig("t", "train", 16, 8)
    batch = next(SyntheticLMSource(cfg, shape, seed=1))
    batch = jax.tree.map(jnp.asarray, batch)
    opt = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(model, TrainConfig(opt=opt, microbatches=1))(
        params, opt_state, batch)
    p4, _, m4 = make_train_step(model, TrainConfig(opt=opt, microbatches=4))(
        params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_checkpoint_resume_bitexact(tmp_path, rng_key):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    model = build_model(cfg)
    shape = ShapeConfig("t", "train", 16, 4)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(warmup_steps=2, total_steps=10))
    step_fn = jax.jit(make_train_step(model, tcfg))

    def run(n_start, n_end, params, opt_state):
        src = SyntheticLMSource(cfg, shape, seed=9)
        src.step = n_start
        for i in range(n_start, n_end):
            params, opt_state, m = step_fn(params, opt_state,
                                           jax.tree.map(jnp.asarray, next(src)))
        return params, opt_state

    params, opt_state = init_train_state(model, rng_key)
    pa, oa = run(0, 6, params, opt_state)

    pb, ob = run(0, 3, *init_train_state(model, rng_key))
    cm = CheckpointManager(str(tmp_path))
    cm.save(3, {"params": pb, "opt": ob})
    restored, _ = cm.restore(3, {"params": pb, "opt": ob})
    pc, oc = run(3, 6, restored["params"], restored["opt"])

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_end_to_end(rng_key):
    cfg = get_smoke_config("qwen3-32b")
    model = build_model(cfg)
    params = model.init(rng_key)
    srv = BatchedServer(model, params,
                        ServeConfig(max_len=32, max_new_tokens=4),
                        OffloadPolicy(max_batch=4))
    with srv.make_dispatcher() as d:
        prompts = [np.arange(1, 6, dtype=np.int32) * (i + 1) % cfg.vocab_size
                   for i in range(5)]
        jids = [d.request("generate", p, mode="pipelined") for p in prompts]
        outs = [d.query(j) for j in jids]
    assert all(o.shape == (4,) for o in outs)
    assert srv.stats["requests"] == 5
    # determinism: same prompt -> same tokens
    a = srv.generate_batch(srv._pack([prompts[0]]))
    b = srv.generate_batch(srv._pack([prompts[0]]))
    np.testing.assert_array_equal(a, b)
    srv.close()


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The real dry-run machinery on the production mesh (512 host devices),
    via subprocess so the main test process keeps 1 device."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k", "--force"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[ok" in out.stdout
