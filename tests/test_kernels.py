"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes,
in interpret mode (CPU container; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.offload_copy import offload_copy_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------------------
# offload_copy (the DSA-engine analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 2, 3, 4])          # sync/async/pipelined
@pytest.mark.parametrize("inject", [False, True])        # cache injection
@pytest.mark.parametrize("dtype,out_dtype", [
    ("float32", "float32"), ("float32", "bfloat16"), ("bfloat16", "float32")])
def test_offload_copy_modes(depth, inject, dtype, out_dtype, rng_key):
    x = jax.random.normal(rng_key, (512, 256)).astype(dtype)
    y, s = offload_copy_pallas(x, scale=1.5, out_dtype=out_dtype, depth=depth,
                               block_rows=128, inject=inject, interpret=True)
    yr, sr = ref.offload_copy(x, scale=1.5, out_dtype=out_dtype, inject=inject)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=1e-2, atol=1e-2)
    if inject:
        assert abs(float(s) - float(sr)) <= abs(float(sr)) * 1e-2 + 1e-2


@given(st.sampled_from([64, 128, 256]), st.sampled_from([1, 2, 4]))
@settings(max_examples=8)
def test_offload_copy_block_shape_sweep(rows, depth):
    x = jnp.arange(rows * 128, dtype=jnp.float32).reshape(rows, 128) / 1000.0
    y, _ = offload_copy_pallas(x, depth=depth, block_rows=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,t,h,kh,hd,causal", [
    (128, 128, 4, 4, 32, True),
    (128, 128, 4, 2, 64, True),
    (64, 128, 8, 1, 32, False),
    (256, 256, 2, 2, 128, True),
])
def test_flash_attention_shapes(s, t, h, kh, hd, causal, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, s, h, hd))
    k = jax.random.normal(ks[1], (2, t, kh, hd))
    v = jax.random.normal(ks[2], (2, t, kh, hd))
    o = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64,
                               interpret=True)
    orf = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    orf = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), rtol=0.1, atol=0.1)


@given(st.sampled_from([32, 64, 128]))
@settings(max_examples=6)
def test_flash_attention_block_invariance(bq):
    ks = jax.random.split(jax.random.key(bq), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    a = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bq,
                               interpret=True)
    b = flash_attention_pallas(q, k, v, causal=True, block_q=128, block_k=128,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

def _ssd_inputs(key, b=2, s=32, nh=4, p=16, g=2, n=8):
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, nh, p))
    bm = 0.5 * jax.random.normal(ks[1], (b, s, g, n))
    cm = 0.5 * jax.random.normal(ks[2], (b, s, g, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, nh)))
    da = -jnp.exp(jax.random.normal(ks[4], (nh,))) * dt
    dsk = jnp.linspace(0.5, 1.5, nh)
    return xh, bm, cm, dt, da, dsk


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_ssd_scan_chunks_groups(chunk, g, rng_key):
    xh, bm, cm, dt, da, dsk = _ssd_inputs(rng_key, g=g)
    y, hf = ssd_scan_pallas(xh, bm, cm, dt, da, dsk, chunk=chunk,
                            interpret=True)
    yr, hr = ref.ssd_scan(xh, bm, cm, dt, da, dsk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=1e-4, atol=1e-4)


def test_ssd_scan_bf16_inputs(rng_key):
    xh, bm, cm, dt, da, dsk = _ssd_inputs(rng_key)
    y, _ = ssd_scan_pallas(xh.astype(jnp.bfloat16), bm, cm, dt, da, dsk,
                           chunk=16, interpret=True)
    yr, _ = ref.ssd_scan(xh.astype(jnp.bfloat16), bm, cm, dt, da, dsk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# policy-driven wrapper (offload control)
# ---------------------------------------------------------------------------

def test_ops_threshold_dispatch(rng_key):
    from repro.core.policy import OffloadPolicy, ExecutionMode, Device
    from repro.kernels import ops
    x = jax.random.normal(rng_key, (256, 128))
    small_policy = OffloadPolicy(offload_threshold_bytes=1 << 30)  # never
    y1, _ = ops.offload_copy(x, policy=small_policy)
    y2, _ = ops.offload_copy(
        x, policy=OffloadPolicy(offload_threshold_bytes=1))       # always
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
