"""Tier-2 movement modes preserve semantics: manual-DP shard_map training
equals the GSPMD-default step; decode movement variants equal baseline
decode (exact or within quantization error)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMSource
from repro.models import build_model
from repro.models import attention as attn
from repro.models.transformer import lm_decode_step_inplace
from repro.optim import adamw
from repro.sharding import api as shard_api
from repro.train import TrainConfig, init_train_state, make_train_step


@pytest.fixture()
def unit_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shard_api.use_mesh(mesh):
        yield mesh


def test_manual_dp_equals_default_step(rng_key, unit_mesh):
    """shard_map manual-DP (one psum/step) is numerically the same step."""
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params, opt_state = init_train_state(model, rng_key)
    batch = jax.tree.map(jnp.asarray,
                         next(SyntheticLMSource(cfg, ShapeConfig("t", "train", 16, 4))))
    opt = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    p_ref, _, m_ref = make_train_step(model, TrainConfig(opt=opt))(
        params, opt_state, batch)
    p_man, _, m_man = make_train_step(
        model, TrainConfig(opt=opt, manual_dp_axes=("data", "model")))(
        params, opt_state, batch)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_man["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_man)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_manual_dp_with_microbatches(rng_key, unit_mesh):
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params, opt_state = init_train_state(model, rng_key)
    batch = jax.tree.map(jnp.asarray,
                         next(SyntheticLMSource(cfg, ShapeConfig("t", "train", 16, 4))))
    opt = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    p_ref, _, m_ref = make_train_step(
        model, TrainConfig(opt=opt, microbatches=2))(params, opt_state, batch)
    p_man, _, m_man = make_train_step(
        model, TrainConfig(opt=opt, microbatches=2,
                           manual_dp_axes=("data", "model")))(
        params, opt_state, batch)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_man["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_man)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_sp_decode_attention_unit_axis(rng_key, unit_mesh):
    """Split-KV shard_map decode == merged decode on a size-1 model axis."""
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, max_len=16)
    la, _ = lm_decode_step_inplace(params, cache, toks[:, 8:9], cfg)
    lb, _ = lm_decode_step_inplace(params, cache, toks[:, 8:9], cfg,
                                   sp_axis="model")
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-5, atol=1e-5)


def test_q8_cache_roundtrip_error_bounded(rng_key):
    """int8 KV quantization: per-vector relative error < 2%."""
    x = jax.random.normal(rng_key, (2, 16, 4, 32))
    q, s = attn.quantize_kv(x)
    y = attn.dequantize_kv(q, s, x.dtype)
    rel = float(jnp.max(jnp.abs(y - x)) / jnp.max(jnp.abs(x)))
    assert q.dtype == jnp.int8
    assert rel < 0.02, rel


def test_q8_decode_close_to_exact(rng_key):
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, max_len=16)
    la, _ = model.decode_step(params, cache, toks[:, 8:9])
    kq, ks = attn.quantize_kv(cache["k"])
    vq, vs = attn.quantize_kv(cache["v"])
    qcache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
              "index": cache["index"]}
    lb, qc2 = lm_decode_step_inplace(params, qcache, toks[:, 8:9], cfg)
    assert qc2["k"].dtype == jnp.int8
    err = float(jnp.max(jnp.abs(la - lb)))
    assert err < 0.05, f"quantized decode too far from exact: {err}"
