"""MoE routing/dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe


def mk_cfg(e=4, k=2, cf=4.0, d=16, f=32):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=d,
                       num_heads=2, num_kv_heads=2, d_ff=f, vocab_size=64,
                       num_experts=e, num_experts_per_token=k,
                       moe_capacity_factor=cf,
                       dtype="float32", param_dtype="float32")


def test_output_shape_and_finite(rng_key):
    cfg = mk_cfg()
    params = moe.moe_init(rng_key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, aux = moe.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(aux)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_dropless_at_high_capacity_is_permutation_invariant(rng_key):
    """With no dropping, shuffling tokens then unshuffling is a no-op."""
    cfg = mk_cfg(cf=8.0)
    params = moe.moe_init(rng_key, cfg)
    x = jax.random.normal(jax.random.key(2), (1, 16, cfg.d_model))
    y, _ = moe.moe_apply(params, x, cfg)
    perm = jax.random.permutation(jax.random.key(3), 16)
    y_perm, _ = moe.moe_apply(params, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(y_perm),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drop_zeroes_tokens(rng_key):
    """With capacity 0 every token is dropped -> MoE output is exactly 0."""
    cfg = dataclasses.replace(mk_cfg(), moe_capacity_factor=1e-9)
    params = moe.moe_init(rng_key, cfg)
    x = jax.random.normal(jax.random.key(4), (1, 8, cfg.d_model))
    # capacity floor is 1, so force collisions instead: all tokens identical
    x = jnp.broadcast_to(x[:, :1], x.shape)
    y, _ = moe.moe_apply(params, x, cfg)
    # capacity=1 per expert: only the first token per expert slot survives
    assert float(jnp.abs(y[0, -1]).sum()) == 0.0, "overflow token not dropped"
    assert float(jnp.abs(y[0, 0]).sum()) > 0.0


@given(st.integers(2, 5))
def test_combine_weights_normalized(seed):
    """Per-token combine weights sum to <= 1 (== 1 when nothing dropped)."""
    cfg = mk_cfg(cf=8.0)
    params = moe.moe_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 100), (1, 12, cfg.d_model))
    # reconstruct weights through a linear probe: moe(αx) with identity experts
    # is hard; instead check routing internals via the public contract:
    y, aux = moe.moe_apply(params, x, cfg)
    assert aux >= 0.99, "balanced-ish aux loss should be >= ~1"
    assert bool(jnp.all(jnp.isfinite(y)))


def test_expert_capacity_formula():
    cfg = mk_cfg(e=8, k=2, cf=1.0)
    assert moe.expert_capacity(64, cfg) == 16
    cfg2 = mk_cfg(e=8, k=2, cf=1.25)
    assert moe.expert_capacity(64, cfg2) == 20
    assert moe.expert_capacity(1, mk_cfg(e=64, k=1, cf=1.0)) == 1  # floor


def test_group_tail_handling(rng_key):
    """Token counts that don't divide GROUP_SIZE still produce full output."""
    cfg = mk_cfg()
    params = moe.moe_init(rng_key, cfg)
    old = moe.GROUP_SIZE
    try:
        moe.GROUP_SIZE = 8
        x = jax.random.normal(jax.random.key(5), (1, 12, cfg.d_model))
        y, _ = moe.moe_apply(params, x, cfg)
        assert y.shape == x.shape
    finally:
        moe.GROUP_SIZE = old
