"""ROCKET core runtime: the paper's configuration semantics (Table III/§V),
latency model, engine modes, dispatcher, buffer pools."""
import time

import numpy as np
import pytest
from hypothesis import given, strategies as st

import jax

from repro.core import (
    AsyncTransferEngine,
    BufferPool,
    ExecutionMode,
    LatencyModel,
    OffloadPolicy,
    QueuePair,
    RequestDispatcher,
    calibrate,
)
from repro.core.policy import Device


# ---------------------------------------------------------------------------
# policy semantics (paper Table III + §V defaults)
# ---------------------------------------------------------------------------

def test_injection_defaults_follow_paper():
    sync = OffloadPolicy(mode=ExecutionMode.SYNC)
    async_ = OffloadPolicy(mode=ExecutionMode.ASYNC)
    pipe = OffloadPolicy(mode=ExecutionMode.PIPELINED)
    assert sync.injection_enabled(1) is True          # sync: on
    assert async_.injection_enabled(1) is True        # async single-client: on
    assert async_.injection_enabled(4) is False       # async contended: off
    assert pipe.injection_enabled(1) is False         # pipelined: off
    # explicit override wins
    assert OffloadPolicy(mode=ExecutionMode.PIPELINED,
                         cache_injection=True).injection_enabled(8) is True


def test_size_threshold_offload_control():
    pol = OffloadPolicy(offload_threshold_bytes=1024)
    assert not pol.should_offload(512)
    assert pol.should_offload(2048)
    assert not pol.with_device("inline").should_offload(1 << 30)


@given(st.integers(0, 1 << 28))
def test_latency_model_monotonic(nbytes):
    m = LatencyModel(73.6, 33.4)
    assert m.predict_us(nbytes) >= m.l_fixed_us
    assert m.defer_seconds(nbytes) <= m.predict_us(nbytes) * 1e-6


def test_latency_model_matches_paper_constants():
    m = LatencyModel()                                # paper's measured priors
    assert abs(m.predict_us(1 << 20) - (73.6 + 33.4)) < 1e-6
    # ~30 GB/s implied DSA-like bandwidth
    assert 20 < m.bandwidth_gbps() < 40


def test_calibration_recovers_linear_model():
    # constants sized well above the host's sleep granularity (containers
    # can have ~1ms timer quanta, which would flatten a microsecond-scale
    # fake model into alpha=0)
    true = LatencyModel(l_fixed_us=1000.0, alpha_us_per_mb=2000.0)

    def fake_transfer(buf):
        time.sleep(true.predict_us(buf.nbytes) * 1e-6)

    m = calibrate(fake_transfer, sizes_bytes=(1 << 19, 1 << 20, 1 << 21),
                  repeats=3)
    assert abs(m.alpha_us_per_mb - 2000.0) < 600.0
    assert m.l_fixed_us < 3000.0


def test_pipeline_depth_from_latency_model():
    m = LatencyModel(10.0, 10.0)
    assert m.pipeline_depth_for(1 << 20, compute_us_per_block=1000.0) == 2
    assert m.pipeline_depth_for(1 << 20, compute_us_per_block=5.0) == 5
    assert m.pipeline_depth_for(1 << 20, compute_us_per_block=0.1) == 8  # cap


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_engine_sync_mode_never_offloads():
    with AsyncTransferEngine(OffloadPolicy(mode=ExecutionMode.SYNC,
                                           offload_threshold_bytes=1)) as eng:
        job = eng.submit(np.ones((64, 64), np.float32))
        assert job.done()
        assert eng.stats.offloaded == 0 and eng.stats.inline == 1


def test_engine_threshold_keeps_small_transfers_inline():
    pol = OffloadPolicy(mode=ExecutionMode.ASYNC,
                        offload_threshold_bytes=1 << 20)
    with AsyncTransferEngine(pol) as eng:
        eng.submit(np.ones(16, np.float32)).get()          # 64B -> inline
        eng.submit(np.ones(1 << 19, np.float32)).get()     # 2MB -> offload
        assert eng.stats.inline == 1
        assert eng.stats.offloaded == 1


def test_engine_pipelined_backpressure():
    pol = OffloadPolicy(mode=ExecutionMode.PIPELINED, pipeline_depth=2,
                        offload_threshold_bytes=1)
    with AsyncTransferEngine(pol) as eng:
        jobs = [eng.submit(np.full((128,), i, np.float32)) for i in range(6)]
        outs = eng.drain()
        assert len(outs) <= 3                      # ring bounded at depth+1
        vals = [float(np.asarray(j.get())[0]) for j in jobs]
        assert vals == [float(i) for i in range(6)]   # order & values intact


def test_engine_results_correct_across_modes():
    for mode in ExecutionMode:
        with AsyncTransferEngine(OffloadPolicy(mode=mode,
                                               offload_threshold_bytes=1)) as eng:
            x = np.arange(1024, dtype=np.float32)
            out = np.asarray(eng.submit(x).get())
            np.testing.assert_array_equal(out, x)


# ---------------------------------------------------------------------------
# dispatcher / query handler
# ---------------------------------------------------------------------------

def test_dispatcher_sync_returns_directly():
    with RequestDispatcher() as d:
        d.register_handler("inc", lambda x: x + 1)
        assert d.request("inc", np.float32(41), mode="sync") == 42


def test_dispatcher_pipelined_batches():
    pol = OffloadPolicy(mode=ExecutionMode.PIPELINED, max_batch=4)
    with RequestDispatcher(pol, max_batch_wait_s=0.05) as d:
        d.register_handler("sq", lambda x: x * x,
                           batch_fn=lambda xs: [x * x for x in xs])
        jids = [d.request("sq", np.float32(i), mode="pipelined")
                for i in range(8)]
        outs = [d.query(j) for j in jids]
        assert outs == [i * i for i in range(8)]
        assert d.stats.batches < 8                 # some batching happened


def test_dispatcher_async_and_unknown_job():
    with RequestDispatcher() as d:
        d.register_handler("neg", lambda x: -x)
        j = d.request("neg", np.float32(5), mode="async")
        assert d.query(j) == -5
        with pytest.raises(KeyError):
            d.queries.query(99999)


# ---------------------------------------------------------------------------
# queue pairs / buffer pools (page-fault-avoidance analogue)
# ---------------------------------------------------------------------------

def test_buffer_pool_reuse():
    pool = BufferPool()
    a = pool.acquire((32, 32), np.float32)
    pool.release(a)
    b = pool.acquire((32, 32), np.float32)
    assert a is b                                   # the same mapping reused
    assert pool.stats.hits == 1 and pool.stats.misses == 1
    c = pool.acquire((32, 32), np.float64)          # different key
    assert c is not a


def test_buffer_pool_preallocate_counts_as_setup():
    pool = BufferPool()
    pool.preallocate((8,), np.float32, 4)
    for _ in range(4):
        pool.release(pool.acquire((8,), np.float32))
    assert pool.stats.misses == 0                   # no runtime page faults
    assert pool.stats.hits >= 4


@given(st.lists(st.sampled_from([(4, 4), (8, 8)]), min_size=1, max_size=12))
def test_buffer_pool_property_reuse_rate(shapes):
    pool = BufferPool(max_per_key=len(shapes))
    held = []
    for s in shapes:
        held.append(pool.acquire(s, np.float32))
    for b in held:
        pool.release(b)
    for s in shapes:
        pool.acquire(s, np.float32)
    assert pool.stats.hits >= len(shapes)           # second pass all hits


def test_queue_pair_slots_and_backpressure():
    qp = QueuePair(2, (4,), (4,))
    s1 = qp.acquire_tx(1)
    s2 = qp.acquire_tx(2)
    assert s1 is not None and s2 is not None
    assert qp.acquire_tx(3) is None                 # ring full
    qp.release(s1)
    assert qp.acquire_tx(3) is not None
