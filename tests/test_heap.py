"""Bulk heap: extent allocator + the large-message datapath.

Allocator unit tests run against a raw :class:`BulkHeap`; datapath tests
drive it through real transports — in-process pairs for deterministic
scheduling, then spawned processes for the 128 MB acceptance round trip
with counted single-copy proof (data_slot_bytes <= 1 MB, so every large
message *must* ride the heap).
"""
import multiprocessing as mp
import time

import numpy as np
import pytest

from repro.core.copyengine import CopyEngine, set_engine
from repro.core.policy import OffloadPolicy
from repro.ipc import ShmTransport, TransportSpec
from repro.ipc.heap import (
    BulkHeap,
    HeapExhausted,
    HeapSpec,
    MAX_SEGMENTS,
    next_pow2,
    segments_used,
)

TIGHT = OffloadPolicy(offload_threshold_bytes=1, poll_interval_us=50.0,
                      heap_threshold_bytes=1 << 18)
E = 1 << 16                      # tiny extents: allocator tests stay fast


def _heap(n_extents=16, extent_bytes=E, name="rocket-test-heap"):
    return BulkHeap.create(name, HeapSpec(extent_bytes, n_extents))


# ---------------------------------------------------------------------------
# allocator: rounding, reuse, scatter, exhaustion
# ---------------------------------------------------------------------------

def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_alloc_rounds_to_pow2_size_class():
    with _heap() as h:
        segs = h.try_alloc(3 * E)            # 3 extents -> class of 4
        assert segs == ((0, 4 * E),)
        assert h.free_extents(h.tx_dir) == 12
        h.free(segs, h.tx_dir)
        assert h.free_extents(h.tx_dir) == 16


def test_alloc_free_reuse_cycles():
    """Freed extents are found again (next-fit wraps the table)."""
    with _heap(n_extents=8) as h:
        for _ in range(50):                  # >> table size: forces reuse
            segs = h.try_alloc(5 * E)        # class 8 = the whole table
            assert segs is not None
            h.free(segs, h.tx_dir)
        assert h.free_extents(h.tx_dir) == 8
        assert h.stats.allocs == 50 and h.stats.frees == 50


def test_scatter_allocation_under_fragmentation():
    """With no contiguous run big enough, the allocator returns a
    multi-extent scatter list covering the exact need."""
    with _heap(n_extents=16) as h:
        holds = [h.try_alloc(1) for _ in range(16)]       # fill: 1 extent each
        # free alternating extents: max contiguous run is 1
        for i in range(0, 16, 2):
            h.free(holds[i], h.tx_dir)
        segs = h.try_alloc(3 * E)            # needs 3 extents, scattered
        assert segs is not None and len(segs) == 3
        assert h.stats.scatter_allocs == 1
        assert sum(cap for _, cap in segs) == 3 * E
        # virtual mapping covers the payload exactly, in order
        pieces = segments_used(segs, 3 * E - 100)
        assert sum(used for _, _, used in pieces) == 3 * E - 100


def test_exhaustion_is_retryable_backpressure():
    """No room -> try_alloc None (counted), alloc() blocks then times out,
    and an abort check turns the wait into HeapExhausted immediately."""
    with _heap(n_extents=4) as h:
        hold = h.try_alloc(4 * E)
        assert h.try_alloc(E) is None
        assert h.stats.exhausted == 1
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError, match="exhausted"):
            h.alloc(E, timeout_s=0.1)
        assert time.perf_counter() - t0 >= 0.1
        with pytest.raises(HeapExhausted):
            h.alloc(E, timeout_s=5.0, abort_check=lambda: True)
        # free from the "receiver" side unblocks a waiting alloc
        h.free(hold, h.tx_dir)
        assert h.try_alloc(E) is not None


def test_alloc_larger_than_direction_capacity_raises():
    with _heap(n_extents=4) as h:
        with pytest.raises(ValueError, match="exceeds heap direction"):
            h.try_alloc(5 * E)


def test_scatter_respects_max_segments():
    """Fragmentation worse than MAX_SEGMENTS runs reports exhaustion, not
    an unboundedly long wire descriptor."""
    n = 2 * (MAX_SEGMENTS + 8)
    with _heap(n_extents=n) as h:
        holds = [h.try_alloc(1) for _ in range(n)]
        for i in range(0, n, 2):             # MAX_SEGMENTS+8 isolated frees
            h.free(holds[i], h.tx_dir)
        assert h.try_alloc((MAX_SEGMENTS + 4) * E) is None
        assert h.stats.exhausted == 1


# ---------------------------------------------------------------------------
# cross-process: alloc here, free there; reap after a kill
# ---------------------------------------------------------------------------

def _peer_free_entry(name: str, spec: HeapSpec, segs, q) -> None:
    h = BulkHeap.attach(name, spec)
    try:
        # the attacher's rx dir is the creator's tx dir: receiver-side free
        h.free(segs, h.rx_dir)
        q.put(h.free_extents(h.rx_dir))
    finally:
        h.close()


def test_cross_process_alloc_here_free_there():
    spec = HeapSpec(E, 8)
    h = BulkHeap.create("rocket-test-xproc-heap", spec)
    try:
        segs = h.try_alloc(3 * E)
        assert h.free_extents(h.tx_dir) == 4
        ctx = mp.get_context("spawn")
        q = ctx.Queue()
        p = ctx.Process(target=_peer_free_entry,
                        args=(h.arena.name, spec, segs, q))
        p.start()
        assert q.get(timeout=60) == 8        # peer observed the free
        p.join(timeout=30)
        assert p.exitcode == 0
        assert h.free_extents(h.tx_dir) == 8  # visible on our side too
    finally:
        h.close()
        h.unlink()


def _leaky_client_entry(name: str) -> None:
    """Attach, allocate extents as if mid-send, then die without freeing
    or publishing (the crash the reaper exists for)."""
    t = ShmTransport.attach(name, policy=TIGHT)
    segs = t.heap.try_alloc(3 * t.heap.spec.extent_bytes)
    assert segs is not None
    import os
    os._exit(1)                              # no close, no announce


def test_leaked_extent_reap_after_killed_client():
    spec = TransportSpec(data_slots=2, data_slot_bytes=1 << 18,
                         heap_extent_bytes=E, heap_extents=8,
                         ctrl_slots=2, ctrl_slot_bytes=1 << 12)
    server = ShmTransport.create(spec=spec, policy=TIGHT)
    try:
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=_leaky_client_entry, args=(server.name,))
        p.start()
        p.join(timeout=60)
        assert p.exitcode == 1
        # the dead attacher's tx dir (our rx) holds leaked extents
        assert server.heap.free_extents(server.heap.rx_dir) < 8
        # peer never announced close -> guarded reap refuses without force
        with pytest.raises(RuntimeError, match="refusing"):
            server.reap_heap()
        reaped = server.reap_heap(force=True)
        assert reaped == 4                   # 3 extents -> pow2 class of 4
        assert server.heap.free_extents(server.heap.rx_dir) == 8
        assert server.heap.stats.reaped == 4
    finally:
        server.close()


# ---------------------------------------------------------------------------
# datapath: threshold selection, leases free extents, scatter reassembly
# ---------------------------------------------------------------------------

def _pair(spec, policy=TIGHT):
    a = ShmTransport.create(spec=spec, policy=policy)
    b = ShmTransport.attach(a.name, policy=policy)
    return a, b


SPEC = TransportSpec(data_slots=3, data_slot_bytes=1 << 20,
                     heap_extent_bytes=1 << 18, heap_extents=16,
                     ctrl_slots=4, ctrl_slot_bytes=4 << 10)


def test_threshold_selects_inline_slot_vs_heap():
    a, b = _pair(SPEC)
    try:
        a.send({"x": np.zeros(16, np.uint8)}, mode="sync")   # tiny: slot
        b.recv(timeout_s=10)
        assert a.data.stats.heap_sends == 0
        a.send({"x": np.zeros(1 << 18, np.uint8)}, mode="sync")  # >= thresh
        b.recv(timeout_s=10)
        assert a.data.stats.heap_sends == 1
        assert b.data.stats.heap_recvs == 1
        # over slot capacity *must* go heap even in a fresh channel
        a.send({"x": np.zeros((1 << 20) + 1, np.uint8)}, mode="sync")
        b.recv(timeout_s=10)
        assert a.data.stats.heap_sends == 2
    finally:
        b.close(); a.close()


def test_heap_lease_release_frees_extents_and_backpressures():
    """A held lease keeps extents ALLOCATED (sender-side backpressure);
    releasing it frees them and unblocks the sender."""
    a, b = _pair(SPEC)
    try:
        big = {"x": np.arange(1 << 20, dtype=np.uint8)}      # 4 extents
        a.send(big, mode="sync")
        lease = b.recv(copy=False, timeout_s=10)
        assert lease.held
        assert b.heap.free_extents(b.heap.rx_dir) == 12
        np.testing.assert_array_equal(lease.tree["x"], big["x"])
        with pytest.raises(TimeoutError):        # 12 left, need 16: blocked
            a.data._heap_alloc_blocking(13 << 18, timeout_s=0.1)
        lease.release()
        assert lease.tree is None
        assert b.heap.free_extents(b.heap.rx_dir) == 16
        assert a.heap.free_extents(a.heap.tx_dir) == 16      # same table
    finally:
        b.close(); a.close()


def test_heap_copy_recv_frees_extents_immediately():
    a, b = _pair(SPEC)
    try:
        msg = np.arange(1 << 20, dtype=np.uint8)
        a.send({"x": msg}, mode="sync")
        tree, _ = b.recv(copy=True, timeout_s=10)
        # extents are already back, so the tree must be materialized: a
        # reused heap range cannot corrupt it
        assert b.heap.free_extents(b.heap.rx_dir) == 16
        a.heap.try_alloc(16 << 18)               # reuse the whole direction
        a.heap.u8(a.heap.tx_dir, 0, 1 << 20)[:] = 0xFF
        np.testing.assert_array_equal(tree["x"], msg)
    finally:
        b.close(); a.close()


def test_scatter_message_reassembles_straddling_leaves():
    """Fragment the heap so a big leaf must scatter across extents, and
    verify byte identity plus the counted reassembly."""
    a, b = _pair(SPEC)
    try:
        E_ = SPEC.heap_extent_bytes
        holds = [a.heap.try_alloc(1) for _ in range(16)]
        for i in range(0, 16, 2):
            a.heap.free(holds[i], a.heap.tx_dir)     # only 1-extent runs free
        msg = {"x": np.arange(2 * E_ + 100, dtype=np.uint8)}  # needs 3 runs
        a.send(msg, mode="sync")
        lease = b.recv(copy=False, timeout_s=10)
        np.testing.assert_array_equal(lease.tree["x"], msg["x"])
        assert a.heap.stats.scatter_allocs == 1
        assert b.data.stats.heap_reassembles == 1    # straddler copied once
        lease.release()
        for i in range(1, 16, 2):
            a.heap.free(holds[i], a.heap.tx_dir)
        assert a.heap.free_extents(a.heap.tx_dir) == 16
    finally:
        b.close(); a.close()


def test_heap_reserve_then_fill_and_abort():
    a, b = _pair(SPEC)
    try:
        tmpl = {"r": np.empty(1 << 19, np.int32)}            # 2 MB: heap
        slot = a.data.reserve(tmpl, header={"j": 3})
        assert slot.tree["r"].base is not None               # view into heap
        slot.tree["r"][:] = 9
        slot.publish()
        got, hdr = b.recv(timeout_s=10)
        assert hdr == {"j": 3} and (got["r"] == 9).all()
        assert a.data.stats.heap_sends == 1
        # abort returns the extents without publishing anything
        slot = a.data.reserve(tmpl)
        slot.abort()
        assert a.heap.free_extents(a.heap.tx_dir) == 16
        assert b.data.try_recv() is None
    finally:
        b.close(); a.close()


def test_heap_disabled_spec_keeps_slot_cap_error():
    spec = TransportSpec(data_slots=2, data_slot_bytes=1 << 18,
                         heap_extents=0, ctrl_slots=2,
                         ctrl_slot_bytes=1 << 12)
    a, b = _pair(spec)
    try:
        assert a.heap is None
        with pytest.raises(ValueError, match="slot capacity"):
            a.send({"x": np.zeros((1 << 18) + 1, np.uint8)}, mode="sync")
    finally:
        b.close(); a.close()


def test_offloaded_heap_send_parks_on_exhaustion_until_lease_release():
    """Pipelined heap sends WouldBlock-park on an exhausted heap instead
    of blocking an engine worker, and complete once extents free up."""
    a, b = _pair(SPEC)
    try:
        chunky = OffloadPolicy(offload_threshold_bytes=1,
                               heap_threshold_bytes=1 << 18,
                               heap_chunk_bytes=1 << 18,
                               poll_interval_us=50.0)
        a.data.policy = chunky
        big = {"x": np.arange(12 << 18, dtype=np.uint8)}     # 12 of 16 ext.
        a.send(big, mode="sync")
        lease = b.recv(copy=False, timeout_s=10)             # hold 16 (pow2)
        h = a.send(big, mode="async")                        # must park
        time.sleep(0.1)
        assert not h.done()
        lease.release()
        h.wait(timeout_s=30)
        lease2 = b.recv(copy=False, timeout_s=10)
        np.testing.assert_array_equal(lease2.tree["x"], big["x"])
        lease2.release()
    finally:
        b.close(); a.close()


# ---------------------------------------------------------------------------
# acceptance: 128 MB pytree round trip, counted single copy per direction
# ---------------------------------------------------------------------------

BIG_SPEC = TransportSpec(data_slots=4, data_slot_bytes=1 << 20,   # <= 1 MB
                         heap_extent_bytes=8 << 20, heap_extents=20,
                         ctrl_slots=4, ctrl_slot_bytes=4 << 10)
BIG_POLICY = OffloadPolicy(offload_threshold_bytes=1,
                           heap_threshold_bytes=1 << 20,
                           poll_interval_us=100.0)


def _big_tree():
    """A 128 MB pytree (three leaves, mixed dtypes/shapes)."""
    return {
        "tokens": np.arange(24 << 20, dtype=np.int32),        # 96 MB
        "embeds": {"v": np.arange(7 << 20, dtype=np.float32)  # 28 MB
                   .reshape(7, 1 << 20)},
        "mask": np.full(4 << 20, 7, np.uint8),                # 4 MB
    }


def _big_echo_entry(name: str, q) -> None:
    """Child: receive the 128 MB tree as a zero-copy lease, verify bytes,
    echo it back through its own heap direction, report its counters."""
    eng = CopyEngine(BIG_POLICY)
    set_engine(eng)
    t = ShmTransport.attach(name, policy=BIG_POLICY)
    try:
        lease = t.recv(copy=False, timeout_s=120)
        expect = _big_tree()
        ok = (np.array_equal(lease.tree["tokens"], expect["tokens"])
              and np.array_equal(lease.tree["embeds"]["v"],
                                 expect["embeds"]["v"])
              and np.array_equal(lease.tree["mask"], expect["mask"]))
        # echo straight from the leased views: the send-side heap fill is
        # this direction's ONE payload copy
        t.send(lease.tree, header={"echo": True}, mode="sync")
        lease.release()
        tags = eng.tagged_snapshot()
        q.put({"ok": ok, "copies": tags["copies"], "bytes": tags["bytes"],
               "stats": t.data.stats.snapshot()})
    finally:
        t.close()


@pytest.mark.slow
def test_128mb_pytree_roundtrip_single_copy_counted():
    """The PR's acceptance bar: a 128 MB pytree crosses a spawned-process
    transport whose data slots are 1 MB, byte-identical both ways, with
    engine counters proving exactly ONE payload copy per direction
    (send-side heap fill; zero receive-side copies)."""
    eng = CopyEngine(BIG_POLICY)
    prev = set_engine(eng)
    server = ShmTransport.create(spec=BIG_SPEC, policy=BIG_POLICY)
    try:
        ctx = mp.get_context("spawn")
        q = ctx.Queue()
        p = ctx.Process(target=_big_echo_entry, args=(server.name, q))
        p.start()

        tree = _big_tree()
        nbytes = sum(a.nbytes for a in
                     (tree["tokens"], tree["embeds"]["v"], tree["mask"]))
        assert nbytes == 128 << 20
        server.send(tree, mode="sync")
        echoed = server.recv(copy=False, timeout_s=120)
        assert echoed.header.get("echo")
        assert np.array_equal(echoed.tree["tokens"], tree["tokens"])
        assert np.array_equal(echoed.tree["embeds"]["v"],
                              tree["embeds"]["v"])
        assert np.array_equal(echoed.tree["mask"], tree["mask"])
        echoed.release()

        child = q.get(timeout=120)
        p.join(timeout=60)
        assert child["ok"], "child saw corrupted bytes"

        # -- counted proof: one payload copy per direction ------------------
        for side, tags, bts in (("server", eng.tagged_snapshot()["copies"],
                                 eng.tagged_snapshot()["bytes"]),
                                ("child", child["copies"], child["bytes"])):
            assert tags.get("heap_fill", 0) == 3, (side, tags)  # 3 leaves
            assert bts.get("heap_fill", 0) == nbytes, (side, bts)
            assert tags.get("recv_copy", 0) == 0, (side, tags)
            assert tags.get("heap_reassemble", 0) == 0, (side, tags)
        assert server.data.stats.heap_sends == 1
        assert server.data.stats.heap_recvs == 1
        assert child["stats"]["heap_sends"] == 1
        assert child["stats"]["heap_recvs"] == 1
    finally:
        set_engine(prev)
        server.close()
        eng.close()
