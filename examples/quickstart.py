"""Quickstart: build a model, train a few steps with the ROCKET input
pipeline, checkpoint, and generate.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource
from repro.models import build_model
from repro.optim import adamw
from repro.serve import BatchedServer, ServeConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main():
    # 1. pick an architecture (any of the 10 assigned ids; reduced config
    #    for CPU) and build the model
    cfg = get_smoke_config("granite-8b")
    model = build_model(cfg)
    params, opt_state = init_train_state(model, jax.random.key(0))
    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(params)):,}")

    # 2. train with the pipelined (ROCKET) input movement mode
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=5,
                                             total_steps=50))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    shape = ShapeConfig("quickstart", "train", 64, 8)
    pipeline = InputPipeline(
        SyntheticLMSource(cfg, shape, seed=0),
        OffloadPolicy(mode=ExecutionMode.PIPELINED, offload_threshold_bytes=1))
    for step, batch in zip(range(30), pipeline):
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 10 == 0:
            print(f"step {step:3d} loss {float(m['loss']):.4f}")
    pipeline.close()

    # 3. checkpoint + restore (mesh-agnostic, elastic)
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save_async(30, {"params": params, "opt": opt_state})
        cm.wait()
        restored, _ = cm.restore(30, {"params": params, "opt": opt_state})
        print("checkpoint roundtrip ok")
        params = restored["params"]

    # 4. serve: batched generation through the request dispatcher
    server = BatchedServer(model, params, ServeConfig(max_len=96,
                                                      max_new_tokens=8))
    with server.make_dispatcher() as dispatcher:
        jids = [dispatcher.request("generate",
                                   np.arange(5, dtype=np.int32) + i,
                                   mode="pipelined") for i in range(3)]
        outs = [dispatcher.query(j) for j in jids]
    print(f"generated: {[o.tolist() for o in outs]}")
    server.close()


if __name__ == "__main__":
    main()
