"""Serving example: a multi-client inference pipeline through the ROCKET
request dispatcher, comparing the paper's three execution modes end to end
(Fig. 10/11 scenario: clients submit requests, the server batches them).

  PYTHONPATH=src python examples/serve_pipeline.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import ExecutionMode, OffloadPolicy
from repro.models import build_model
from repro.serve import BatchedServer, ServeConfig


def run_mode(model, params, mode: str, requests: int, prompt_len: int,
             new_tokens: int) -> tuple[float, float]:
    scfg = ServeConfig(max_len=prompt_len + new_tokens,
                       max_batch=4, max_new_tokens=new_tokens)
    server = BatchedServer(model, params, scfg,
                           OffloadPolicy(mode=ExecutionMode(mode), max_batch=4))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab_size, prompt_len)
               .astype(np.int32) for _ in range(requests)]
    with server.make_dispatcher() as d:
        t0 = time.perf_counter()
        if mode == "sync":
            outs = [d.request("generate", p, mode="sync") for p in prompts]
        else:
            jids = [d.request("generate", p, mode=mode) for p in prompts]
            outs = [d.query(j) for j in jids]
        dt = time.perf_counter() - t0
        mean_batch = d.stats.mean_batch or 1.0
    server.close()
    total_tokens = sum(o.size for o in outs)
    return dt / requests * 1e3, mean_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.name} ({cfg.family}), {args.requests} requests, "
          f"{args.new_tokens} new tokens each\n")
    base = None
    for mode in ("sync", "async", "pipelined"):
        ms, mb = run_mode(model, params, mode, args.requests,
                          args.prompt_len, args.new_tokens)
        base = base or ms
        print(f"{mode:10s} {ms:8.1f} ms/req  speedup {base/ms:4.2f}x  "
              f"mean_batch {mb:.1f}")
    print("\n(async removes queueing from the caller's critical path; "
          "pipelined batches requests (mean_batch above) — on parallel "
          "accelerators batching amortizes weight reads per token, on this "
          "1-core CPU the batched compute scales linearly so the benefit "
          "shows in mean_batch, not wall time — the paper's Fig. 11 point "
          "that the best mode is workload- and hardware-dependent)")


if __name__ == "__main__":
    main()
