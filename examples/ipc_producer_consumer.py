"""Two real processes, one shared-memory transport: ROCKET IPC end-to-end.

A producer *process* generates synthetic LM batches and streams them through
the pre-mapped shm ring transport; this (consumer) process feeds them to the
ROCKET input pipeline, verifies determinism against an in-process source,
and demos the cross-process dispatcher (request/query over IPC).

  PYTHONPATH=src python examples/ipc_producer_consumer.py
"""
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core.dispatcher import RequestDispatcher
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource, make_source
from repro.ipc import tree_nbytes


def main():
    cfg = get_smoke_config("granite-8b")
    shape = ShapeConfig("ipc-demo", "train", 128, 512)
    policy = OffloadPolicy(mode=ExecutionMode.PIPELINED,
                           offload_threshold_bytes=1)

    # 1. producer process → shm ring → consumer pipeline
    print("spawning producer process (shared-memory transport)...")
    source = make_source(cfg, shape, source="ipc", seed=0, policy=policy)
    pipeline = InputPipeline(source, policy)
    reference = SyntheticLMSource(cfg, shape, seed=0)

    n_steps, nbytes = 20, 0
    t0 = time.perf_counter()
    for step in range(n_steps):
        batch = next(pipeline)
        nbytes += tree_nbytes({k: np.asarray(v) for k, v in batch.items()})
    dt = time.perf_counter() - t0
    print(f"consumed {n_steps} cross-process batches: "
          f"{nbytes / (1 << 20):.1f} MB in {dt:.2f}s "
          f"({nbytes / dt / (1 << 20):.0f} MB/s)")

    # determinism: the transport moves bytes, it never transforms them
    check = make_source(cfg, shape, source="ipc", seed=0, policy=policy)
    expect = next(iter(reference))
    got = next(iter(check))
    for k in expect:
        np.testing.assert_array_equal(got[k], expect[k])
    check.close()
    print("determinism: ipc batches byte-identical to in-process source ✓")

    stats = source._producer.transport.stats()
    ring = stats["rings"]["rx_data"]
    print(f"rx ring: consumed={ring['consumed']} polls={ring['polls']} "
          f"blocked={ring['blocked_wait_s'] * 1e3:.1f}ms "
          f"deferred={ring['deferred_sleep_s'] * 1e3:.1f}ms")
    pipeline.close()

    # 2. cross-process dispatcher: request/query over the transport
    #    (server here; the client would normally live in another process —
    #    see tests/test_ipc.py for the spawned-client version)
    from repro.ipc import DispatcherServer, RemoteDispatcherClient, \
        ShmTransport, TransportSpec

    print("\ndispatcher over IPC (paper Listing 1 across the boundary):")
    transport = ShmTransport.create(
        spec=TransportSpec(data_slot_bytes=1 << 20), policy=policy)
    dispatcher = RequestDispatcher(policy)
    dispatcher.register_handler("scale", lambda x: x * 2.0,
                                batch_fn=lambda xs: [x * 2.0 for x in xs])
    server = DispatcherServer(dispatcher, transport).start()

    client_t = ShmTransport.attach(transport.name, policy=policy)
    client = RemoteDispatcherClient(client_t)
    jids = [client.request("scale", np.full((1024,), i, np.float32),
                           mode="pipelined") for i in range(4)]
    outs = [client.query(j) for j in jids]
    assert all(float(o[0]) == 2.0 * i for i, o in enumerate(outs))
    print(f"pipelined request/query over shm: {len(jids)} jobs ok, "
          f"mean batch {dispatcher.stats.mean_batch:.1f}")

    client.close()
    client_t.close()
    server.close()
    dispatcher.close()
    transport.close()
    print("done.")


if __name__ == "__main__":
    main()
