"""The ROCKET core in isolation: calibrate the latency model, then drive the
async transfer engine and the tier-3 offload-copy kernel through the paper's
configuration space (mode × device × injection).

  PYTHONPATH=src python examples/offload_modes.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AsyncTransferEngine, ExecutionMode, LatencyModel,
                        OffloadPolicy, calibrate)
from repro.core.policy import Device
from repro.kernels import ops, ref


def main():
    # 1. per-node calibration (the paper's deployment-time profiling script)
    model = calibrate(lambda b: jax.block_until_ready(jax.device_put(b)),
                      sizes_bytes=(1 << 18, 1 << 20, 1 << 22), repeats=5)
    print(f"calibrated: L = {model.l_fixed_us:.1f}us "
          f"+ {model.alpha_us_per_mb:.2f}us/MB "
          f"(implied bw {model.bandwidth_gbps():.0f} GB/s, "
          f"rel std {model.rel_std:.0%})")

    # 2. tier-1: engine modes over a 16MB message stream
    buf = np.ones((4 << 20,), np.float32)
    print("\ntier-1 engine (16MB x 8 transfers):")
    for mode in ExecutionMode:
        pol = OffloadPolicy(mode=mode, offload_threshold_bytes=1,
                            pipeline_depth=3)
        with AsyncTransferEngine(pol, latency=model) as eng:
            t0 = time.perf_counter()
            jobs = [eng.submit(buf) for _ in range(8)]
            for j in jobs:
                j.get()
            dt = (time.perf_counter() - t0) / 8 * 1e3
            s = eng.stats
            print(f"  {mode.value:10s} {dt:7.2f} ms/transfer  "
                  f"offloaded={s.offloaded} polls={s.polls}")

    # 3. the size threshold (offload control): small stays inline
    pol = OffloadPolicy(mode=ExecutionMode.ASYNC,
                        offload_threshold_bytes=1 << 20)
    with AsyncTransferEngine(pol, latency=model) as eng:
        eng.submit(np.ones(64, np.float32)).get()       # 256B  -> inline
        eng.submit(np.ones(1 << 20, np.float32)).get()  # 4MB   -> offload
        print(f"\nthreshold: inline={eng.stats.inline} "
              f"offloaded={eng.stats.offloaded} (paper Table III 'Data Size')")

    # 4. tier-3: the DSA-analogue Pallas kernel (interpret mode on CPU)
    x = jax.random.normal(jax.random.key(0), (1024, 256))
    print("\ntier-3 offload_copy kernel (mode x injection):")
    for mode in ("sync", "async", "pipelined"):
        for inject in (False, True):
            pol = OffloadPolicy(mode=ExecutionMode(mode),
                                offload_threshold_bytes=1,
                                cache_injection=inject)
            y, total = ops.offload_copy(x, scale=2.0, policy=pol,
                                        inject=inject)
            yr, tr = ref.offload_copy(x, scale=2.0, inject=inject)
            ok = bool(jnp.allclose(y, yr, atol=1e-5))
            extra = f" fused_sum={float(total):.1f}" if inject else ""
            print(f"  mode={mode:10s} inject={str(inject):5s} "
                  f"allclose={ok}{extra}")


if __name__ == "__main__":
    main()
