"""Multi-client serving fabric: one server, N client processes, one batch.

Demonstrates the fabric end-to-end without a model (see
``tests/test_fabric.py::test_serve_over_ipc_context_manager`` for the
BatchedServer version):

1. the server opens a :class:`~repro.ipc.ServingFabric` — listener +
   reactor + one shared dispatcher — and registers a ``scale`` handler;
2. three client *processes* connect by rendezvous name, each getting a
   dedicated pre-mapped queue pair;
3. every client streams pipelined requests concurrently; requests from
   different processes landing inside the batching window are packed into
   one handler call (watch ``mean batch`` > 1) and the replies are
   demultiplexed back to the right client.

  PYTHONPATH=src python examples/ipc_multiclient_serve.py
"""
import multiprocessing as mp
import time

import numpy as np

from repro.core.dispatcher import RequestDispatcher
from repro.core.policy import OffloadPolicy
from repro.ipc import RemoteDispatcherClient, ServingFabric, TransportSpec

N_CLIENTS = 3
N_REQUESTS = 8


def client_main(name: str, marker: int) -> None:
    """One client process: connect, stream pipelined requests, verify."""
    client = RemoteDispatcherClient.connect(name, timeout_s=60)
    sent = [np.full((1024,), marker * 100 + i, np.float32)
            for i in range(N_REQUESTS)]
    jids = [client.request("scale", a, mode="pipelined") for a in sent]
    for a, jid in zip(sent, jids):
        out = client.query(jid, timeout=60)
        assert out.tobytes() == (a * 2.0).tobytes(), "reply was not mine!"
    print(f"client {marker}: {N_REQUESTS} pipelined requests ok "
          f"(replies byte-identical)")
    client.close()


def main():
    policy = OffloadPolicy(offload_threshold_bytes=1, max_batch=16)
    dispatcher = RequestDispatcher(policy, max_batch_wait_s=0.02)
    dispatcher.register_handler("scale", lambda x: x * 2.0,
                                batch_fn=lambda xs: [x * 2.0 for x in xs])

    spec = TransportSpec(data_slots=4, data_slot_bytes=1 << 20)
    with ServingFabric(dispatcher, spec=spec, policy=policy,
                       own_dispatcher=True).start() as fabric:
        print(f"fabric up at {fabric.name!r}; spawning {N_CLIENTS} clients")
        ctx = mp.get_context("spawn")
        procs = [ctx.Process(target=client_main, args=(fabric.name, m))
                 for m in range(N_CLIENTS)]
        t0 = time.perf_counter()
        for p in procs:
            p.start()
        for p in procs:
            p.join(timeout=120)
            assert p.exitcode == 0, f"client failed: {p.exitcode}"
        dt = time.perf_counter() - t0

        stats = fabric.stats()
        print(f"served {stats['dispatcher']['requests']} requests from "
              f"{stats['accepted']} processes in {dt:.2f}s — "
              f"mean batch {stats['dispatcher']['mean_batch']:.1f}, "
              f"reactor sweeps {stats['reactor']['sweeps']}")
    print("fabric torn down (one with-block). done.")


if __name__ == "__main__":
    main()
