"""End-to-end training driver example: a llama-style LM trained for a few
hundred steps on the synthetic induction corpus, with checkpointing,
restart-on-failure, straggler monitoring and ROCKET input movement.

CPU-friendly default (~12M params). ``--preset 100m`` selects the ~100M
configuration (same code path; budget minutes-per-step on one CPU core).

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 5
"""
import argparse
import dataclasses
import os
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource
from repro.ft import RestartManager, StragglerMonitor
from repro.models import build_model
from repro.optim import adamw
from repro.train import TrainConfig, init_train_state, make_train_step

PRESETS = {
    "12m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1024, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="12m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/example_lm")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"example-lm-{args.preset}", family="dense",
                      dtype="float32", param_dtype="float32", remat=False,
                      **PRESETS[args.preset])
    model = build_model(cfg)
    shape = ShapeConfig("train", "train", args.seq, args.batch)

    tcfg = TrainConfig(opt=adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    pipeline = InputPipeline(
        SyntheticLMSource(cfg, shape, seed=0),
        OffloadPolicy(mode=ExecutionMode.PIPELINED, offload_threshold_bytes=1))
    cm = CheckpointManager(args.ckpt_dir)
    rm = RestartManager(cm, save_every=100)
    mon = StragglerMonitor()

    params, opt_state = init_train_state(model, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch}x{args.seq}")

    start = cm.latest_step() or 0
    if start:
        state, extra = cm.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        if "data" in extra:
            pipeline.restore(extra["data"])
        print(f"resumed from step {start}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        ts = time.perf_counter()
        batch = next(pipeline)
        params, opt_state, m = step_fn(params, opt_state, batch)
        mon.record_step(time.perf_counter() - ts, step)
        rm.maybe_save(step + 1, {"params": params, "opt": opt_state},
                      {"data": pipeline.state()})
        if step % 25 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - ts
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} {dt*1e3:7.1f} ms "
                  f"{shape.tokens_per_step/dt:8.0f} tok/s", flush=True)
    cm.wait()
    total = time.perf_counter() - t0
    print(f"done in {total:.1f}s; engine stats: "
          f"{pipeline.engine.stats.snapshot()}")
    if mon.events:
        print(f"straggler events: {len(mon.events)}")
    pipeline.close()


if __name__ == "__main__":
    main()
