"""Fig. 16 analogue: chaos soak — crash recovery + corruption containment.

The robustness claim behind the fault-injection plane: a serving fabric
under a *seeded, replayable* fault schedule loses **zero** requests and
duplicates **zero** replies, and its crash wreckage (orphaned shared
memory, stranded bulk-heap extents) is reclaimed and counted — recovery
costs wall-clock, never correctness.  Two sub-benches witness it:

- ``fig16/crash`` — a :class:`~repro.ft.supervisor.FabricSupervisor`
  runs the fabric in a child process with ``worker.crash`` armed to
  fire mid-soak (hard ``os._exit`` while a request batch drains).  The
  client keeps issuing sync requests through the death: heartbeat
  staleness trips :meth:`~repro.ipc.worker.RemoteDispatcherClient.reconnect`,
  the supervisor reclaims the orphaned segments and restarts the fabric
  under the same rendezvous name, and the unacked request replays with
  its idempotent id.  Reported: goodput over the whole soak (crash
  included), recovery time (the worst single-request latency — the one
  that spanned the crash), restarts, segments reclaimed, and the gated
  identities ``lost_replies``/``dup_replies``/``leaked_arenas``.

- ``fig16/corrupt`` — in-process fabric with ``meta_checksum`` on and a
  plane that corrupts one wire meta (CRC quarantine → counted
  ``corrupt_drops``, request resubmitted under its dedup id) and leaks
  one bulk-heap extent (suppressed free → force-reap reclaims it).
  Gated: ``lost_replies``/``dup_replies``/``leaked_extents``.

All four gate tokens carry **zero slack** in ``run.py CHECKED_METRICS``:
they are correctness identities, not timings — any nonzero value is a
reliability regression, and CI fails on it.

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig16``
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.ft import inject as _inject
from repro.ft.inject import FaultPlane, FaultSpec
from repro.ft.supervisor import SHM_DIR, FabricSupervisor

NAME = "rocket-fig16"
SEED = 16
N_REQS = 30                    # soak length (sync requests per sub-bench)
CRASH_AT = 12                  # worker.crash fires on this drained batch
D = 256                        # request payload width (1KB — stays inline)
# fast failure detection for a benchmark-sized soak: the client declares
# the server dead after 0.4s of heartbeat silence and retries quickly
RETRY = RetryPolicy(heartbeat_interval_s=0.1, heartbeat_stale_s=0.4,
                    connect_timeout_s=10.0, max_reconnects=8)


def _soak(client, n: int) -> dict:
    """Issue ``n`` sync requests, validating every reply; returns mean/max
    latency and goodput over the whole window (faults included)."""
    vec = np.arange(D, dtype=np.float32)
    lat_max = total = 0.0
    t0 = time.perf_counter()
    for _ in range(n):
        t = time.perf_counter()
        out = client.request("double", vec, mode="sync")
        dt = time.perf_counter() - t
        total += dt
        lat_max = max(lat_max, dt)
        if not np.allclose(out, vec * 2):
            raise AssertionError("corrupted reply payload")
    wall = time.perf_counter() - t0
    return {"mean_us": total / n * 1e6, "max_ms": lat_max * 1e3,
            "goodput_rps": n / wall}


def _crash_bench():
    """Supervised child fabric killed mid-soak; client rides it out."""
    from repro.ipc.worker import RemoteDispatcherClient

    policy = OffloadPolicy(mode="pipelined", retry=RETRY)
    plane = FaultPlane(SEED, {"worker.crash": FaultSpec(at=(CRASH_AT,))})
    sup = FabricSupervisor(NAME, "repro.ft.supervisor:echo_fabric_factory",
                           policy=policy, max_restarts=3,
                           plane_json=plane.spec_json()).start()
    try:
        if not sup.wait_alive(30.0):
            raise RuntimeError("supervised fabric never came up")
        client = RemoteDispatcherClient.connect(NAME, policy=policy)
        try:
            m = _soak(client, N_REQS)
            lost, dup = client.lost_replies, client.dup_replies
            reconnects = client.reconnects
        finally:
            client.close()
    finally:
        sup.close()            # terminates the child, reclaims segments
    leaked = len([f for f in os.listdir(SHM_DIR) if f.startswith(NAME)])
    s = sup.stats()
    if s["crashes"] < 1:
        raise RuntimeError("chaos schedule never fired worker.crash")
    return fmt_row(
        "fig16/crash", m["mean_us"],
        f"goodput={m['goodput_rps']:.0f}rps;recovery_ms={m['max_ms']:.0f};"
        f"crashes={s['crashes']};restarts={s['restarts']};"
        f"reclaimed={s['arenas_reclaimed'] + s['heaps_reclaimed']};"
        f"reconnects={reconnects};"
        f"lost_replies={lost};dup_replies={dup};leaked_arenas={leaked}")


def _corrupt_bench():
    """In-process fabric: one corrupted wire meta (CRC quarantine) + one
    leaked heap extent (suppressed free), both repaired and counted."""
    from repro.core.dispatcher import RequestDispatcher
    from repro.ipc.worker import RemoteDispatcherClient, ServingFabric

    policy = OffloadPolicy(mode="pipelined", meta_checksum=True,
                           heap_threshold_bytes=1 << 16, retry=RETRY)
    plane = FaultPlane(SEED, {
        "channel.meta.corrupt": FaultSpec(rate=1.0, max_fires=1),
        "heap.leak": FaultSpec(rate=1.0, max_fires=1),
    })
    _inject.install(plane)
    try:
        dispatcher = RequestDispatcher(policy)
        dispatcher.register_handler("double", lambda x: x * 2)
        fabric = ServingFabric(dispatcher, policy=policy,
                               own_dispatcher=True).start()
        try:
            client = RemoteDispatcherClient.connect(fabric.name,
                                                    policy=policy)
            try:
                m = _soak(client, N_REQS)
                # one large payload rides the bulk heap; its free is the
                # suppressed one (heap.leak) — a datable stranded extent
                big = np.ones(1 << 17, np.uint8)
                out = client.request("double", big, mode="sync")
                if not np.all(out == 2):
                    raise AssertionError("corrupted heap reply")
                lost, dup = client.lost_replies, client.dup_replies
                retries = client.retries
            finally:
                client.close()
            conns = fabric._all_connections()
            drops = sum(c.transport.data.stats.corrupt_drops
                        for c in conns)
            # crash-reap the stranded extent (the reactor does the same
            # force-reap when it tears a dead connection down) and count
            # what is still allocated afterwards — the gated leak
            reaped = leaked = 0
            for c in conns:
                heap = c.transport.heap
                if heap is None:
                    continue
                reaped += c.transport.reap_heap(force=True)
                leaked += sum(
                    heap.spec.n_extents - heap.free_extents(d)
                    for d in (heap.tx_dir, heap.rx_dir))
        finally:
            fabric.close()
    finally:
        _inject.uninstall()
    if plane.fired("channel.meta.corrupt") != 1 or drops < 1:
        raise RuntimeError("corruption schedule never fired/quarantined")
    if plane.fired("heap.leak") != 1:
        raise RuntimeError("heap-leak schedule never fired")
    return fmt_row(
        "fig16/corrupt", m["mean_us"],
        f"goodput={m['goodput_rps']:.0f}rps;corrupt_drops={drops};"
        f"retries={retries};heap_reaped={reaped};"
        f"lost_replies={lost};dup_replies={dup};leaked_extents={leaked}")


def run():
    yield _crash_bench()
    yield _corrupt_bench()
