"""Paper Fig. 12: latency decomposition across device × mode
(sync_inline -> sync_offload -> async_offload -> pipelined_offload), using
the engine's instrumentation to attribute produce / wait / overlap."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core import AsyncTransferEngine, ExecutionMode, OffloadPolicy
from repro.core.policy import Device

STEPS = 10
MB = 16


def _variant(name: str, pol: OffloadPolicy, sim: bool = False) -> str:
    from benchmarks.common import simulated_dsa_put
    from repro.core import LatencyModel
    buf = np.ones(MB * (1 << 20) // 4, np.float32)
    model = LatencyModel(l_fixed_us=50.0, alpha_us_per_mb=33.4)
    kwargs = dict(put_fn=simulated_dsa_put(model), stage=False,
                  latency=model) if sim else {}
    with AsyncTransferEngine(pol, **kwargs) as eng:
        t0 = time.perf_counter()
        pending = []
        for _ in range(STEPS):
            pending.append(eng.submit(buf))
            # handler compute that async modes can overlap
            acc = 0.0
            for _ in range(50):
                acc += float(np.sum(buf[:4096]))
        for j in pending:
            j.get()
        total = (time.perf_counter() - t0) / STEPS * 1e6
        s = eng.stats
        return fmt_row(
            f"fig12/{name}", total,
            f"wait_ms={s.blocked_wait_s * 1e3 / STEPS:.2f};"
            f"deferred_ms={s.deferred_sleep_s * 1e3 / STEPS:.2f};"
            f"offloaded={s.offloaded}")


def run() -> list[str]:
    rows = []
    for sim, tag in ((False, "realcopy_1core"), (True, "simdsa")):
        rows += [
            _variant(f"{tag}/sync_inline", OffloadPolicy(
                mode=ExecutionMode.SYNC, device=Device.INLINE), sim),
            _variant(f"{tag}/sync_offload", OffloadPolicy(
                mode=ExecutionMode.SYNC, offload_threshold_bytes=1), sim),
            _variant(f"{tag}/async_offload", OffloadPolicy(
                mode=ExecutionMode.ASYNC, offload_threshold_bytes=1), sim),
            _variant(f"{tag}/pipelined_offload", OffloadPolicy(
                mode=ExecutionMode.PIPELINED, offload_threshold_bytes=1,
                pipeline_depth=4), sim),
        ]
    return rows
