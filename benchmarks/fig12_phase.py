"""Traced phase decomposition of the serving datapath (fig12 companion).

Answers "where do the microseconds of one served request actually go?"
by running the *same* cross-client serving workload as
``benchmarks/fig13_copy_path.py`` (k client processes streaming 4 MB
pipelined requests into one fabric) with the :mod:`repro.obs` tracer
enabled, A/B over ``zero_copy_serving`` — the two datapaths behind the
recorded ``fig13copy/zerocopy_speedup`` row.

Every process involved (server fabric, spawned clients) writes spans into
its own shared-memory trace ring; after the sweep the measurement child
collects all rings of its session into one timeline and reduces them to
per-phase log-bucket histograms (:func:`repro.obs.hist.phase_histograms`).
The emitted rows give per-request µs for each phase of both modes, plus a
``diagnosis`` row naming the phases where the 2-copy baseline *beats* the
single-copy path — the written explanation for a sub-1x speedup row.

This module must stay jax-free: the measurement runs in a spawn child
that imports only this module + numpy + repro (see fig13_copy_path).

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig12phase``
"""
from __future__ import annotations

import multiprocessing as mp
import traceback

from benchmarks.fig13_copy_path import CLIENTS, N_PER_CLIENT, _serve, fmt_row

#: phases shown per mode (by total time); the rest still count toward the
#: coverage figure but would drown the CSV in near-zero rows
TOP_PHASES = 8


def _measure_entry(out_q) -> None:
    """Spawn-child main: warm up untraced, then trace one serving sweep
    per datapath mode under a fresh trace session each."""
    try:
        from repro.obs import trace as obs_trace
        from repro.obs.hist import phase_histograms

        _serve(True)                       # warmup: page cache, spawn tails
        out = {}
        for zero_copy in (True, False):
            obs_trace.enable()             # fresh session: clean ring set
            wall, _copies, _dbytes, mean_batch, _prof = _serve(zero_copy)
            view = obs_trace.collect(unlink=True)
            obs_trace.disable()
            out["zerocopy" if zero_copy else "baseline"] = {
                "wall_s": wall,
                "mean_batch": mean_batch,
                "records": view.total_records,
                "drops": view.total_drops,
                "phases": {name: h.to_dict()
                           for name, h in phase_histograms(view).items()},
            }
        out_q.put(("ok", out))
    except BaseException:
        out_q.put(("err", traceback.format_exc()))


def _per_req_us(mode: dict) -> dict:
    """Phase name -> µs per request (histogram totals / request count)."""
    n = CLIENTS * N_PER_CLIENT
    return {name: d["total"] / 1e3 / n for name, d in mode["phases"].items()}


def run():
    """Yield CSV rows: per-mode e2e + per-phase µs/req, then the
    diagnosis row naming where the baseline beats zero-copy."""
    total = CLIENTS * N_PER_CLIENT
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    proc = ctx.Process(target=_measure_entry, args=(out_q,))
    proc.start()
    status, payload = out_q.get(timeout=600)
    proc.join(timeout=60)
    if status != "ok":
        raise RuntimeError(f"fig12phase measurement child failed:\n{payload}")

    for tag in ("zerocopy", "baseline"):
        mode = payload[tag]
        e2e_us = mode["wall_s"] / total * 1e6
        per_req = _per_req_us(mode)
        # server-side phases only: client send/wait overlap the server
        # pipeline, so summing them against wall clock double-counts
        server = {k: v for k, v in per_req.items()
                  if not k.startswith(("client.", "query."))}
        yield fmt_row(
            f"fig12phase/{tag}", e2e_us,
            f"{mode['records']}records;drops={mode['drops']};"
            f"batch{mode['mean_batch']:.1f};"
            f"server_phase_us={sum(server.values()):.0f}")
        for name in sorted(per_req, key=lambda k: -per_req[k])[:TOP_PHASES]:
            d = mode["phases"][name]
            yield fmt_row(
                f"fig12phase/{tag}/{name}", per_req[name],
                f"n={d['n']};mean_us={d['total'] / 1e3 / max(d['n'], 1):.1f}")

    # the diagnosis: per-phase µs/req delta, zerocopy minus baseline —
    # positive = the single-copy datapath spends MORE here than the
    # 2-copy baseline (the phases a sub-1x speedup row comes from)
    zc, bl = _per_req_us(payload["zerocopy"]), _per_req_us(payload["baseline"])
    delta = {k: zc.get(k, 0.0) - bl.get(k, 0.0) for k in set(zc) | set(bl)
             if not k.startswith(("client.", "query."))}
    losses = sorted(((v, k) for k, v in delta.items() if v > 0), reverse=True)
    wins = sorted(((-v, k) for k, v in delta.items() if v < 0), reverse=True)
    loss_s = ";".join(f"{k}+{v:.0f}us/req" for v, k in losses[:3]) or "none"
    win_s = ";".join(f"{k}-{v:.0f}us/req" for v, k in wins[:2]) or "none"
    yield fmt_row("fig12phase/diagnosis", 0.0,
                  f"zerocopy_loses:{loss_s}|zerocopy_wins:{win_s}")
