"""Paper Fig. 10: execution modes × workloads — end-to-end training
throughput under sync / async / pipelined input movement, for a dense and a
MoE workload (the CPU-runnable analogues of the paper's five pipelines)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import fmt_row
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import ExecutionMode, OffloadPolicy
from repro.data import InputPipeline, SyntheticLMSource
from repro.models import build_model
from repro.optim import adamw
from repro.train import TrainConfig, init_train_state, make_train_step

STEPS = 12


def _throughput(arch: str, mode: str) -> tuple[float, float]:
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, opt_state = init_train_state(model, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, TrainConfig(
        opt=adamw.AdamWConfig(warmup_steps=2, total_steps=STEPS))),
        donate_argnums=(0, 1))
    shape = ShapeConfig("b", "train", 64, 8)
    pol = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1,
                        pipeline_depth=3)
    pipe = InputPipeline(SyntheticLMSource(cfg, shape, seed=0), pol)
    # warmup/compile
    params, opt_state, _ = step_fn(params, opt_state, next(pipe))
    t0 = time.perf_counter()
    for _, batch in zip(range(STEPS), pipe):
        params, opt_state, m = step_fn(params, opt_state, batch)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    pipe.close()
    toks = STEPS * shape.tokens_per_step
    return dt / STEPS * 1e6, toks / dt


def run() -> list[str]:
    rows = []
    for arch in ("granite-8b", "granite-moe-1b-a400m"):
        base = None
        for mode in ("sync", "async", "pipelined"):
            us, tput = _throughput(arch, mode)
            base = base or tput
            rows.append(fmt_row(f"fig10/{arch}/{mode}", us,
                                f"tok_s={tput:.0f};speedup={tput / base:.2f}x"))
    return rows
