"""Paper Fig. 3: polling strategies on completion latency + CPU usage
(busy-poll vs lazy 100µs poll vs the hybrid size-aware deferral)."""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core.latency import LatencyModel

WORK_US = 2000.0      # simulated engine completion time


def _job():
    done = threading.Event()
    t = threading.Timer(WORK_US * 1e-6, done.set)
    t.start()
    return done


def _measure(poll_fn, iters=20):
    lats, polls = [], 0
    for _ in range(iters):
        t0 = time.perf_counter()
        done = _job()
        polls += poll_fn(done)
        lats.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(lats)), polls / iters


def run() -> list[str]:
    rows = []

    def busy(done):
        n = 0
        while not done.is_set():
            n += 1
        return n

    def lazy(done):           # poll every 100us
        n = 0
        while not done.is_set():
            n += 1
            done.wait(100e-6)
        return n

    def hybrid(done):         # paper: sleep 0.95*L, then short passive waits
        model = LatencyModel(l_fixed_us=WORK_US, alpha_us_per_mb=0.0)
        time.sleep(model.defer_seconds(0))
        n = 0
        while not done.is_set():
            n += 1
            done.wait(25e-6)
        return n

    for name, fn in (("busypoll", busy), ("lazypoll", lazy),
                     ("hybrid", hybrid)):
        lat, polls = _measure(fn)
        over = lat - WORK_US
        rows.append(fmt_row(f"fig3/{name}", lat,
                            f"overshoot_us={over:.0f};polls={polls:.0f}"))
    return rows
