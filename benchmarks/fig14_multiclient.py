"""Fig. 14 analogue: aggregate serving throughput vs client count.

The paper's 2.1x-throughput claim rests on a server batching requests from
*many* concurrent clients.  This sweep measures exactly that on the
multi-client fabric: k client *processes* connect to one
:class:`~repro.ipc.ServingFabric`, each keeps a small fixed number of
pipelined requests in flight (an interactive client's concurrency), and the
server packs whatever arrived inside the batching window — so the achieved
batch size, and with it the throughput, grows with the client count.

The ``step`` handler has decode-step cost structure: a *fixed* per-call
latency (memory-bound decode streams every weight once regardless of batch
rows — simulated as a calibrated sleep, same rationale as
``common.simulated_dsa_put``: on a 2-core CI box a real weight-sized matmul
fights the client processes for cores and the scheduling noise swamps the
effect under study) plus a real per-row numpy term for the activations.
Expect aggregate req/s to scale ≥1.5x going 1→4 clients; the per-client
request count is constant, so scaling comes entirely from batch formation.

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig14``
"""
from __future__ import annotations

import multiprocessing as mp
import time
from collections import deque

import numpy as np

from benchmarks.common import fmt_row

CLIENT_COUNTS = (1, 2, 4)
N_PER_CLIENT = 48            # requests each client issues
CLIENT_DEPTH = 2             # outstanding requests per client (interactive)
D_MODEL = 384                # activation width (the real per-row term)
FIXED_CALL_S = 0.020         # per-call weight-streaming latency (simulated)
# coarse poll quanta: the sweep runs k+1 processes on whatever cores the CI
# box has, so idle waits must be cheap — latency is dominated by the ~20ms
# handler anyway
_POLL_US = {"server": 500.0, "client": 1000.0}


def _client_entry(name: str, n: int, out_q) -> None:
    """One client process: gate, then stream n depth-bounded requests."""
    from repro.core.policy import OffloadPolicy
    from repro.ipc import RemoteDispatcherClient

    # default offload threshold: the ~1.5KB request payloads stay inline
    # (no per-client engine thread burning the contended cores)
    policy = OffloadPolicy(poll_interval_us=_POLL_US["client"])
    client = RemoteDispatcherClient.connect(name, policy=policy, timeout_s=60)
    vec = np.ones((D_MODEL,), np.float32)
    while int(client.request("gate", vec[:1], mode="sync")[0]) == 0:
        time.sleep(0.002)
    t0 = time.time()                       # wall clock: comparable cross-process
    outstanding: deque = deque()
    for _ in range(n):
        outstanding.append(client.request("step", vec, mode="pipelined"))
        if len(outstanding) >= CLIENT_DEPTH:
            client.query(outstanding.popleft(), timeout=60)
    while outstanding:
        client.query(outstanding.popleft(), timeout=60)
    out_q.put((t0, time.time()))
    client.close()


def _serve_k_clients(k: int) -> tuple[float, float]:
    """Run the sweep point; returns (wall seconds, mean server batch)."""
    from repro.core.dispatcher import RequestDispatcher
    from repro.core.policy import OffloadPolicy
    from repro.ipc import ServingFabric, TransportSpec

    rng = np.random.default_rng(0)
    weights = rng.standard_normal((D_MODEL, D_MODEL)).astype(np.float32)
    gate = [0.0]

    def step_batch(xs: list[np.ndarray]) -> list[np.ndarray]:
        time.sleep(FIXED_CALL_S)           # fixed per-call cost (the weights)
        out = np.stack(xs) @ weights       # per-row term (the activations)
        return [out[i] for i in range(len(xs))]

    # max_batch = the server's configured batch capacity: when every
    # client's outstanding requests are in (4 clients x depth 2), the batch
    # closes immediately instead of waiting out the window
    policy = OffloadPolicy(offload_threshold_bytes=1,
                           max_batch=CLIENT_COUNTS[-1] * CLIENT_DEPTH,
                           poll_interval_us=_POLL_US["server"])
    dispatcher = RequestDispatcher(policy, max_batch_wait_s=0.010)
    dispatcher.register_handler("gate", lambda x: np.float32(gate[0]) + x)
    dispatcher.register_handler("step", lambda x: step_batch([x])[0],
                                batch_fn=step_batch)
    spec = TransportSpec(data_slots=8, data_slot_bytes=1 << 20,
                         ctrl_slots=4, ctrl_slot_bytes=16 << 10)
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    with ServingFabric(dispatcher, spec=spec, policy=policy,
                       own_dispatcher=True).start() as fabric:
        procs = [ctx.Process(target=_client_entry,
                             args=(fabric.name, N_PER_CLIENT, out_q),
                             daemon=True)
                 for _ in range(k)]
        for p in procs:
            p.start()
        while fabric.listener.accepted < k:
            time.sleep(0.005)
        gate[0] = 1.0                      # all connected: release together
        spans = [out_q.get(timeout=120) for _ in procs]
        for p in procs:
            p.join(timeout=60)
        mean_batch = fabric.dispatcher.stats.mean_batch
    wall = max(t1 for _, t1 in spans) - min(t0 for t0, _ in spans)
    return wall, mean_batch


def run():
    """Yield one CSV row per client count plus the 1→4 scaling factor."""
    rps = {}
    for k in CLIENT_COUNTS:
        wall, mean_batch = _serve_k_clients(k)
        total = k * N_PER_CLIENT
        rps[k] = total / wall
        yield fmt_row(f"fig14/clients{k}", wall / total * 1e6,
                      f"{rps[k]:.0f}req/s batch{mean_batch:.1f}")
    lo, hi = CLIENT_COUNTS[0], CLIENT_COUNTS[-1]
    yield fmt_row(f"fig14/scaling_{lo}to{hi}", 0.0,
                  f"{rps[hi] / rps[lo]:.2f}x")
