"""Paper Fig. 13: normalized instruction/cycle counts per movement mode.

CPU-instruction analogue: host-side busy time (produce + blocked wait) per
step and completion-check count from the engine instrumentation, normalized
to the synchronous baseline — the same counters the paper reads from perf."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core import AsyncTransferEngine, ExecutionMode, OffloadPolicy

STEPS = 12
MB = 16


def _measure(mode: str, sim: bool = False):
    from benchmarks.common import simulated_dsa_put
    from repro.core import LatencyModel
    pol = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1,
                        pipeline_depth=4)
    buf = np.ones(MB * (1 << 20) // 4, np.float32)
    model = LatencyModel(l_fixed_us=50.0, alpha_us_per_mb=33.4)
    kwargs = dict(put_fn=simulated_dsa_put(model), stage=False,
                  latency=model) if sim else {}
    with AsyncTransferEngine(pol, **kwargs) as eng:
        busy = 0.0
        pending = []
        for _ in range(STEPS):
            t0 = time.perf_counter()
            pending.append(eng.submit(buf))
            busy += time.perf_counter() - t0
            acc = 0.0                         # overlap-able handler work
            for _ in range(30):
                acc += float(np.sum(buf[:4096]))
        t0 = time.perf_counter()
        for j in pending:
            j.get()
        busy += time.perf_counter() - t0
        return busy / STEPS * 1e6, eng.stats.polls


def run() -> list[str]:
    rows = []
    for sim, tag in ((False, "realcopy_1core"), (True, "simdsa")):
        base_busy = None
        for mode in ("sync", "async", "pipelined"):
            busy_us, polls = _measure(mode, sim=sim)
            base_busy = base_busy or busy_us
            rows.append(fmt_row(
                f"fig13/{tag}/{mode}", busy_us,
                f"normalized_busy={busy_us / base_busy:.2f};polls={polls}"))
    return rows
