"""Paper Fig. 13: normalized instruction/cycle counts per movement mode.

Two witnesses for the paper's per-mode instruction claim, never
conflated:

- ``witness=timed`` rows — the original host-side busy-time analogue
  (produce + blocked wait per step) plus the engine's completion-poll
  count, normalized to the synchronous baseline.  Kept as the explicit
  fallback: it runs everywhere and tracks the same quantity the paper's
  perf numbers move with, but it is *wall clock*, not instructions.
- ``witness=<tier>`` rows (``fig13/hw/<mode>``) — real readings from
  :mod:`repro.obs.hwcounters` metered around exactly the same busy
  sections: retired instructions per step on a `perf-hw` host,
  cpu-ns + context switches per step on the `perf-sw`/`rusage`
  fallback tiers.  On tier `none` a single ``fig13/hw/unavailable``
  row is emitted — counted, not silent.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import counter_meter, fmt_row
from repro.core import AsyncTransferEngine, ExecutionMode, OffloadPolicy

STEPS = 12
MB = 16


def _measure(mode: str, sim: bool = False):
    """One mode's sweep; returns (busy_us/step, polls, meter) where the
    meter accumulated hardware counters over the same busy sections the
    timed analogue measures."""
    from benchmarks.common import simulated_dsa_put
    from repro.core import LatencyModel
    pol = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1,
                        pipeline_depth=4)
    buf = np.ones(MB * (1 << 20) // 4, np.float32)
    model = LatencyModel(l_fixed_us=50.0, alpha_us_per_mb=33.4)
    kwargs = dict(put_fn=simulated_dsa_put(model), stage=False,
                  latency=model) if sim else {}
    meter = counter_meter()
    with AsyncTransferEngine(pol, **kwargs) as eng:
        busy = 0.0
        pending = []
        for _ in range(STEPS):
            t0 = time.perf_counter()
            with meter:
                pending.append(eng.submit(buf))
            busy += time.perf_counter() - t0
            acc = 0.0                         # overlap-able handler work
            for _ in range(30):
                acc += float(np.sum(buf[:4096]))
        t0 = time.perf_counter()
        with meter:
            for j in pending:
                j.get()
        busy += time.perf_counter() - t0
        return busy / STEPS * 1e6, eng.stats.polls, meter


def _hw_tokens(meter) -> tuple[float, str]:
    """(per-step witness value, token string) from a mode's meter.

    The normalized column uses instructions when the tier counts them,
    else task-clock ns — whichever the witness actually measured."""
    t = meter.totals
    insn = t.get("instructions", 0)
    clk = t.get("task_clock_ns", 0)
    csw = t.get("ctx_sw", 0)
    toks = []
    if insn:
        toks.append(f"insn/step={insn / STEPS:.0f}")
    if clk:
        toks.append(f"cpu_us/step={clk / 1e3 / STEPS:.1f}")
    toks.append(f"ctx_sw/step={csw / STEPS:.2f}")
    val = float(insn if insn else clk)
    return val, ";".join(toks)


def run() -> list[str]:
    """Yield the timed-analogue rows and the counter-witnessed rows."""
    rows = []
    meters = {}
    for sim, tag in ((False, "realcopy_1core"), (True, "simdsa")):
        base_busy = None
        for mode in ("sync", "async", "pipelined"):
            busy_us, polls, meter = _measure(mode, sim=sim)
            if not sim:
                meters[mode] = meter
            base_busy = base_busy or busy_us
            rows.append(fmt_row(
                f"fig13/{tag}/{mode}", busy_us,
                f"normalized_busy={busy_us / base_busy:.2f};polls={polls};"
                f"witness=timed"))
    # hardware-witnessed rows for the real-copy sweep: same busy
    # sections, counted instead of timed
    tier = next(iter(meters.values())).tier if meters else "none"
    if tier == "none":
        rows.append(fmt_row("fig13/hw/unavailable", 0.0,
                            "no counter tier on this host;witness=none"))
    else:
        base_val = None
        for mode in ("sync", "async", "pipelined"):
            val, toks = _hw_tokens(meters[mode])
            base_val = base_val or val or 1.0
            rows.append(fmt_row(
                f"fig13/hw/{mode}", 0.0,
                f"normalized={val / base_val:.2f};{toks};witness={tier}"))
    for m in meters.values():
        m.close()
    return rows
