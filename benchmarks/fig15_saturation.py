"""Fig. 15 analogue: SLO saturation sweep — per-lane latency, goodput, sheds.

The SLO serving claim: under saturating offered load a lane-ordered server
keeps its *priority* lane inside its deadline by shedding the best-effort
lane, and sharding the drain loop raises the saturation ceiling.  This
sweep measures exactly that on the multi-client fabric: 4 client processes
(2 per lane) open-loop-pace pipelined requests at a configured offered
load, every request carrying ``(priority, deadline_ms)`` wire meta, against
a 2-shard :class:`~repro.ipc.ServingFabric` whose dispatcher runs a
matching worker pool.

The ``work`` handler has the same decode-step cost structure as fig14: a
*fixed* per-call sleep (weight streaming — simulated for the same reason as
``common.simulated_dsa_put``: on a small CI box real matmuls fight the
client processes for cores) so one worker's capacity is exactly
``MAX_BATCH / FIXED_CALL_S`` req/s and "2x offered load" means something.

Per sweep point and lane the row reports server-side p50/p99 service time
(reactor delivery → reply), goodput-at-deadline (completed on time / wall),
and the counted shed/miss totals.  Two extra rows carry the *counted,
timing-independent* CI gates (see ``run.py CHECKED_METRICS``):

- ``fig15/accounting`` — ``slo_lost/req`` (every submitted request got
  exactly one reply: ok, shed, or error — 0 by construction unless the
  reply path drops one) and ``shed_drift`` (server-counted sheds ==
  client-observed shed errors — sheds are *counted* replies, never silent);
- ``fig15/shards_1to2`` — aggregate goodput ratio of 2 reactor shards
  (+ 2 dispatcher workers) over 1 at the 2x point, the sharding headline
  (timing-derived, so recorded but not gated).

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig15``
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np

from benchmarks.common import fmt_row

D_MODEL = 256                  # request payload width (1KB — stays inline)
FIXED_CALL_S = 0.040           # per-batch weight-streaming latency (simulated)
MAX_BATCH = 4                  # server batch capacity
CAP1 = MAX_BATCH / FIXED_CALL_S      # one worker's capacity, req/s (= 100)
SWEEP_S = 2.5                  # paced send window per point
# lane plan: lane 0 (priority) offers 30% of the load with a roomier
# deadline, lane 1 (best effort) 70% with a tight one — at 2x the lane-0
# share stays under capacity, so lane ordering + shedding keeps it on SLO
LANES = (
    {"lane": 0, "share": 0.30, "deadline_ms": 250.0, "clients": 2},
    {"lane": 1, "share": 0.70, "deadline_ms": 150.0, "clients": 2},
)
_POLL_US = {"server": 500.0, "client": 1000.0}


def _client_entry(name: str, lane: int, interval_s: float, n: int,
                  deadline_ms: float, out_q) -> None:
    """One client: gate, then open-loop-pace n pipelined SLO requests."""
    from repro.core.policy import OffloadPolicy
    from repro.ipc import RemoteDispatcherClient

    policy = OffloadPolicy(poll_interval_us=_POLL_US["client"])
    client = RemoteDispatcherClient.connect(name, policy=policy,
                                            timeout_s=60, lane=lane)
    submitted = replies = 0
    vec = np.ones((D_MODEL,), np.float32)
    while True:
        submitted += 1
        gate_open = int(client.request("gate", vec[:1], mode="sync")[0])
        replies += 1                   # sync gate polls are replies too
        if gate_open != 0:
            break
        time.sleep(0.002)
    t0 = time.time()                   # wall clock: comparable cross-process
    jobs = []
    next_t = time.perf_counter()
    for _ in range(n):
        now = time.perf_counter()
        if now < next_t:
            time.sleep(next_t - now)
        next_t += interval_s           # absolute schedule: no pacing drift
        jobs.append(client.request("work", vec, mode="pipelined",
                                   deadline_ms=deadline_ms))
        submitted += 1
    shed = 0
    for jid in jobs:
        try:
            client.query(jid, timeout=120)
        except RuntimeError as e:
            if str(e).startswith("DeadlineExceeded"):
                shed += 1
        replies += 1                   # ok, shed, and error replies all count
    out_q.put({"lane": lane, "t0": t0, "t1": time.time(),
               "submitted": submitted, "replies": replies, "shed": shed})
    client.close()


def _run_point(load_x: float, reactors: int) -> dict:
    """One sweep point: offered ``load_x`` × the 2-worker capacity against
    ``reactors`` shards (dispatcher workers match the shard count)."""
    from repro.core.dispatcher import RequestDispatcher
    from repro.core.policy import OffloadPolicy
    from repro.ipc import ServingFabric, TransportSpec

    gate = [0.0]

    def work_batch(xs: list[np.ndarray]) -> list[np.ndarray]:
        time.sleep(FIXED_CALL_S)       # fixed per-call cost (the weights)
        return [x + 1.0 for x in xs]

    policy = OffloadPolicy(offload_threshold_bytes=1, max_batch=MAX_BATCH,
                           poll_interval_us=_POLL_US["server"])
    dispatcher = RequestDispatcher(policy, max_batch_wait_s=0.005,
                                   workers=reactors)
    dispatcher.register_handler("gate", lambda x: np.float32(gate[0]) + x)
    dispatcher.register_handler("work", lambda x: work_batch([x])[0],
                                batch_fn=work_batch)
    spec = TransportSpec(data_slots=8, data_slot_bytes=1 << 20,
                         ctrl_slots=4, ctrl_slot_bytes=16 << 10)
    offered = load_x * 2 * CAP1        # x is relative to the SHARDED capacity
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    with ServingFabric(dispatcher, spec=spec, policy=policy,
                       own_dispatcher=True, reactors=reactors,
                       max_inflight=64).start() as fabric:
        procs = []
        for cfg in LANES:
            rate = offered * cfg["share"] / cfg["clients"]   # req/s per client
            n = max(1, int(round(rate * SWEEP_S)))
            for _ in range(cfg["clients"]):
                procs.append(ctx.Process(
                    target=_client_entry,
                    args=(fabric.name, cfg["lane"], 1.0 / rate, n,
                          cfg["deadline_ms"], out_q),
                    daemon=True))
        for p in procs:
            p.start()
        while fabric.listener.accepted < len(procs):
            time.sleep(0.005)
        gate[0] = 1.0                  # all connected: release together
        reports = [out_q.get(timeout=180) for _ in procs]
        for p in procs:
            p.join(timeout=60)
        stats = fabric.stats()
    wall = (max(r["t1"] for r in reports) - min(r["t0"] for r in reports))
    return {"reports": reports, "stats": stats, "wall": wall,
            "load_x": load_x, "reactors": reactors}


def _lane_rows(point: dict):
    """Per-lane CSV rows for one sweep point (server-side SLO clock)."""
    stats, wall = point["stats"], point["wall"]
    slo, disp = stats["slo"], stats["dispatcher"]
    tag = f"fig15/load{point['load_x']:g}x"
    for cfg in LANES:
        lane = cfg["lane"]
        ls = slo.get(f"lane{lane}", {})
        n = disp["lane_requests"].get(lane, 0)
        shed = disp["lane_shed"].get(lane, 0)
        miss = ls.get("misses", 0)
        goodput = max(0, n - shed - miss) / wall
        yield fmt_row(f"{tag}_lane{lane}", ls.get("p50_ms", 0.0) * 1e3,
                      f"p99={ls.get('p99_ms', 0.0):.1f}ms "
                      f"{goodput:.0f}good/s shed{shed} miss{miss}")


def _goodput(point: dict) -> float:
    """Aggregate on-time completions per second for one point."""
    disp = point["stats"]["dispatcher"]
    slo = point["stats"]["slo"]
    n = sum(disp["lane_requests"].values())
    shed = sum(disp["lane_shed"].values())
    miss = slo.get("deadline_misses", 0)
    return max(0, n - shed - miss) / point["wall"]


def run():
    """Yield the sweep rows, the counted accounting gate, and the
    1→2-shard goodput comparison."""
    points = [_run_point(0.5, reactors=2), _run_point(2.0, reactors=2)]
    for point in points:
        yield from _lane_rows(point)
    solo = _run_point(2.0, reactors=1)       # sharding headline comparison

    # counted, timing-independent gates over ALL runs (incl. the 1-shard
    # one): every submitted request produced exactly one reply, and the
    # server's shed counter matches the client-observed shed errors
    submitted = replies = client_shed = server_shed = 0
    for point in points + [solo]:
        for r in point["reports"]:
            submitted += r["submitted"]
            replies += r["replies"]
            client_shed += r["shed"]
        server_shed += point["stats"]["dispatcher"]["shed"]
    lost = (submitted - replies) / max(1, submitted)
    yield fmt_row("fig15/accounting", 0.0,
                  f"n={submitted};slo_lost/req={lost:.4f};"
                  f"shed_drift={abs(server_shed - client_shed)}")
    yield fmt_row("fig15/shards_1to2", 0.0,
                  f"{_goodput(points[1]) / max(_goodput(solo), 1e-9):.2f}x")
