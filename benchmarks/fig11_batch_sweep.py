"""Paper Fig. 11: transfer-size sensitivity — which movement mode wins as the
per-request data volume grows (paper: pipelined worst when small, best once
past a threshold; static always-offload can lose to inline)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core import AsyncTransferEngine, ExecutionMode, OffloadPolicy
from repro.core.latency import LatencyModel

REQS = 16


def _run_mode(mode: str, nbytes: int) -> float:
    pol = OffloadPolicy(mode=ExecutionMode(mode), offload_threshold_bytes=1,
                        pipeline_depth=4)
    buf = np.ones(nbytes // 4, np.float32)
    with AsyncTransferEngine(pol, latency=LatencyModel(5.0, 30.0)) as eng:
        t0 = time.perf_counter()
        jobs = [eng.submit(buf) for _ in range(REQS)]
        # simulated per-request handler work overlapping the engine
        x = 0.0
        for _ in range(REQS):
            x += float(np.sum(buf[:1024]))
        for j in jobs:
            j.get()
        return (time.perf_counter() - t0) / REQS * 1e6


def run() -> list[str]:
    rows = []
    for kb in (64, 1024, 8192):
        best, best_us = None, float("inf")
        for mode in ("sync", "async", "pipelined"):
            us = _run_mode(mode, kb << 10)
            if us < best_us:
                best, best_us = mode, us
            rows.append(fmt_row(f"fig11/{kb}KB/{mode}", us, ""))
        rows.append(fmt_row(f"fig11/{kb}KB/best", best_us, f"mode={best}"))
    return rows
