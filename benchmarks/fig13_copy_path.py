"""Fig. 13 copy-path analogue: counted copies-per-request + zero-copy wins.

Two measurements of the single-copy serving datapath, both against the
same cross-client serving workload (k client processes streaming ≥1 MB
pipelined requests at fixed depth into one fabric):

- **copies per request** — read from the process-wide CopyEngine's tagged
  counters (counted, not timed): the zero-copy reactor + batch-formation
  gather should show exactly 1 payload memcpy per request server-side
  (``gather``), where the copy-out baseline (``zero_copy_serving=False``,
  the PR 2 datapath) pays ``recv_copy`` + ``gather`` = 2;
- **throughput** — requests/s of the same sweep, zero-copy vs baseline
  (expect ≥1.3x at 1 MB where the eliminated memcpy dominates), plus an
  in-process microbench of the descriptor cache (steady-state sends skip
  the per-message ``pickle.dumps`` of the tree descriptor).

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig13copy``
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time
import traceback
from collections import deque

import numpy as np


def fmt_row(name: str, us: float, derived: str) -> str:
    """Local copy of benchmarks.common.fmt_row: this module must stay
    jax-free so the measurement server process (a spawn child importing
    only this module) never pays jax's thread pools — a loaded jax in
    the serving process measurably skews the 2-thread copy pipeline."""
    return f"{name},{us:.1f},{derived}"

CLIENTS = 2
N_PER_CLIENT = 12
CLIENT_DEPTH = 6                 # flood-ish: keep the server saturated so
                                 # throughput reflects server copy work, not
                                 # client round-trip pacing
ROW_ELEMS = 1 << 20              # 4 MB float32 request payload (≥1MB)
REPLY_ELEMS = 8                  # tiny reply: the request path is under test
REPEATS = 3                      # best-of per mode: CI boxes are noisy
_POLL_US = {"server": 100.0, "client": 500.0}


def _client_entry(name: str, n: int, out_q) -> None:
    """One client process: gate, then stream depth-bounded 1MB requests."""
    from repro.core.policy import OffloadPolicy
    from repro.ipc import RemoteDispatcherClient

    # inline sync copies into the ring: the fastest client send path, so
    # the measured delta is the *server-side* copy work under test
    policy = OffloadPolicy(poll_interval_us=_POLL_US["client"],
                           offload_threshold_bytes=1 << 60)
    client = RemoteDispatcherClient.connect(name, policy=policy, timeout_s=60)
    while int(client.request("gate", np.zeros(1, np.float32),
                             mode="sync")[0]) == 0:
        time.sleep(0.002)
    row = np.arange(ROW_ELEMS, dtype=np.float32)
    t0 = time.time()
    outstanding: deque = deque()
    for _ in range(n):
        outstanding.append(client.request("fold", row, mode="pipelined"))
        if len(outstanding) >= CLIENT_DEPTH:
            client.query(outstanding.popleft(), timeout=60)
    while outstanding:
        client.query(outstanding.popleft(), timeout=60)
    out_q.put((t0, time.time()))
    client.close()


def _serve(zero_copy: bool):
    """One sweep point; returns
    ``(wall_s, tag_deltas, tag_bytes, mean_batch, phase_profile)`` —
    the last is this run's delta of the hardware-witness per-phase
    accumulators (empty when profiling is off)."""
    from repro.core.copyengine import get_engine
    from repro.core.dispatcher import RequestDispatcher
    from repro.core.policy import OffloadPolicy
    from repro.ipc import ServingFabric, TransportSpec
    from repro.obs import hwcounters as hw

    gate = [0.0]
    gate_calls = [0]

    def gate_fn(x):
        # counted so the client's nondeterministic gate *polling* can be
        # subtracted from the copy-out mode's recv_copy delta — keeping
        # copies/request a deterministic metric `run.py --check` can gate
        gate_calls[0] += 1
        return np.float32(gate[0]) + x

    def fold_slab(slab: np.ndarray, shapes):
        # consume the gathered batch buffer without copying the payload
        return [np.array(slab[i, :REPLY_ELEMS])
                for i in range(len(shapes))]

    policy = OffloadPolicy(offload_threshold_bytes=1,
                           max_batch=8,
                           poll_interval_us=_POLL_US["server"],
                           zero_copy_serving=zero_copy)
    dispatcher = RequestDispatcher(policy, max_batch_wait_s=0.002)
    dispatcher.register_handler("gate", gate_fn)
    dispatcher.register_handler("fold",
                                lambda x: np.array(x[:REPLY_ELEMS]),
                                slab_fn=fold_slab)
    spec = TransportSpec(data_slots=12, data_slot_bytes=(ROW_ELEMS * 4) + (1 << 16),
                         ctrl_slots=4, ctrl_slot_bytes=16 << 10)
    eng = get_engine()
    before = eng.tagged_snapshot()
    prof0 = hw.phase_totals()
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    with ServingFabric(dispatcher, spec=spec, policy=policy,
                       own_dispatcher=True).start() as fabric:
        procs = [ctx.Process(target=_client_entry,
                             args=(fabric.name, N_PER_CLIENT, out_q),
                             daemon=True)
                 for _ in range(CLIENTS)]
        for p in procs:
            p.start()
        while fabric.listener.accepted < CLIENTS:
            time.sleep(0.005)
        gate[0] = 1.0
        spans = [out_q.get(timeout=180) for _ in procs]
        for p in procs:
            p.join(timeout=60)
        mean_batch = fabric.dispatcher.stats.mean_batch
    after = eng.tagged_snapshot()
    prof1 = hw.phase_totals()
    profile = {}
    for phase, acc in prof1.items():
        base = prof0.get(phase, {})
        d = {k: v - base.get(k, 0) for k, v in acc.items()
             if v - base.get(k, 0)}
        if d:
            profile[phase] = d
    deltas = {k: after["copies"].get(k, 0) - before["copies"].get(k, 0)
              for k in set(after["copies"]) | set(before["copies"])}
    dbytes = {k: after["bytes"].get(k, 0) - before["bytes"].get(k, 0)
              for k in set(after["bytes"]) | set(before["bytes"])}
    wall = max(t1 for _, t1 in spans) - min(t0 for t0, _ in spans)
    if not zero_copy:
        # copy-out mode pays one (4-byte) recv_copy per gate poll too;
        # remove that timing-dependent count so copies/req reflects the
        # fold datapath only (zero-copy mode receives gates as leases)
        deltas["recv_copy"] = deltas.get("recv_copy", 0) - gate_calls[0]
    return wall, deltas, dbytes, mean_batch, profile


def _bench_descr_cache(enabled: bool, n_msgs: int = 200) -> float:
    """In-process channel pair: µs/message for a 32-leaf tree with the
    structure-keyed descriptor cache on vs off."""
    from repro.core.policy import OffloadPolicy
    from repro.ipc import ShmTransport, TransportSpec

    policy = OffloadPolicy()                     # sync sends (inline copy)
    spec = TransportSpec(data_slots=8, data_slot_bytes=1 << 20,
                         data_meta_bytes=16 << 10,
                         ctrl_slots=4, ctrl_slot_bytes=4 << 10)
    a = ShmTransport.create(spec=spec, policy=policy)
    b = ShmTransport.attach(a.name, policy=policy)
    if not enabled:                              # benchmark-only A/B poke
        for ch in (a.data, b.data):
            ch._cache_enabled = False
            ch._tx_descr_cache.clear()
            ch._rx_descr_cache.clear()
    tree = {f"leaf{i:02d}": np.ones(512, np.float32) for i in range(32)}
    stop = threading.Event()

    def consume():
        while not stop.is_set():
            item = b.data.try_recv(copy=False)
            if item is None:
                time.sleep(0)
                continue
            item.release()

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    for _ in range(10):                          # warmup
        a.send(tree, mode="sync")
    t0 = time.perf_counter()
    for _ in range(n_msgs):
        a.send(tree, mode="sync")
    dt = time.perf_counter() - t0
    a.data.flush()
    time.sleep(0.05)                             # let the consumer drain
    stop.set()
    t.join(timeout=5)
    b.close()
    a.close()
    return dt / n_msgs * 1e6


def _measure_entry(out_q) -> None:
    """Spawn-child main: run the whole serving sweep in a process that has
    imported nothing but numpy + repro (in particular: no jax from the
    harness), so the measured 2-thread copy pipeline is clean."""
    try:
        from repro.obs import hwcounters as hw
        # the hardware witness is always on for this bench — it IS the
        # autopsy tool for the zerocopy-vs-baseline row; cost is ~2
        # syscalls per drain/batch/reply scope, identical in both modes
        tier = hw.enable()
        _serve(True)                       # warmup: page cache, spawn tails
        best: dict = {}
        for _ in range(REPEATS):           # alternate modes, best-of each:
            for zero_copy in (True, False):   # scheduling noise on small
                run_out = _serve(zero_copy)   # CI boxes swamps any one run
                if zero_copy not in best or run_out[0] < best[zero_copy][0]:
                    best[zero_copy] = run_out
        cache_us = {on: min(_bench_descr_cache(on) for _ in range(REPEATS))
                    for on in (True, False)}
        out_q.put(("ok", (best, cache_us, tier)))
    except BaseException:
        out_q.put(("err", traceback.format_exc()))


def run():
    """Yield CSV rows: per-mode copies/req + req/s with counter-witnessed
    columns and a per-phase autopsy row per mode, then the speedups."""
    # safe here: run() executes in the harness process (which already
    # imported jax); only the measurement child must stay jax-free
    from benchmarks.common import witness_tokens
    total = CLIENTS * N_PER_CLIENT
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    # not daemonic: the measurement server spawns its own client processes
    proc = ctx.Process(target=_measure_entry, args=(out_q,))
    proc.start()
    status, payload = out_q.get(timeout=600)
    proc.join(timeout=60)
    if status != "ok":
        raise RuntimeError(f"fig13copy measurement child failed:\n{payload}")
    best, cache_us, tier = payload
    rps = {}
    req_bytes = total * ROW_ELEMS * 4
    for zero_copy, tag in ((True, "zerocopy"), (False, "baseline")):
        wall, copies, dbytes, mean_batch, profile = best[zero_copy]
        server_copies = copies.get("gather", 0) + copies.get("recv_copy", 0)
        server_mb = (dbytes.get("gather", 0)
                     + dbytes.get("recv_copy", 0)) / (1 << 20)
        rps[tag] = total / wall
        # counter-witnessed columns: sum the serving process's phase
        # deltas (the client-side phases live in the client processes)
        totals: dict = {}
        attributed_ns = 0
        for phase, acc in profile.items():
            for k, v in acc.items():
                if k not in ("count", "bytes"):
                    totals[k] = totals.get(k, 0) + v
            # lease holds overlap every other phase, and sg_gather is a
            # nested sub-scope of handler — counting either would
            # double-attribute the same wall time
            if phase not in ("lease_hold", "sg_gather"):
                attributed_ns += acc.get("wall_ns", 0)
        witness = witness_tokens(totals, tier, nbytes=req_bytes,
                                 reqs=total)
        # phase_cover: fraction of the sweep's wall clock attributed to
        # named phases; thread concurrency (reactor + dispatcher) can
        # push this past 1.0 — it is occupancy, not critical path
        cover = attributed_ns / (wall * 1e9) if wall > 0 else 0.0
        yield fmt_row(
            f"fig13copy/{tag}", wall / total * 1e6,
            f"{rps[tag]:.0f}req/s;"
            f"copies/req={server_copies / total:.2f};"
            f"MBcopied/req={server_mb / total:.2f};"
            f"batch{mean_batch:.1f};"
            f"phase_cover={cover:.2f};{witness}")
        # the per-phase autopsy row: where the serving process's time
        # (and counters) went, µs/request, largest first
        parts = []
        for phase, acc in sorted(profile.items(),
                                 key=lambda kv: -kv[1].get("wall_ns", 0)):
            us_req = acc.get("wall_ns", 0) / 1e3 / total
            cpu_req = acc.get("task_clock_ns", 0) / 1e3 / total
            parts.append(f"{phase}:{us_req:.0f}us"
                         + (f"/{cpu_req:.0f}cpu" if cpu_req else ""))
        yield fmt_row(f"fig13copy/phases_{tag}", 0.0,
                      ";".join(parts) + f";witness={tier}")
    yield fmt_row("fig13copy/zerocopy_speedup", 0.0,
                  f"{rps['zerocopy'] / rps['baseline']:.2f}x")
    yield fmt_row("fig13copy/descr_cache_on", cache_us[True], "32-leaf tree")
    yield fmt_row("fig13copy/descr_cache_off", cache_us[False], "32-leaf tree")
    yield fmt_row("fig13copy/descr_cache_speedup", 0.0,
                  f"{cache_us[False] / cache_us[True]:.2f}x")
