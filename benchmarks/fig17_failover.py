"""Fig. 17 analogue: diskless failover — warm-standby promotion vs cold restart.

The failover claim behind the checkpoint/replication plane: keeping a
warm standby fed by diskless state replication over the fabric turns
crash recovery from *re-initialization* (spawn + import + model build)
into a *promotion handshake* — strictly faster on the same host — while
preserving the replicated state byte-identically and keeping every
exactly-once identity intact across the switchover.  Two sub-benches,
identical except for the standby:

- ``fig17/cold`` — a :class:`~repro.ft.supervisor.FabricSupervisor`
  runs the restorable reference fabric
  (:func:`repro.ft.standby.param_echo_factory`, ~8 MB deterministic
  params) with ``worker.crash`` armed mid-soak and **no standby**:
  recovery is a cold restart and its cost (the worst single-request
  latency — the one spanning the crash) is dominated by process spawn,
  interpreter imports, and parameter re-initialization.

- ``fig17/warm`` — the same, plus a warm standby continuously pulling
  size-classed snapshot shards (CRC-gated, streamed through the bulk
  heap — ``heap_threshold_bytes`` is lowered so the ~256 KB shards ride
  extents) and the dispatcher delta log.  On the crash the supervisor
  *promotes*: the standby rebuilds the fabric from replicated state
  under the same rendezvous name and clients ride through on
  reconnect-with-replay.  Byte-identity is gated: the promotion ack's
  payload digest must equal the digest pulled from the primary before
  the crash, and the promoted fabric must re-serve that same digest.

``fig17/summary`` compares the two and **fails if warm promotion is not
strictly faster than cold restart**.  The fig16 zero-slack identities
(``lost_replies``/``dup_replies``/``leaked_arenas``) are emitted on
both rows and gated by ``run.py --check``.

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig17``
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core.policy import OffloadPolicy, RetryPolicy
from repro.ft.inject import FaultPlane, FaultSpec
from repro.ft.supervisor import SHM_DIR, FabricSupervisor

NAME = "rocket-fig17"
SEED = 17
N_REQS = 30                    # soak length (sync requests per sub-bench)
CRASH_AT = 12                  # worker.crash fires on this drained batch
D = 256                        # request payload width (1KB — stays inline)
FACTORY = "repro.ft.standby:param_echo_factory"
# fast failure detection, benchmark-sized (same shape as fig16)
RETRY = RetryPolicy(heartbeat_interval_s=0.1, heartbeat_stale_s=0.4,
                    connect_timeout_s=10.0, max_reconnects=8)


def _policy() -> OffloadPolicy:
    # low heap threshold so the ~256KB replication shards ride the bulk
    # heap (the diskless stream's intended datapath), not ring slots
    return OffloadPolicy(mode="pipelined", heap_threshold_bytes=1 << 16,
                         retry=RETRY)


def _soak(client, n: int) -> dict:
    """Issue ``n`` sync requests, validating every reply; returns mean/max
    latency and goodput over the whole window (the crash included)."""
    vec = np.arange(D, dtype=np.float32)
    lat_max = total = 0.0
    t0 = time.perf_counter()
    for _ in range(n):
        t = time.perf_counter()
        out = client.request("double", vec, mode="sync")
        dt = time.perf_counter() - t
        total += dt
        lat_max = max(lat_max, dt)
        if not np.allclose(out, vec * 2):
            raise AssertionError("corrupted reply payload")
    wall = time.perf_counter() - t0
    return {"mean_us": total / n * 1e6, "max_ms": lat_max * 1e3,
            "goodput_rps": n / wall}


def _pull_manifest(client) -> dict:
    """The serving fabric's current snapshot manifest, via the same
    replication op a standby uses."""
    from repro.checkpoint import ReplicationSource
    raw = client.request(ReplicationSource.OP_MANIFEST,
                         np.zeros(1, np.uint8), mode="sync")
    return json.loads(bytes(np.asarray(raw, np.uint8)))


def _recovery_bench(warm: bool) -> tuple[str, dict]:
    """One crash-recovery soak; returns ``(row, measurements)``."""
    from repro.ipc.worker import RemoteDispatcherClient

    policy = _policy()
    plane = FaultPlane(SEED, {"worker.crash": FaultSpec(at=(CRASH_AT,))})
    sup = FabricSupervisor(
        NAME, FACTORY, policy=policy, max_restarts=3,
        plane_json=plane.spec_json(),
        standby_factory=FACTORY if warm else None,
        standby_interval_s=0.1, promote_timeout_s=20.0).start()
    try:
        if not sup.wait_alive(30.0):
            raise RuntimeError("supervised fabric never came up")
        client = RemoteDispatcherClient.connect(NAME, policy=policy,
                                                timeout_s=30.0)
        try:
            if warm:
                # the crash must not outrun replication: wait until the
                # standby has applied at least one full snapshot
                deadline = time.perf_counter() + 60.0
                while time.perf_counter() < deadline:
                    st = sup.standby_stats(timeout_s=5.0)
                    if st and st.get("snapshots_applied", 0) >= 1:
                        break
                    time.sleep(0.05)
                else:
                    raise RuntimeError("standby never applied a snapshot")
            psum0 = float(client.request("psum", np.zeros(1), mode="sync"))
            digest0 = _pull_manifest(client)["digest"]
            m = _soak(client, N_REQS)
            # post-recovery witnesses: the replacement fabric serves the
            # same state (psum) and the same payload digest
            psum1 = float(client.request("psum", np.zeros(1), mode="sync"))
            digest1 = _pull_manifest(client)["digest"]
            lost, dup = client.lost_replies, client.dup_replies
            reconnects = client.reconnects
        finally:
            client.close()
    finally:
        sup.close()            # terminates children, reclaims segments
    leaked = len([f for f in os.listdir(SHM_DIR) if f.startswith(NAME)])
    s = sup.stats()
    if s["crashes"] < 1:
        raise RuntimeError("chaos schedule never fired worker.crash")
    if psum1 != psum0:
        raise RuntimeError(f"state witness diverged across recovery: "
                           f"psum {psum0} -> {psum1}")
    if digest1 != digest0:
        raise RuntimeError(f"snapshot digest diverged across recovery: "
                           f"{digest0[:12]} -> {digest1[:12]}")
    meas = {"recovery_ms": m["max_ms"], "stats": s}
    base = (f"goodput={m['goodput_rps']:.0f}rps;"
            f"recovery_ms={m['max_ms']:.0f};"
            f"crashes={s['crashes']};restarts={s['restarts']};"
            f"promotions={s['promotions']};reconnects={reconnects};"
            f"lost_replies={lost};dup_replies={dup};leaked_arenas={leaked}")
    if not warm:
        if s["restarts"] != 1 or s["promotions"] != 0:
            raise RuntimeError(f"cold bench recovered wrong: {s}")
        return fmt_row("fig17/cold", m["mean_us"], base), meas
    if s["promotions"] != 1 or s["restarts"] != 0:
        raise RuntimeError(f"warm bench did not promote: {s}")
    ack = s["last_promotion"]
    # byte-identity across the handoff: the state the standby promoted IS
    # the last completed snapshot the primary served
    if ack["digest"] != digest0:
        raise RuntimeError(f"promoted state digest {ack['digest'][:12]} != "
                           f"pre-crash snapshot {digest0[:12]}")
    rstats = ack["stats"]
    derived = (base +
               f";promote_ms={ack['bind_ms']:.1f};"
               f"repl_lag_ms={ack['lag_ms']:.0f};"
               f"ckpt_shard_copies={rstats['shard_pulls']};"
               f"repl_mb={rstats['bytes_pulled'] / 1e6:.1f}")
    return fmt_row("fig17/warm", m["mean_us"], derived), meas


def run():
    cold_row, cold = _recovery_bench(warm=False)
    yield cold_row
    warm_row, warm = _recovery_bench(warm=True)
    yield warm_row
    cold_ms, warm_ms = cold["recovery_ms"], warm["recovery_ms"]
    if not warm_ms < cold_ms:
        raise RuntimeError(
            f"warm promotion ({warm_ms:.0f}ms) not strictly faster than "
            f"cold restart ({cold_ms:.0f}ms)")
    yield fmt_row(
        "fig17/summary", warm_ms * 1e3,
        f"cold_ms={cold_ms:.0f};warm_ms={warm_ms:.0f};"
        f"speedup={cold_ms / warm_ms:.1f}x")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row)
