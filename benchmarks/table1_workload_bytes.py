"""Paper Table I: bytes moved per request/step for the assigned workloads —
the transfer volumes the movement runtime must sustain (from input_specs,
no allocation)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fmt_row
from repro.configs import SHAPES, get_config, list_archs
from repro.launch import specs as specs_mod


def run() -> list[str]:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            shape = SHAPES[shape_name]
            sds = specs_mod.input_specs(cfg, shape)
            nbytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                         for s in jax.tree.leaves(sds))
            derived = f"req_bytes={nbytes / 2 ** 20:.1f}MB"
            if shape.kind == "decode":
                from repro.models import build_model
                cache = specs_mod.cache_specs(build_model(cfg), shape)
                cbytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                             for s in jax.tree.leaves(cache))
                derived += f";state_bytes={cbytes / 2 ** 30:.2f}GB"
            rows.append(fmt_row(f"table1/{arch}/{shape_name}", 0.0, derived))
    return rows
