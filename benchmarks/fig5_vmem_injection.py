"""Paper Fig. 5: cache-injection effect — the fused consumer (reduction over
the copied buffer while resident) vs a separate second pass.  Derived metric:
modelled HBM traffic (jcost) + wall time of the inline path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import block, fmt_row, time_us
from repro.kernels import ref


def run() -> list[str]:
    rows = []
    x = jnp.ones((2048, 512), jnp.float32)
    nbytes = x.size * x.dtype.itemsize

    # HBM traffic through the *kernel* (tier 3), analytically:
    #   no_inject: read x + write y + (consumer re-reads y from HBM)
    #   inject:    read x + write y  (consumer reduces while VMEM-resident)
    sep_traffic = 3 * nbytes
    fus_traffic = 2 * nbytes
    saving = (1 - fus_traffic / sep_traffic) * 100.0

    def separate(a):
        y, _ = ref.offload_copy(a, scale=2.0)
        return y, jnp.sum(y * 1.0000001)       # defeat trivial CSE

    def fused(a):
        y, s = ref.offload_copy(a, scale=2.0, inject=True)
        return y, s

    t_sep = time_us(lambda: block(jax.jit(separate)(x)))
    t_fus = time_us(lambda: block(jax.jit(fused)(x)))
    rows.append(fmt_row("fig5/no_inject", t_sep,
                        f"hbm_bytes={sep_traffic:.2e}"))
    rows.append(fmt_row("fig5/inject", t_fus,
                        f"hbm_bytes={fus_traffic:.2e};"
                        f"traffic_saving={saving:.0f}%"))
    return rows
