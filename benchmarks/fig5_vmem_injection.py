"""Paper Fig. 5: cache-injection effect — the fused consumer (reduction over
the copied buffer while resident) vs a separate second pass.

Two witnesses, reported side by side and never conflated:

- ``witness=model`` rows — the analytical traffic model (read x + write
  y + optional consumer re-read), the paper's 3N-vs-2N accounting, plus
  wall time of the jitted kernels.  Always emitted.
- ``witness=<tier>`` rows (``fig5/witness/*``) — a *measured*
  cache-injection analogue via :mod:`repro.obs.hwcounters`: consume a
  produced buffer while cache-resident ("injected") vs after a
  cache-sized clobber evicts it ("cold re-read").  On a `perf-hw` host
  the witness is the LLC-miss delta between the two passes; on the
  fallback tiers it is the timed cold-vs-warm ratio (labeled
  ``witness=timed`` — explicitly *not* a counter reading).  This closes
  the ROADMAP item "real cache-injection measurement".
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import block, counter_meter, fmt_row, time_us
from repro.kernels import ref

# measured-injection analogue geometry: a 4 MB working buffer (fits in
# a typical LLC) and a 64 MB clobber (evicts any LLC)
_WORK_ELEMS = 1 << 20
_CLOBBER_ELEMS = 16 << 20
_PASSES = 5


def _measured_rows() -> list[str]:
    """The hardware-witnessed cold-vs-warm consumer passes."""
    work = np.ones(_WORK_ELEMS, np.float32)
    out = np.empty_like(work)
    clobber = np.ones(_CLOBBER_ELEMS, np.float32)
    m_warm = counter_meter()
    m_cold = counter_meter()
    tier = m_warm.tier
    warm_ts, cold_ts = [], []
    sink = 0.0
    for _ in range(_PASSES):
        # produce (copy into out), then consume immediately — the
        # injected case: the consumer's reads hit cache lines the
        # producing copy just wrote
        np.copyto(out, work)
        t0 = time.perf_counter()
        with m_warm:
            sink += float(out.sum())
        warm_ts.append(time.perf_counter() - t0)
        # produce, evict via a cache-sized streaming pass, then consume
        # — the no-injection case: every consumer read misses to DRAM
        np.copyto(out, work)
        sink += float(clobber.sum())         # the eviction pass
        t0 = time.perf_counter()
        with m_cold:
            sink += float(out.sum())
        cold_ts.append(time.perf_counter() - t0)
    warm_us = min(warm_ts) * 1e6
    cold_us = min(cold_ts) * 1e6
    nbytes = _PASSES * _WORK_ELEMS * 4
    rows = []
    if tier == "perf-hw" and m_cold.totals.get("llc_misses"):
        warm_mpb = m_warm.totals.get("llc_misses", 0) / nbytes
        cold_mpb = m_cold.totals["llc_misses"] / nbytes
        rows.append(fmt_row(
            "fig5/witness/warm_reuse", warm_us,
            f"llc_miss/byte={warm_mpb:.6f};witness={tier}"))
        rows.append(fmt_row(
            "fig5/witness/cold_reread", cold_us,
            f"llc_miss/byte={cold_mpb:.6f};witness={tier}"))
        ratio = cold_mpb / warm_mpb if warm_mpb else float("inf")
        rows.append(fmt_row(
            "fig5/witness/summary", 0.0,
            f"cold/warm_llc_miss={ratio:.1f}x;witness={tier}"))
    else:
        # fallback tier: the witness is the timed ratio — labeled as
        # such, never passed off as a counter reading
        rows.append(fmt_row("fig5/witness/warm_reuse", warm_us,
                            "witness=timed"))
        rows.append(fmt_row("fig5/witness/cold_reread", cold_us,
                            "witness=timed"))
        rows.append(fmt_row(
            "fig5/witness/summary", 0.0,
            f"cold/warm_time={cold_us / max(warm_us, 1e-9):.2f}x;"
            f"witness=timed"))
    m_warm.close()
    m_cold.close()
    return rows


def run() -> list[str]:
    """Yield the analytic-model rows and the measured-witness rows."""
    rows = []
    x = jnp.ones((2048, 512), jnp.float32)
    nbytes = x.size * x.dtype.itemsize

    # HBM traffic through the *kernel* (tier 3), analytically:
    #   no_inject: read x + write y + (consumer re-reads y from HBM)
    #   inject:    read x + write y  (consumer reduces while VMEM-resident)
    sep_traffic = 3 * nbytes
    fus_traffic = 2 * nbytes
    saving = (1 - fus_traffic / sep_traffic) * 100.0

    def separate(a):
        y, _ = ref.offload_copy(a, scale=2.0)
        return y, jnp.sum(y * 1.0000001)       # defeat trivial CSE

    def fused(a):
        y, s = ref.offload_copy(a, scale=2.0, inject=True)
        return y, s

    t_sep = time_us(lambda: block(jax.jit(separate)(x)))
    t_fus = time_us(lambda: block(jax.jit(fused)(x)))
    rows.append(fmt_row("fig5/no_inject", t_sep,
                        f"hbm_bytes={sep_traffic:.2e};witness=model"))
    rows.append(fmt_row("fig5/inject", t_fus,
                        f"hbm_bytes={fus_traffic:.2e};"
                        f"traffic_saving={saving:.0f}%;witness=model"))
    rows.extend(_measured_rows())
    return rows
