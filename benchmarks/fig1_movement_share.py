"""Paper Fig. 1: share of end-to-end latency attributable to data movement
as a function of message size (shmem/gRPC echo analogue: host->device
transfer + a fixed device compute step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import block, fmt_row, time_us


@jax.jit
def _compute(x):
    # fixed "handler" compute: a couple of matmul passes over a slice
    y = x[: 256 * 256].reshape(256, 256)
    for _ in range(4):
        y = jnp.tanh(y @ y.T / 256.0)
    return y.sum()


def run() -> list[str]:
    from repro.core import AsyncTransferEngine, SYNC_INLINE
    rows = []
    with AsyncTransferEngine(SYNC_INLINE) as eng:
        for mb in (1, 8, 32, 128):
            n = mb * (1 << 20) // 4
            host = np.ones(n, np.float32)
            eng.submit(host).get()                      # pre-map the pool

            def step():
                dev = eng.submit(host).get()            # the IPC transfer
                block(_compute(dev))                    # the handler

            total = time_us(step, iters=5)
            move = time_us(lambda: eng.submit(host).get(), iters=5)
            share = move / total * 100.0
            rows.append(fmt_row(f"fig1/movement_share_{mb}MB", total,
                                f"move_share={share:.0f}%"))
    return rows
