"""Fig. 7 analogue: small-message control-plane cost (§IV/§V).

The paper's CPU-efficiency argument is that *fixed* per-message costs —
slot claim, meta encode, doorbell, poll wakeup — dominate small-message
IPC, not bandwidth.  This sweep (4 KB – 256 KB, producer process →
consumer process) measures three configurations of the same transport:

- ``static``    — the PR-4 behaviour: pipelined sends, every message
  pays full control-plane cost (one slot + one doorbell each);
- ``coalesced`` — the small-message fast path: up to 8 messages packed
  into one ring slot as a microbatch frame (``FLAG_COALESCED``);
- ``adaptive``  — ``OffloadPolicy(governor="adaptive")``: the channel's
  governor picks inline/offload/coalesce per message from measured
  per-size-class cost EWMAs and queue occupancy.

Besides wall-clock µs/msg and msg/s, each row reports two **counted**
metrics that ``run.py --check`` gates against the committed snapshot
(timing-noise-immune, like copies/request):

- ``doorbells/msg`` — ring publishes per message, from the shared
  produced counter: exactly 1.0 static, < 1 whenever coalescing engages
  on a ≥2-deep stream;
- ``pickle/send``   — meta-path ``pickle.dumps``+``loads`` calls per
  message across *both* endpoints (``ChannelStats.meta_pickles`` /
  ``meta_unpickles``): 0 in steady state now that descriptors are cached
  and headers ride the binary codec.

A final ``fig7/adaptive_margin/<size>`` row reports adaptive throughput
relative to the best static choice.  ~1.0 means the governor matched the
best hand-picked mode; on this shared CI host wall-clock swings ~5x with
neighbor load (see ``_ROUNDS``), so treat the margin as informational —
the *counted* rows above are the regression gate.
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np

from benchmarks.common import fmt_row

SIZES = (4 << 10, 16 << 10, 64 << 10, 256 << 10)
VARIANTS = ("static", "coalesced", "adaptive")
_TOTAL_TARGET = 24 << 20
_K = 8
_WARMUP = 80      # untimed: page first-touch + descr-cache miss + the
                  # governor's cold-start exploration bursts (≤2 bursts ×
                  # 3 routes) — the timed phase must start *converged*, or
                  # a few multi-ms offload probes would dominate the mean


def _n_msgs(size: int) -> int:
    return int(np.clip(_TOTAL_TARGET // size, 192, 256))


def _policy(variant: str):
    from repro.core.policy import OffloadPolicy

    # spin_us=2000: on coarse-timer kernels one quantum sleep costs ~1ms,
    # which would dwarf the per-message control-plane cost being measured
    base = dict(spin_us=2000.0, coalesce_window_us=1000.0, coalesce_max=_K)
    if variant == "static":
        return OffloadPolicy(**base)
    if variant == "coalesced":
        return OffloadPolicy(coalesce_bytes=512 << 10, **base)
    return OffloadPolicy(governor="adaptive", **base)


def _spec(size: int):
    from repro.ipc.transport import TransportSpec

    slot = _K * ((size + 63) // 64 * 64) + (1 << 16)
    return TransportSpec(data_slots=8, data_slot_bytes=slot, heap_extents=0)


# -- child entry (spawn-safe, module level) ----------------------------------

def _producer(name: str, variant: str, size: int, n: int) -> None:
    from repro.ipc import ShmTransport

    t = ShmTransport.attach(name, policy=_policy(variant))
    arr = np.arange(size // 8, dtype=np.int64)
    t.send_msg("ready", timeout_s=60)
    t.recv_msg(timeout_s=60)
    for _ in range(_WARMUP):
        t.send({"a": arr}, mode="pipelined")
    t.data.flush()
    t.recv_msg(timeout_s=60)                  # parent drained the warmup
    base = dict(vars(t.data.stats))           # post-warmup counter baseline
    for _ in range(n):
        t.send({"a": arr}, mode="pipelined")
    t.data.flush()
    stats = vars(t.data.stats)
    out = {k: stats[k] - base[k]
           for k in ("meta_pickles", "sends", "coalesced_sends")}
    if t.data.governor is not None:
        out["governor"] = t.data.governor.snapshot()
    t.send_msg(out, timeout_s=60)
    t.recv_msg(timeout_s=60)                  # hold mapping until parent done
    t.close()


# -- measurement -------------------------------------------------------------

def _bench(variant: str, size: int, n: int):
    from repro.ipc import ShmTransport

    ctx = mp.get_context("spawn")
    t = ShmTransport.create(spec=_spec(size), policy=_policy(variant))
    p = ctx.Process(target=_producer, args=(t.name, variant, size, n),
                    daemon=True)
    p.start()
    t.recv_msg(timeout_s=60)
    t.send_msg("go", timeout_s=60)
    for _ in range(_WARMUP):
        t.recv(timeout_s=60, copy=False).release()
    t.send_msg("drained", timeout_s=60)
    ring = t.data.rx
    produced0 = ring.produced
    unpickles0 = t.data.stats.meta_unpickles
    t0 = time.perf_counter()
    checksum = 0
    for _ in range(n):
        with t.recv(timeout_s=60, copy=False) as lease:
            checksum += int(lease.tree["a"][-1])
    dt = time.perf_counter() - t0
    doorbells = ring.produced - produced0
    unpickles = t.data.stats.meta_unpickles - unpickles0
    child = t.recv_msg(timeout_s=60)
    t.send_msg("done", timeout_s=60)
    p.join(timeout=60)
    t.close()
    assert checksum == n * (size // 8 - 1)
    assert child["sends"] == n
    pickles_per_send = (child["meta_pickles"] + unpickles) / n
    return dt, doorbells / n, pickles_per_send


_ROUNDS = 5       # interleaved rotated rounds, median per variant: this
                  # host's memory bandwidth swings ~5x on a seconds scale
                  # (shared machine), so each variant gets several short
                  # draws spread across the sweep and reports its median —
                  # load swings hit every variant, not just whichever ran
                  # during a slow patch, and a median (unlike a min) gives
                  # the 1-config adaptive run and the 2-config "best
                  # static" the same number of effective draws


def run():
    for size in SIZES:
        n = _n_msgs(size)
        kb = size >> 10
        rounds: dict = {v: [] for v in VARIANTS}
        for r in range(_ROUNDS):
            for i in range(len(VARIANTS)):
                variant = VARIANTS[(i + r) % len(VARIANTS)]
                rounds[variant].append(_bench(variant, size, n))
        med: dict = {}
        for variant in VARIANTS:
            runs = sorted(rounds[variant])
            dt, doorbells, pickles = runs[len(runs) // 2]
            med[variant] = dt
            yield fmt_row(
                f"fig7/{variant}/{kb}KB", dt / n * 1e6,
                f"{size * n / dt / (1 << 20):.0f}MB/s;{n / dt:.0f}msg/s;"
                f"doorbells/msg={doorbells:.2f};pickle/send={pickles:.2f}")
        best_static = min(med["static"], med["coalesced"])
        yield fmt_row(f"fig7/adaptive_margin/{kb}KB", 0.0,
                      f"{best_static / med['adaptive']:.2f}x_of_best_static")
