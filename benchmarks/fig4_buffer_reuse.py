"""Paper Fig. 4: page-fault sensitivity — cold allocation per transfer vs
persistent pooled (pre-touched) staging buffers."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import block, fmt_row, time_us
from repro.core.queuepair import BufferPool

MB = 8
SHAPE = (MB * (1 << 20) // 4,)


def run() -> list[str]:
    rows = []

    def cold():
        buf = np.empty(SHAPE, np.float32)   # fresh mapping: first touch inside
        buf[::4096 // 4] = 1.0
        block(jax.device_put(buf))

    cold_us = time_us(cold, iters=8)
    rows.append(fmt_row("fig4/cold_alloc", cold_us, f"size={MB}MB"))

    pool = BufferPool()
    pool.preallocate(SHAPE, np.float32, 2)

    def pooled():
        buf = pool.acquire(SHAPE, np.float32)
        buf[::4096 // 4] = 1.0
        block(jax.device_put(buf))
        pool.release(buf)

    pooled_us = time_us(pooled, iters=8)
    red = (1 - pooled_us / cold_us) * 100.0
    rows.append(fmt_row("fig4/pooled_reuse", pooled_us,
                        f"reduction={red:.0f}%;reuse={pool.stats.reuse_rate:.2f}"))
    return rows
