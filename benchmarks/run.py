"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig10]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig1_movement_share,
    fig2_ipc_transports,
    fig3_polling,
    fig4_buffer_reuse,
    fig5_vmem_injection,
    fig9_latency_model,
    fig10_modes,
    fig11_batch_sweep,
    fig12_decomposition,
    fig13_instruction_counts,
    fig14_multiclient,
    table1_workload_bytes,
)

MODULES = {
    "table1": table1_workload_bytes,
    "fig1": fig1_movement_share,
    "fig2": fig2_ipc_transports,
    "fig3": fig3_polling,
    "fig4": fig4_buffer_reuse,
    "fig5": fig5_vmem_injection,
    "fig9": fig9_latency_model,
    "fig10": fig10_modes,
    "fig11": fig11_batch_sweep,
    "fig12": fig12_decomposition,
    "fig13": fig13_instruction_counts,
    "fig14": fig14_multiclient,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig10,fig13")
    ap.add_argument("--dry-run", action="store_true",
                    help="import and list the selected modules, run nothing "
                         "(CI smoke: catches import/registration breakage)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {','.join(unknown)}; "
                 f"choose from {','.join(MODULES)}")
    if args.dry_run:
        for name in names:
            mod = MODULES[name]
            assert callable(mod.run), name
            print(f"{name},DRY,{mod.__name__}")
        return
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            for row in MODULES[name].run():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
