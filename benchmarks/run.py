"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig10]
  PYTHONPATH=src python -m benchmarks.run --only fig2,fig13copy,fig14 \\
      --record BENCH_IPC.json     # machine-readable perf snapshot

``--record`` writes every produced row plus host metadata to a JSON file
(the CI uploads it as an artifact), seeding a benchmark trajectory that
later PRs can diff against.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback

from benchmarks import (
    fig1_movement_share,
    fig2_ipc_transports,
    fig3_polling,
    fig4_buffer_reuse,
    fig5_vmem_injection,
    fig9_latency_model,
    fig10_modes,
    fig11_batch_sweep,
    fig12_decomposition,
    fig13_instruction_counts,
    fig13_copy_path,
    fig14_multiclient,
    table1_workload_bytes,
)

MODULES = {
    "table1": table1_workload_bytes,
    "fig1": fig1_movement_share,
    "fig2": fig2_ipc_transports,
    "fig3": fig3_polling,
    "fig4": fig4_buffer_reuse,
    "fig5": fig5_vmem_injection,
    "fig9": fig9_latency_model,
    "fig10": fig10_modes,
    "fig11": fig11_batch_sweep,
    "fig12": fig12_decomposition,
    "fig13": fig13_instruction_counts,
    "fig13copy": fig13_copy_path,
    "fig14": fig14_multiclient,
}


def _record(path: str, rows: list[str], failures: list[str]) -> None:
    """Write the collected rows as a machine-readable snapshot."""
    parsed = []
    for row in rows:
        name, us, derived = (row.split(",", 2) + ["", ""])[:3]
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        parsed.append({"bench": name, "us_per_call": us_val,
                       "derived": derived})
    snapshot = {
        "schema": 1,
        "created_unix": int(time.time()),
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "python": platform.python_version(),
            "cpus": os.cpu_count(),
        },
        "rows": parsed,
        "failures": failures,
    }
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2)
        f.write("\n")
    print(f"# recorded {len(parsed)} rows -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig10,fig13copy")
    ap.add_argument("--dry-run", action="store_true",
                    help="import and list the selected modules, run nothing "
                         "(CI smoke: catches import/registration breakage)")
    ap.add_argument("--record", metavar="PATH", default=None,
                    help="also write the rows as a JSON perf snapshot "
                         "(e.g. BENCH_IPC.json; uploaded as a CI artifact)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {','.join(unknown)}; "
                 f"choose from {','.join(MODULES)}")
    if args.dry_run:
        for name in names:
            mod = MODULES[name]
            assert callable(mod.run), name
            print(f"{name},DRY,{mod.__name__}")
        return
    print("name,us_per_call,derived")
    collected: list[str] = []
    failures: list[str] = []
    for name in names:
        try:
            for row in MODULES[name].run():
                print(row, flush=True)
                collected.append(row)
        except Exception:
            failures.append(name)
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.record:
        _record(args.record, collected, failures)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
