"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig10]
  PYTHONPATH=src python -m benchmarks.run --only fig2,fig13copy,fig14 \\
      --record BENCH_IPC.json     # machine-readable perf snapshot

``--record`` writes every produced row plus host metadata to a JSON file
(the CI uploads it as an artifact), seeding a benchmark trajectory that
later PRs can diff against.

``--check BENCH_IPC.json`` turns the snapshot into a gate: the run's
*counted* metrics — copies/request and doorbells/request, read from the
CopyEngine's tagged counters, immune to CI timing noise — are compared
against the committed snapshot and any regression exits nonzero, so CI
fails instead of silently uploading a worse artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback

from benchmarks import (
    fig1_movement_share,
    fig2_ipc_transports,
    fig3_polling,
    fig4_buffer_reuse,
    fig5_vmem_injection,
    fig6_large_payloads,
    fig7_small_messages,
    fig9_latency_model,
    fig10_modes,
    fig11_batch_sweep,
    fig12_decomposition,
    fig12_phase,
    fig13_instruction_counts,
    fig13_copy_path,
    fig14_multiclient,
    fig15_saturation,
    fig16_chaos,
    fig17_failover,
    table1_workload_bytes,
)

MODULES = {
    "table1": table1_workload_bytes,
    "fig1": fig1_movement_share,
    "fig2": fig2_ipc_transports,
    "fig3": fig3_polling,
    "fig4": fig4_buffer_reuse,
    "fig5": fig5_vmem_injection,
    "fig6": fig6_large_payloads,
    "fig7": fig7_small_messages,
    "fig9": fig9_latency_model,
    "fig10": fig10_modes,
    "fig11": fig11_batch_sweep,
    "fig12": fig12_decomposition,
    "fig12phase": fig12_phase,
    "fig13": fig13_instruction_counts,
    "fig13copy": fig13_copy_path,
    "fig14": fig14_multiclient,
    "fig15": fig15_saturation,
    "fig16": fig16_chaos,
    "fig17": fig17_failover,
}

# counted (non-timing) metrics gated by ``--check``: metric token ->
# (multiplicative slack, additive slack).  copies/request is exact by
# construction, so any increase is a datapath regression.  Doorbell
# *coalescing* depends on how fast the engine drains relative to the
# producer, so the legitimate range is [~0, submissions/request] — the
# additive slack of 3.0 covers the worst legitimate case at the gated
# fig6 point (2 fill chunks + 1 publish per message, one ring each);
# only a notify-happier submission path (e.g. ringing per SG entry or
# per park retry) can exceed it.
#
# The fig7 control-plane metrics: doorbells/msg counts ring publishes
# per message (exactly 1.0 static; < 1 whenever send coalescing engages).
# Frame fill depth wobbles with scheduling — the window flushes partial
# frames when the producer stalls — so the gate allows 1.5x the recorded
# coalescing level + 0.1: a recorded 0.12 (K≈8) may drift to 0.28, but a
# path that stops coalescing (→1.0) or rings per sub-message fails.
# pickle/send counts meta-path pickle calls per message across both
# endpoints — 0 in steady state (binary headers + descriptor caches), so
# any regression that reintroduces per-send pickling fails the gate.
#
# The fig15 SLO-accounting metrics are timing-independent *identities* with
# zero slack: slo_lost/req is the fraction of submitted requests that never
# produced a reply (ok, shed error, or other error — anything nonzero means
# the reply path dropped one), and shed_drift is the absolute difference
# between the server's counted sheds and the shed errors clients observed
# (a shed must always be a counted, replied-to event — never silent).
#
# The fig16 chaos identities are the reliability gates, all zero-slack:
# under the seeded fault schedule (server crash mid-batch, corrupted wire
# meta, leaked heap extent) every request must complete exactly once
# (lost_replies=0, dup_replies=0) and every orphaned resource must be
# reclaimed (leaked_arenas=0 /dev/shm segments after supervisor close,
# leaked_extents=0 allocated heap extents after crash-reap).
#
# The hardware-witness counter metrics (obs/hwcounters.py) are gated
# only between rows measured at the SAME witness tier (see the
# ``witness=`` token handling in _check): instructions retired per
# payload byte is schedule-independent on a given build (1.5x headroom
# for allocator/dict-order jitter), LLC misses per byte wobble with
# co-tenancy (2x), and context switches per request vary with the
# scheduler but catch order-of-magnitude regressions (a spin→sleep or
# lock-convoy explosion) even at 3x + 50.  cpu_ns/byte (the perf-sw /
# rusage fallback column) is cpu-time — less noisy than wall clock but
# still timing — so it is recorded, never gated.
CHECKED_METRICS = {
    "copies/req": (1.0, 0.01),
    "doorbells/req": (1.0, 3.0),
    "doorbells/msg": (1.5, 0.1),
    "pickle/send": (1.0, 0.01),
    "slo_lost/req": (1.0, 0.0),
    "shed_drift": (1.0, 0.0),
    "lost_replies": (1.0, 0.0),
    "dup_replies": (1.0, 0.0),
    "leaked_arenas": (1.0, 0.0),
    "leaked_extents": (1.0, 0.0),
    "insn/byte": (1.5, 0.1),
    "llc_miss/byte": (2.0, 0.01),
    "ctx_sw/req": (3.0, 50.0),
}

# counter metrics only comparable within one witness tier: a perf-hw
# instruction count and a rusage cpu-time reading are different
# instruments, so _check skips (loudly) rather than gating across tiers
WITNESS_METRICS = {"insn/byte", "llc_miss/byte", "ctx_sw/req"}


def _parse_counted(derived: str) -> tuple[dict, str]:
    """Extract the counted ``key=value`` metric tokens and the witness
    tier from a derived field (e.g.
    ``"812MB/s;copies/req=1.00;ctx_sw/req=2.1;witness=perf-sw"``).
    Returns ``(metrics, witness)`` — witness is ``""`` for rows that
    carry no counter readings."""
    out, witness = {}, ""
    for tok in derived.split(";"):
        if "=" not in tok:
            continue
        key, _, val = tok.partition("=")
        if key == "witness":
            witness = val
        elif key in CHECKED_METRICS:
            try:
                out[key] = float(val)
            except ValueError:
                pass
    return out, witness


def _check(path: str, rows: list[str]) -> list[str]:
    """Compare this run's counted metrics against the committed snapshot;
    returns human-readable regression strings (empty = pass).  Only rows
    present in BOTH are compared, so adding benches never breaks the gate
    — but a gated metric that *disappears* from a produced row's derived
    field is a failure with an explicit diff, not a vacuous pass (a
    refactor that stops emitting ``copies/req`` must not turn the gate
    off silently)."""
    with open(path) as f:
        snapshot = json.load(f)
    baseline = {}
    for row in snapshot.get("rows", []):
        counted, witness = _parse_counted(row.get("derived") or "")
        if counted:
            baseline[row["bench"]] = (counted, witness)
    produced = {}
    for row in rows:
        name, _, derived = (row.split(",", 2) + ["", ""])[:3]
        produced[name] = _parse_counted(derived)
    problems, compared, tier_skipped = [], 0, 0
    for name, (base, base_witness) in baseline.items():
        if name not in produced:
            continue                   # row not produced (e.g. --only subset)
        counted, witness = produced[name]
        # witness-tier comparability: a row whose counter readings come
        # from a different tier than the baseline's (perf-hw host vs
        # rusage container, say) is a different instrument, not a
        # regression — skip its counter metrics with a loud note, and
        # never flag them as "disappeared" either
        tier_mismatch = (witness != base_witness
                         and (witness or base_witness))
        if tier_mismatch:
            skipped = sorted(WITNESS_METRICS
                             & (set(base) | set(counted)))
            if skipped:
                tier_skipped += len(skipped)
                print(f"# --check: {name}: witness tier "
                      f"{witness or 'none'!r} != baseline "
                      f"{base_witness or 'none'!r} — skipping "
                      f"incomparable counter metrics: {', '.join(skipped)}",
                      file=sys.stderr)
        for key, base_val in base.items():
            if tier_mismatch and key in WITNESS_METRICS:
                continue
            if key not in counted:
                problems.append(
                    f"{name}: gated metric {key!r} disappeared "
                    f"(baseline {base_val:g}, this run has no such token)")
                continue
            new_val = counted[key]
            compared += 1
            factor, slack = CHECKED_METRICS[key]
            limit = base_val * factor + slack
            if new_val > limit:
                problems.append(
                    f"{name}: {key}={new_val:g} exceeds baseline "
                    f"{base_val:g} (limit {limit:g})")
    print(f"# --check: compared {compared} counted metrics against {path}"
          + (f" ({tier_skipped} skipped on witness-tier mismatch)"
             if tier_skipped else ""),
          file=sys.stderr)
    if compared == 0:
        problems.append(
            f"--check found no overlapping counted metrics in {path}; "
            f"refusing to pass vacuously (run with --record first?)")
    return problems


def _record(path: str, rows: list[str], failures: list[str]) -> None:
    """Write the collected rows as a machine-readable snapshot."""
    parsed = []
    for row in rows:
        name, us, derived = (row.split(",", 2) + ["", ""])[:3]
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        parsed.append({"bench": name, "us_per_call": us_val,
                       "derived": derived})
    from repro.obs import hwcounters
    snapshot = {
        "schema": 1,
        "created_unix": int(time.time()),
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "python": platform.python_version(),
            "cpus": os.cpu_count(),
            # hardware-witness capability: which tier produced any
            # counter columns in these rows, and why (paranoid level,
            # per-event open errors) — so a snapshot's counter numbers
            # are never read without knowing their instrument
            "perf": hwcounters.probe().to_dict(),
        },
        "rows": parsed,
        "failures": failures,
    }
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2)
        f.write("\n")
    print(f"# recorded {len(parsed)} rows -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig10,fig13copy")
    ap.add_argument("--dry-run", action="store_true",
                    help="import and list the selected modules, run nothing "
                         "(CI smoke: catches import/registration breakage)")
    ap.add_argument("--record", metavar="PATH", default=None,
                    help="also write the rows as a JSON perf snapshot "
                         "(e.g. BENCH_IPC.json; uploaded as a CI artifact)")
    ap.add_argument("--check", metavar="PATH", default=None,
                    help="compare this run's COUNTED metrics (copies/req, "
                         "doorbells/req) against a recorded snapshot and "
                         "exit nonzero on regression — the non-timing CI "
                         "gate (e.g. --only fig6 --check BENCH_IPC.json); "
                         "also gates that an untraced run wrote exactly 0 "
                         "trace records")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="run with repro.obs tracing enabled (this process "
                         "AND every spawned child) and export the joined "
                         "timeline as Chrome/Perfetto trace JSON to PATH; "
                         "a per-phase decomposition table goes to stderr")
    ap.add_argument("--counters", action="store_true",
                    help="run with the hardware-witness profiler enabled "
                         "(repro.obs.hwcounters; this process AND every "
                         "spawned child) and print the per-phase counter "
                         "table to stderr; readings carry the host's "
                         "witness tier (perf-hw/perf-sw/rusage/none)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown module(s) {','.join(unknown)}; "
                 f"choose from {','.join(MODULES)}")
    if args.dry_run:
        for name in names:
            mod = MODULES[name]
            assert callable(mod.run), name
            print(f"{name},DRY,{mod.__name__}")
        return
    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()          # env-inherited: spawn children trace too
    if args.counters:
        from repro.obs import hwcounters
        tier = hwcounters.enable()  # env-inherited, like tracing
        print(f"# hwcounters: witness tier {tier}", file=sys.stderr)
    print("name,us_per_call,derived")
    collected: list[str] = []
    failures: list[str] = []
    for name in names:
        try:
            for row in MODULES[name].run():
                print(row, flush=True)
                collected.append(row)
        except Exception:
            failures.append(name)
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)
    # check BEFORE record: --check gates against the *committed* snapshot,
    # which --record (same path in CI) is about to overwrite
    problems = _check(args.check, collected) if args.check else []
    if args.trace:
        from repro.obs import hist as obs_hist
        from repro.obs import trace as obs_trace
        view = obs_trace.collect(unlink=True)
        obs_trace.disable()
        view.save_chrome(args.trace)
        print(f"# trace: {view.total_records} records from "
              f"{len(view.rings)} rings ({len(view.pids)} processes, "
              f"{view.total_drops} dropped) -> {args.trace}",
              file=sys.stderr)
        print(obs_hist.phase_report(view), file=sys.stderr)
    elif args.check:
        # the tracing-overhead gate, disabled half: an untraced benchmark
        # run must write EXACTLY zero trace records in this process —
        # tracing off means off, not "cheap"
        from repro.obs import trace as obs_trace
        emitted = obs_trace.emitted_count()
        if emitted:
            problems.append(
                f"tracing is disabled but {emitted} trace records were "
                f"written — a span site is missing its enabled guard")
    if args.counters:
        from repro.obs import hwcounters
        snap = hwcounters.snapshot()
        print(f"# hwcounters[{snap['tier']}]: {snap['scopes']} scopes "
              f"({snap['unavailable']} unavailable)", file=sys.stderr)
        for phase, acc in sorted(snap["phases"].items(),
                                 key=lambda kv: -kv[1].get("wall_ns", 0)):
            keys = ", ".join(f"{k}={v}" for k, v in sorted(acc.items()))
            print(f"#   {phase}: {keys}", file=sys.stderr)
        hwcounters.disable()
    elif args.check:
        # the same counted-zero contract for the hw profiler: profiling
        # off must account EXACTLY zero scopes in this process
        from repro.obs import hwcounters
        scopes = hwcounters.scope_count()
        if scopes:
            problems.append(
                f"hw profiling is disabled but {scopes} counter scopes "
                f"were accounted — a site is missing its PROF.enabled "
                f"guard")
    if args.record:
        _record(args.record, collected, failures)
    for p in problems:
        print(f"# REGRESSION {p}", file=sys.stderr)
    if problems:
        raise SystemExit(2)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
