"""Fig. 2 analogue: real IPC transports across message sizes.

Producer *process* → consumer *process*, same machine:

- ``pipe``         — pickle over ``multiprocessing.Pipe`` (the classic
  serialize + kernel-buffer double-copy baseline);
- ``shm``          — the repro's shared-memory ring transport, consumer
  copies the payload out (conservative: 1 copy in + 1 copy out);
- ``shm-zerocopy`` — same transport, consumer reads the payload in place
  (views into the pre-mapped slot; the paper's zero-copy receive).

Sub-MB sizes ride the transport's small-message fast path: binary wire
meta (no per-send pickle) and pipelined **coalesced frames** (up to 8
messages per ring slot under ``FLAG_COALESCED``), so slot claim, meta
encode, and doorbell are amortized K-ways — the control-plane cost that
dominated the old per-call latency at 64 KB.  ≥1 MB rows keep the plain
sync slot path (bandwidth-bound; fig6 owns the heap sweep above that).

Reports microseconds per message, MB/s, and messages/s for each
(transport, size).  The shm ring should meet or beat the pipe baseline
from ~64 KB up.
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np

from benchmarks.common import fmt_row

SIZES = (64 << 10, 1 << 20, 8 << 20)
_TOTAL_TARGET = 64 << 20          # ~bytes moved per (transport, size) point
_COALESCE_BELOW = 1 << 20         # sub-MB: use the coalesced fast path
_COALESCE_MAX = 8


def _n_msgs(size: int) -> int:
    return int(np.clip(_TOTAL_TARGET // size, 8, 256))


def _spec(size: int):
    from repro.ipc.transport import TransportSpec

    # heap disabled: fig2 measures the *slot* transport (fig6 owns the
    # large-payload heap sweep) — without this, >=8MB points would silently
    # route via the bulk heap under the default policy threshold.  Small
    # sizes get slots big enough to hold one full coalesced frame, and a
    # deeper ring so the producer keeps streaming while the consumer works
    # through a frame's K messages (slot recycle happens at frame, not
    # message, granularity).
    slot = size + (1 << 16)
    if size < _COALESCE_BELOW:
        slot = _COALESCE_MAX * ((size + 63) // 64 * 64) + (1 << 16)
        return TransportSpec(data_slots=8, data_slot_bytes=slot,
                             heap_extents=0)
    return TransportSpec(data_slots=4, data_slot_bytes=slot, heap_extents=0)


def _policy(size: int):
    from repro.core.policy import OffloadPolicy

    if size < _COALESCE_BELOW:
        # small-message fast path: pipelined sends join microbatch frames.
        # The wide window lets frames fill to K on a slow-Python producer,
        # and the long spin keeps both endpoints in the yield-only phase
        # across the inter-frame gap — on this kernel class a single
        # quantum sleep costs ~1 ms (see OffloadPolicy.spin_us), which
        # would dwarf the per-frame cost being measured
        return OffloadPolicy(coalesce_bytes=_COALESCE_BELOW,
                             coalesce_max=_COALESCE_MAX,
                             coalesce_window_us=1000.0,
                             spin_us=2000.0,
                             offload_threshold_bytes=1 << 62)
    return OffloadPolicy()        # sends stay inline (sync copy)


def _send_mode(size: int) -> str:
    return "pipelined" if size < _COALESCE_BELOW else "sync"


# -- child entries (spawn-safe, module level) --------------------------------

_WARMUP = 3      # untimed messages: page first-touch, import/jit tails


def _pipe_producer(conn, size: int, n: int) -> None:
    arr = np.arange(size // 8, dtype=np.int64)
    conn.send("ready")                            # two-way handshake: child
    conn.recv()                                   # startup stays untimed
    for _ in range(n + _WARMUP):
        conn.send(arr)
    conn.close()


def _shm_producer(name: str, size: int, n: int) -> None:
    from repro.ipc import ShmTransport

    t = ShmTransport.attach(name, policy=_policy(size))
    arr = np.arange(size // 8, dtype=np.int64)
    mode = _send_mode(size)
    t.send_msg("ready", timeout_s=60)             # two-way handshake
    t.recv_msg(timeout_s=60)
    for _ in range(n + _WARMUP):
        t.send({"a": arr}, mode=mode)
    t.data.flush()
    t.recv_msg(timeout_s=60)                      # hold mapping until consumer done
    t.close()


# -- measurements ------------------------------------------------------------

def _bench_pipe(size: int, n: int) -> float:
    ctx = mp.get_context("spawn")
    parent, child = ctx.Pipe()
    p = ctx.Process(target=_pipe_producer, args=(child, size, n), daemon=True)
    p.start()
    parent.recv()                                 # child is up
    parent.send("go")
    for _ in range(_WARMUP):
        parent.recv()
    t0 = time.perf_counter()
    for _ in range(n):
        arr = parent.recv()
    dt = time.perf_counter() - t0
    assert arr.nbytes == size
    p.join(timeout=60)
    return dt


def _bench_shm(size: int, n: int, zerocopy: bool) -> float:
    from repro.ipc import ShmTransport

    ctx = mp.get_context("spawn")
    t = ShmTransport.create(spec=_spec(size), policy=_policy(size))
    p = ctx.Process(target=_shm_producer, args=(t.name, size, n), daemon=True)
    p.start()
    t.recv_msg(timeout_s=60)                      # child is up + attached
    t.send_msg("go", timeout_s=60)
    for _ in range(_WARMUP):
        item = t.recv(timeout_s=60, copy=not zerocopy)
        if zerocopy:
            item.release()
    # size-aware receive deferral only pays off for single big messages;
    # coalesced small-message bursts arrive many-per-poll, so sleeping a
    # predicted copy latency before each poll would just add latency
    hint = size if size >= _COALESCE_BELOW else 0
    t0 = time.perf_counter()
    checksum = 0
    for _ in range(n):
        if zerocopy:
            with t.recv(copy=False, timeout_s=60, hint_nbytes=hint) as lease:
                checksum += int(lease.tree["a"][-1])   # touch without copying
        else:
            tree, _ = t.recv(timeout_s=60, hint_nbytes=hint)
            checksum += int(tree["a"][-1])
    dt = time.perf_counter() - t0
    t.send_msg("done", timeout_s=60)
    p.join(timeout=60)
    t.close()
    assert checksum == n * (size // 8 - 1)
    return dt


_ROUNDS = 2       # best-of rounds per point: the shared host's bandwidth
                  # swings ~5x minute to minute, and a transport's capability
                  # is its good-mood number — one unlucky draw should not be
                  # committed as the snapshot


def run():
    benches = {
        "pipe": lambda size, n: _bench_pipe(size, n),
        "shm": lambda size, n: _bench_shm(size, n, zerocopy=False),
        "shm-zerocopy": lambda size, n: _bench_shm(size, n, zerocopy=True),
    }
    for size in SIZES:
        n = _n_msgs(size)
        mb = size / (1 << 20)
        best = {}
        for _ in range(_ROUNDS):
            for name, fn in benches.items():
                dt = fn(size, n)
                if name not in best or dt < best[name]:
                    best[name] = dt
        for name, dt in best.items():
            us = dt / n * 1e6
            mbps = size * n / dt / (1 << 20)
            yield fmt_row(f"fig2/{name}/{mb:g}MB", us,
                          f"{mbps:.0f}MB/s;{n / dt:.0f}msg/s")
