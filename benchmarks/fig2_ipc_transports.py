"""Fig. 2 analogue: real IPC transports across message sizes.

Producer *process* → consumer *process*, same machine:

- ``pipe``         — pickle over ``multiprocessing.Pipe`` (the classic
  serialize + kernel-buffer double-copy baseline);
- ``shm``          — the repro's shared-memory ring transport, consumer
  copies the payload out (conservative: 1 copy in + 1 copy out);
- ``shm-zerocopy`` — same transport, consumer reads the payload in place
  (views into the pre-mapped slot; the paper's zero-copy receive).

Reports microseconds per message and MB/s for each (transport, size).
The shm ring should meet or beat the pipe baseline from ~1 MB up.
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np

from benchmarks.common import fmt_row

SIZES = (64 << 10, 1 << 20, 8 << 20)
_TOTAL_TARGET = 64 << 20          # ~bytes moved per (transport, size) point


def _n_msgs(size: int) -> int:
    return int(np.clip(_TOTAL_TARGET // size, 8, 256))


# -- child entries (spawn-safe, module level) --------------------------------

_WARMUP = 3      # untimed messages: page first-touch, import/jit tails


def _pipe_producer(conn, size: int, n: int) -> None:
    arr = np.arange(size // 8, dtype=np.int64)
    conn.send("ready")                            # two-way handshake: child
    conn.recv()                                   # startup stays untimed
    for _ in range(n + _WARMUP):
        conn.send(arr)
    conn.close()


def _shm_producer(name: str, size: int, n: int) -> None:
    from repro.core.policy import OffloadPolicy
    from repro.ipc import ShmTransport

    policy = OffloadPolicy()                      # sends stay inline (sync copy)
    t = ShmTransport.attach(name, policy=policy)
    arr = np.arange(size // 8, dtype=np.int64)
    t.send_msg("ready", timeout_s=60)             # two-way handshake
    t.recv_msg(timeout_s=60)
    for _ in range(n + _WARMUP):
        t.send({"a": arr}, mode="sync")
    t.data.flush()
    t.recv_msg(timeout_s=60)                      # hold mapping until consumer done
    t.close()


# -- measurements ------------------------------------------------------------

def _bench_pipe(size: int, n: int) -> float:
    ctx = mp.get_context("spawn")
    parent, child = ctx.Pipe()
    p = ctx.Process(target=_pipe_producer, args=(child, size, n), daemon=True)
    p.start()
    parent.recv()                                 # child is up
    parent.send("go")
    for _ in range(_WARMUP):
        parent.recv()
    t0 = time.perf_counter()
    for _ in range(n):
        arr = parent.recv()
    dt = time.perf_counter() - t0
    assert arr.nbytes == size
    p.join(timeout=60)
    return dt


def _bench_shm(size: int, n: int, zerocopy: bool) -> float:
    from repro.ipc import ShmTransport
    from repro.ipc.transport import TransportSpec

    ctx = mp.get_context("spawn")
    # heap disabled: fig2 measures the *slot* transport (fig6 owns the
    # large-payload heap sweep) — without this, >=8MB points would silently
    # route via the bulk heap under the default policy threshold
    spec = TransportSpec(data_slots=4, data_slot_bytes=size + (1 << 16),
                         heap_extents=0)
    t = ShmTransport.create(spec=spec)
    p = ctx.Process(target=_shm_producer, args=(t.name, size, n), daemon=True)
    p.start()
    t.recv_msg(timeout_s=60)                      # child is up + attached
    t.send_msg("go", timeout_s=60)
    for _ in range(_WARMUP):
        t.recv(timeout_s=60)
    t0 = time.perf_counter()
    checksum = 0
    for _ in range(n):
        if zerocopy:
            with t.recv(copy=False, timeout_s=60, hint_nbytes=size) as lease:
                checksum += int(lease.tree["a"][-1])   # touch without copying
        else:
            tree, _ = t.recv(timeout_s=60, hint_nbytes=size)
            checksum += int(tree["a"][-1])
    dt = time.perf_counter() - t0
    t.send_msg("done", timeout_s=60)
    p.join(timeout=60)
    t.close()
    assert checksum == n * (size // 8 - 1)
    return dt


def run():
    for size in SIZES:
        n = _n_msgs(size)
        mb = size / (1 << 20)
        for name, dt in (
            ("pipe", _bench_pipe(size, n)),
            ("shm", _bench_shm(size, n, zerocopy=False)),
            ("shm-zerocopy", _bench_shm(size, n, zerocopy=True)),
        ):
            us = dt / n * 1e6
            mbps = size * n / dt / (1 << 20)
            yield fmt_row(f"fig2/{name}/{mb:g}MB", us, f"{mbps:.0f}MB/s")
