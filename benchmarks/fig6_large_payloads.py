"""Fig. 6 analogue: large-payload sweep — fixed slots vs the bulk heap.

The paper opens with services exchanging *hundreds of megabytes per
request*; fixed-slot rings make that unsendable (or force gigantic
arenas).  This sweep sends 1 MB → 256 MB messages producer→consumer
across a real process boundary three ways:

- ``inline``    — the pre-heap datapath: slots sized to the message
  (``data_slot_bytes = size``), sync sends.  256 MB of payload needs
  >0.5 GB of fully-reserved slot arena *per direction*;
- ``heap``      — 1 MB slots + bulk-heap extents, sync sends (one
  blocking gather into the extents, ring carries the descriptor);
- ``heap-pipe`` — same geometry, pipelined sends: the fill is split into
  ``heap_chunk_bytes`` SG submissions on the channel's work queue, so
  the *producer's next produce step* overlaps the offloaded copy.  Run
  at one size: its purpose here is the **counted** submission metrics
  (doorbells/request with chunked fills) — on a 2-core CI box both the
  produce pass and the copy are DRAM-bandwidth-bound, so overlapping
  them cannot beat the sync gather on wall clock (no idle bandwidth to
  hide the copy in; with real compute upstream, or a DSA doing the
  copy, the overlap is the win — that is the paper's point).

Each message is *produced* first (one GIL-releasing numpy pass over the
payload — the stand-in for upstream compute); the reported MB/s is
end-to-end produced-and-delivered payload.

Every heap row carries **counted** metrics from the process-wide
CopyEngine — ``copies/req`` (must stay 1.00: the send-side heap fill is
the only payload memcpy; the consumer reads zero-copy extent views) and
``doorbells/req`` — which is what ``run.py --check BENCH_IPC.json``
gates in CI: a datapath change that sneaks in a second copy or makes
every chunk ring its own doorbell fails the build even if timings are
too noisy to notice.

Run: ``PYTHONPATH=src python -m benchmarks.run --only fig6``
"""
from __future__ import annotations

import multiprocessing as mp
import time

import numpy as np


def fmt_row(name: str, us: float, derived: str) -> str:
    """Local fmt_row (benchmarks.common imports jax; the spawn children
    importing this module must stay jax-free)."""
    return f"{name},{us:.1f},{derived}"


SIZES = (1 << 20, 16 << 20, 64 << 20, 256 << 20)
_TOTAL_TARGET = 1 << 30          # ~bytes moved per (variant, size) point
_WARMUP = 2
_CHUNK = 8 << 20                 # pipelined heap fill chunk


def _n_msgs(size: int) -> int:
    return int(np.clip(_TOTAL_TARGET // size, 3, 64))


def _specs(size: int):
    """(inline spec, heap spec) for one sweep point."""
    from repro.ipc import TransportSpec

    inline = TransportSpec(data_slots=2, data_slot_bytes=size + (1 << 16),
                           ctrl_slots=4, ctrl_slot_bytes=16 << 10,
                           heap_extents=0)
    extent = max(1 << 20, size // 4)
    # enough extents for the in-flight window (pipelined fills + published
    # messages + the consumer's held lease) without scatter fallbacks
    heap = TransportSpec(data_slots=2, data_slot_bytes=1 << 20,
                         ctrl_slots=4, ctrl_slot_bytes=16 << 10,
                         heap_extent_bytes=extent,
                         heap_extents=(size // extent) * 6)
    return inline, heap


def _policy(variant: str):
    from repro.core.policy import OffloadPolicy

    if variant == "heap-pipe":
        return OffloadPolicy(mode="pipelined", offload_threshold_bytes=1,
                             heap_threshold_bytes=1 << 20,
                             heap_chunk_bytes=_CHUNK, pipeline_depth=2,
                             poll_interval_us=100.0)
    # sync/inline: caller-thread copy, no offload round trip
    return OffloadPolicy(mode="sync", offload_threshold_bytes=1 << 62,
                         heap_threshold_bytes=1 << 20,
                         poll_interval_us=100.0)


def _consumer_entry(name: str, variant: str, size: int, n: int) -> None:
    """Child: drain n+warmup messages as zero-copy leases (heap or slot
    views alike), touching one element per message."""
    from repro.ipc import ShmTransport

    t = ShmTransport.attach(name, policy=_policy(variant))
    t.send_msg("ready", timeout_s=120)
    for _ in range(n + _WARMUP):
        with t.recv(copy=False, timeout_s=300, hint_nbytes=size) as lease:
            assert int(lease.tree["a"][-1]) == size // 8 - 1
    t.send_msg("done", timeout_s=120)
    t.recv_msg(timeout_s=120)     # hold the mapping until the parent is done
    t.close()


def _bench(variant: str, size: int, n: int):
    """One sweep point; returns (seconds, counted copies/req,
    counted doorbells/req, scatter allocs)."""
    from repro.core.copyengine import get_engine
    from repro.ipc import ShmTransport

    inline_spec, heap_spec = _specs(size)
    spec = inline_spec if variant == "inline" else heap_spec
    t = ShmTransport.create(spec=spec, policy=_policy(variant))
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_consumer_entry, args=(t.name, variant, size, n),
                    daemon=True)
    p.start()
    t.recv_msg(timeout_s=120)
    base = np.arange(size // 8, dtype=np.int64)
    # rotate more buffers than pipelined keeps in flight (depth 2 + the
    # one being filled): producing message k+4 never races the engine's
    # copy of message k
    scratch = [np.empty_like(base) for _ in range(4)]
    for j in range(_WARMUP):
        np.add(base, 0, out=scratch[j % 4])
        t.send({"a": scratch[j % 4]})
    t.data.flush(timeout_s=300)
    eng = get_engine()
    before = eng.stats.doorbells
    tags0 = eng.tagged_snapshot()["copies"]
    t0 = time.perf_counter()
    for i in range(n):
        buf = scratch[i % 4]
        np.add(base, 0, out=buf)     # produce: upstream compute stand-in
        t.send({"a": buf}, timeout_s=300)
    t.data.flush(timeout_s=300)
    assert t.recv_msg(timeout_s=300) == "done"
    dt = time.perf_counter() - t0
    doorbells = eng.stats.doorbells - before
    tags1 = eng.tagged_snapshot()["copies"]
    # send-side payload copies: slot path tags "send", heap path
    # "heap_fill" (the consumer's zero-copy lease adds none)
    copies = sum(tags1.get(k, 0) - tags0.get(k, 0)
                 for k in ("send", "heap_fill"))
    scatter = t.heap.stats.scatter_allocs if t.heap is not None else 0
    t.send_msg("bye", timeout_s=60)
    p.join(timeout=120)
    t.close()
    return dt, copies / n, doorbells / n, scatter


def run():
    """Yield CSV rows: µs/message + MB/s per (variant, size); heap rows
    add the counted copies/req + doorbells/req the CI gate checks."""
    for size in SIZES:
        n = _n_msgs(size)
        mb = size >> 20
        variants = ("inline", "heap")
        if size == 16 << 20:         # one chunked-offload point: the
            variants += ("heap-pipe",)   # counted doorbells/req row
        for variant in variants:
            dt, copies, doorbells, scatter = _bench(variant, size, n)
            us = dt / n * 1e6
            mbps = size * n / dt / (1 << 20)
            derived = f"{mbps:.0f}MB/s"
            if variant != "inline":
                derived += (f";copies/req={copies:.2f}"
                            f";doorbells/req={doorbells:.2f}")
                if scatter:
                    derived += f";scatter={scatter}"
            yield fmt_row(f"fig6/{variant}/{mb}MB", us, derived)
