"""Shared timing helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_us(fn: Callable, *, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def block(x):
    return jax.block_until_ready(x)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def witness_tokens(totals: dict, tier: str, *, nbytes: int = 0,
                   reqs: int = 0) -> str:
    """Render counter readings as ``k=v`` derived-row tokens + tier.

    Emits only the columns the witness tier actually measured —
    ``insn/byte`` and ``llc_miss/byte`` on `perf-hw`, ``cpu_ns/byte``
    from task-clock on `perf-sw`/`rusage`, ``ctx_sw/req`` wherever
    context switches are counted — and always appends ``witness=<tier>``
    so no reading can masquerade as a different tier's
    (``run.py --check`` treats rows from different tiers as
    incomparable).
    """
    toks = []
    insn = totals.get("instructions", 0)
    llc = totals.get("llc_misses", 0)
    clk = totals.get("task_clock_ns", 0)
    csw = totals.get("ctx_sw")
    if nbytes > 0:
        if insn:
            toks.append(f"insn/byte={insn / nbytes:.4f}")
        if llc:
            toks.append(f"llc_miss/byte={llc / nbytes:.6f}")
        if clk and not insn:
            toks.append(f"cpu_ns/byte={clk / nbytes:.4f}")
    if reqs > 0 and csw is not None:
        toks.append(f"ctx_sw/req={csw / reqs:.2f}")
    toks.append(f"witness={tier}")
    return ";".join(toks)


def counter_meter():
    """A fresh standalone :class:`repro.obs.hwcounters.Meter` (jax-free
    import path, safe in measurement children)."""
    from repro.obs import hwcounters
    return hwcounters.Meter()


def simulated_dsa_put(latency_model):
    """A calibrated *simulated* DSA engine: completion after the modeled
    latency, without consuming caller CPU (sleep releases the GIL).  Used to
    validate mode semantics under genuinely parallel copy hardware — this
    1-core container cannot overlap real memcpys with compute."""
    import jax
    import numpy as np
    import time

    def put(batch, sharding=None):
        nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(batch))
        time.sleep(latency_model.predict_us(nbytes) * 1e-6)
        return batch

    return put
