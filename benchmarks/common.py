"""Shared timing helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_us(fn: Callable, *, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def block(x):
    return jax.block_until_ready(x)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def simulated_dsa_put(latency_model):
    """A calibrated *simulated* DSA engine: completion after the modeled
    latency, without consuming caller CPU (sleep releases the GIL).  Used to
    validate mode semantics under genuinely parallel copy hardware — this
    1-core container cannot overlap real memcpys with compute."""
    import jax
    import numpy as np
    import time

    def put(batch, sharding=None):
        nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(batch))
        time.sleep(latency_model.predict_us(nbytes) * 1e-6)
        return batch

    return put
