"""Paper Fig. 9: the linear transfer-latency model — per-node calibration of
L = L_fixed + alpha * size_MB and its dispersion (paper: std dev < 2%)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import block, fmt_row
from repro.core.latency import calibrate


def run() -> list[str]:
    model = calibrate(
        lambda buf: block(jax.device_put(buf)),
        sizes_bytes=(1 << 18, 1 << 20, 1 << 22, 1 << 23),
        repeats=10)
    pred_1mb = model.predict_us(1 << 20)
    return [fmt_row("fig9/latency_model", pred_1mb,
                    f"L_fixed={model.l_fixed_us:.1f}us;"
                    f"alpha={model.alpha_us_per_mb:.2f}us_per_MB;"
                    f"rel_std={model.rel_std:.1%};"
                    f"bw={model.bandwidth_gbps():.1f}GBps")]
