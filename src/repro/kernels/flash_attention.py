"""GQA flash attention (forward) with explicit VMEM tiling.

The attention hot-spot under the same pipelined-DMA discipline as
``offload_copy``: BlockSpec-driven HBM→VMEM streaming of K/V tiles with a
running (m, l, acc) online-softmax state in VMEM scratch — the bounded
working set that makes 32k-token prefill feasible.

Grid: (batch·q_heads, q_blocks, kv_blocks); kv dimension is ``arbitrary``
(sequential) so scratch carries across kv tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  bq: int, bk: int, nk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    should_compute = True
    if causal:
        # skip tiles fully above the diagonal
        should_compute = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(should_compute)
    def _():
        q = q_ref[0, :, 0, :]                       # (bq, hd)
        k = k_ref[0, :, 0, :]                       # (bk, hd)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * corr + jnp.sum(p, axis=1)
        m_s[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, hd)
        acc[...] = acc[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_s[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False):
    """q: (B,S,H,hd); k/v: (B,T,K,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk
    scale = 1.0 / float(hd) ** 0.5

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda bh, qi, ki: (bh // h, qi, bh % h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bh, qi, ki: (bh // h, ki, (bh % h) // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bh, qi, ki: (bh // h, ki, (bh % h) // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bh, qi, ki: (bh // h, qi, bh % h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
