"""The DSA-engine analogue on TPU: an explicit-DMA streaming copy/transform
kernel with ROCKET's three execution modes and the VMEM-injection knob.

Structure (paper §II-B mapped to TPU):
- *descriptor submission* = ``pltpu.make_async_copy(...).start()``;
- *completion flag*       = the DMA semaphore, ``.wait()``;
- *sync mode*             = depth-1: copy-in → wait → transform → copy-out → wait;
- *async/pipelined*       = depth-k rotation: block i+depth's copy-in is
  submitted while block i is transformed (compute hides the DMA, the same
  overlap the paper gets from its async engine);
- *cache injection*       = ``inject=True`` fuses the consumer (a global
  reduction over the destination — the paper's Fig.-5 microbenchmark) into
  the kernel while the data is VMEM-resident, instead of a second HBM pass.

The transform is a fused scale+cast (a copy engine with a twist, as used by
the data pipeline for dtype conversion on the fly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

LANE = 128          # TPU lane width; last dim of blocks


def _copy_kernel(x_hbm, y_hbm, sum_out, vmem_in, vmem_out, sem_in, sem_out,
                 acc, *, block_rows: int, depth: int, n_blocks: int,
                 scale: float, out_dtype, inject: bool):
    i = pl.program_id(0)
    slot = jax.lax.rem(i, depth)

    def in_copy(b, s):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(b * block_rows, block_rows)],
            vmem_in.at[s], sem_in.at[s])

    def out_copy(b, s):
        return pltpu.make_async_copy(
            vmem_out.at[s],
            y_hbm.at[pl.ds(b * block_rows, block_rows)], sem_out.at[s])

    # --- warm-up: submit the first `depth` descriptors ------------------------
    @pl.when(i == 0)
    def _():
        if inject:
            acc[...] = jnp.zeros_like(acc)
        for d in range(depth):

            @pl.when(d < n_blocks)
            def _():
                in_copy(d, d).start()

    # --- completion check for this block's copy-in ----------------------------
    in_copy(i, slot).wait()

    # --- the previous occupant of the out-slot must have drained ---------------
    @pl.when(i >= depth)
    def _():
        out_copy(i - depth, slot).wait()

    # --- transform while VMEM-resident ----------------------------------------
    data = vmem_in[slot]
    vmem_out[slot] = (data.astype(jnp.float32) * scale).astype(out_dtype)
    if inject:   # fused consumer: reduce the destination while it's in VMEM
        acc[0, 0] += jnp.sum(data.astype(jnp.float32) * scale)

    # --- submit copy-out + prefetch block i+depth ------------------------------
    out_copy(i, slot).start()

    @pl.when(i + depth < n_blocks)
    def _():
        in_copy(i + depth, slot).start()

    # --- drain on the last block ------------------------------------------------
    @pl.when(i == n_blocks - 1)
    def _():
        for d in range(depth):
            b = i - d

            @pl.when((b >= 0) & (b + depth >= n_blocks))
            def _():
                out_copy(b, jax.lax.rem(b, depth)).wait()
        if inject:
            sum_out[0, 0] = acc[0, 0]


def offload_copy_pallas(x, *, scale: float = 1.0, out_dtype=None,
                        depth: int = 2, block_rows: int = 256,
                        inject: bool = False, interpret: bool = False):
    """x: (R, LANE·k) — streams row-blocks through VMEM. Returns (y, sum|None)."""
    assert x.ndim == 2, "offload_copy operates on 2D row-major slabs"
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    n_blocks = rows // block_rows
    depth = max(1, min(depth, n_blocks))
    out_dtype = jnp.dtype(out_dtype or x.dtype)

    kernel = functools.partial(
        _copy_kernel, block_rows=block_rows, depth=depth, n_blocks=n_blocks,
        scale=scale, out_dtype=out_dtype, inject=inject)

    out_shapes = [
        jax.ShapeDtypeStruct((rows, cols), out_dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    y, total = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[pl.BlockSpec(memory_space=pl.ANY),
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((depth, block_rows, cols), x.dtype),
            pltpu.VMEM((depth, block_rows, cols), out_dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x)
    return (y, total[0, 0]) if inject else (y, None)
