"""Mamba2 SSD chunk-scan kernel: carried (N,P) state in VMEM scratch.

Grid (batch, heads, chunks); the chunk dimension is sequential so the
recurrent state lives in VMEM across chunk tiles — inter-chunk state passing
without HBM round-trips (the VMEM-residency/"injection" discipline applied
to the scan carry).  All contractions are 2D MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, dsk_ref,
                y_ref, hout_ref, state, *, q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (q, P)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)      # (q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)      # (q, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (q,)
    da = da_ref[0, :, 0].astype(jnp.float32)        # (q,)
    dsk = dsk_ref[0, 0]

    sgm = jnp.cumsum(da)                             # (q,) inclusive
    s_last = sgm[q - 1]
    dtx = dt[:, None] * x                            # (q, P)

    # intra-chunk: M[j,i] = exp(s_j - s_i) (C_j . B_i), i <= j
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (q,q)
    ldiff = sgm[:, None] - sgm[None, :]
    ji = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(ii <= ji, cb * jnp.exp(ldiff), 0.0)
    y = jax.lax.dot_general(m, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (q,P)

    # inter-chunk: y_j += exp(s_j) C_j . h_prev
    y += jax.lax.dot_general(cm, state[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(sgm)[:, None]

    # state update: h = exp(s_last) h + B^T (decay_to_end * dtx)
    decay = jnp.exp(s_last - sgm)[:, None]                          # (q,1)
    upd = jax.lax.dot_general(bm, decay * dtx, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (N,P)
    state[...] = state[...] * jnp.exp(s_last) + upd

    y_ref[0, :, 0, :] = (y + dsk * x).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _():
        hout_ref[0, 0, :, :] = state[...].astype(hout_ref.dtype)


def ssd_scan_pallas(xh, bm, cm, dt, da, d_skip, *, chunk: int = 256,
                    interpret: bool = False):
    """xh (B,S,H,P); bm/cm (B,S,G,N); dt/da (B,S,H); d_skip (H,).

    Returns (y (B,S,H,P) fp32, h_final (B,H,N,P) fp32).
    """
    b, s, nh, p = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = nh // g
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    kernel = functools.partial(_ssd_kernel, q=q, nc=nc)
    dsk = d_skip.reshape(nh, 1).astype(jnp.float32)

    y, hf = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, nh, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, bm, cm, dt, da, dsk)
    return y, hf
