"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def offload_copy(x, *, scale: float = 1.0, out_dtype=None, inject: bool = False):
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    y = (x.astype(jnp.float32) * scale).astype(out_dtype)
    total = jnp.sum(x.astype(jnp.float32) * scale) if inject else None
    return y, total


def flash_attention(q, k, v, *, causal: bool = True, softcap: float = 0.0):
    """q: (B,S,H,hd); k/v: (B,T,K,hd) — GQA reference, fp32 softmax."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bskge,btke->bkgst", qg, k).astype(jnp.float32) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btke->bskge", w.astype(q.dtype), v)
    return o.reshape(b, s, h, hd)


def ssd_scan(xh, bm, cm, dt, da, d_skip, *, chunk: int = 256):
    """Reference for the Mamba2 chunk-scan kernel: literal recurrence.

    xh (B,S,H,P); bm/cm (B,S,G,N); dt/da (B,S,H); d_skip (H,).
    Returns (y (B,S,H,P) fp32, h_final (B,H,N,P) fp32).
    """
    b, s, nh, p = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = nh // g
    bm_h = jnp.repeat(bm, hg, axis=2).astype(jnp.float32)   # (B,S,H,N)
    cm_h = jnp.repeat(cm, hg, axis=2).astype(jnp.float32)
    dtx = dt[..., None].astype(jnp.float32) * xh.astype(jnp.float32)

    def step(h, xs):
        bmt, cmt, dtxt, dat = xs
        h = h * jnp.exp(dat)[..., None, None] + bmt[..., :, None] * dtxt[..., None, :]
        y = jnp.einsum("bhN,bhNp->bhp", cmt, h)
        return h, y

    h0 = jnp.zeros((b, nh, n, p), jnp.float32)
    xs = (jnp.moveaxis(bm_h, 1, 0), jnp.moveaxis(cm_h, 1, 0),
          jnp.moveaxis(dtx, 1, 0), jnp.moveaxis(da.astype(jnp.float32), 1, 0))
    hf, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                              # (B,S,H,P)
    y = y + d_skip[None, None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)
    return y, hf
