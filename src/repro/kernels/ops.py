"""jit'd public wrappers for the Pallas kernels, with ROCKET offload control:
below the size threshold (or via policy device=inline) the inline XLA path is
used instead of the kernel — the paper's cpu/dsa knob at tier 3.

``interpret=True`` is selected automatically on non-TPU backends so the
kernels validate on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.policy import OffloadPolicy
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.offload_copy import offload_copy_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "scale", "out_dtype", "depth", "block_rows", "inject", "policy"))
def offload_copy(x, *, scale: float = 1.0, out_dtype=None, depth: int = 2,
                 block_rows: int = 256, inject: bool = False,
                 policy: OffloadPolicy | None = None):
    """Streaming copy/transform; inline XLA path below the size threshold."""
    pol = policy or OffloadPolicy()
    if not pol.should_offload(x.size * x.dtype.itemsize):
        return ref.offload_copy(x, scale=scale, out_dtype=out_dtype,
                                inject=inject or pol.injection_enabled())
    mode_depth = {"sync": 1, "async": 2, "pipelined": max(depth, 2)}[
        pol.mode.value]
    return offload_copy_pallas(
        x, scale=scale, out_dtype=out_dtype, depth=mode_depth,
        block_rows=block_rows, inject=inject or pol.injection_enabled(),
        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, bm, cm, dt, da, d_skip, *, chunk: int = 256):
    return ssd_scan_pallas(xh, bm, cm, dt, da, d_skip, chunk=chunk,
                           interpret=_interpret())
