"""Observability plane: cross-process tracing, histograms, unified metrics.

The paper's contribution is *characterizing* where IPC wall clock goes —
synchronization, cache visibility, copy placement — and this package is
the runtime's instrument for doing the same to itself:

- :mod:`repro.obs.trace` — an always-on-capable span recorder writing
  fixed-size binary records into per-thread shared-memory rings
  (single-writer, no locks, no allocation on the hot path), a request id
  that rides the existing binary wire meta across processes, and a
  collector + Chrome-trace exporter that joins every process's spans
  into one timeline without any extra IPC;
- :mod:`repro.obs.hist` — fixed-size log-bucket latency histograms,
  mergeable across processes, built straight from collected trace
  records (per-phase decomposition);
- :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` that unifies the
  stack's ad-hoc ``*Stats`` objects behind one flat snapshot/delta API,
  plus the :class:`SLOTracker` that finally wires ``ft/monitor.py`` and
  ``core/latency.py`` into the serving path.

Nothing here imports jax (benchmark measurement children stay jax-free),
and with tracing disabled (the default) the hot-path cost is one
attribute check — zero records are written, which CI gates on.
"""
from repro.obs import hist, metrics, trace
from repro.obs.hist import Histogram, phase_histograms, phase_report
from repro.obs.metrics import MetricsRegistry, SLOTracker
from repro.obs.trace import TRACE, TraceView, collect, disable, enable

__all__ = [
    "trace", "hist", "metrics",
    "TRACE", "TraceView", "collect", "disable", "enable",
    "Histogram", "phase_histograms", "phase_report",
    "MetricsRegistry", "SLOTracker",
]
