"""Observability plane: cross-process tracing, histograms, unified metrics.

The paper's contribution is *characterizing* where IPC wall clock goes —
synchronization, cache visibility, copy placement — and this package is
the runtime's instrument for doing the same to itself:

- :mod:`repro.obs.trace` — an always-on-capable span recorder writing
  fixed-size binary records into per-thread shared-memory rings
  (single-writer, no locks, no allocation on the hot path), a request id
  that rides the existing binary wire meta across processes, and a
  collector + Chrome-trace exporter that joins every process's spans
  into one timeline without any extra IPC;
- :mod:`repro.obs.hist` — fixed-size log-bucket latency histograms,
  mergeable across processes, built straight from collected trace
  records (per-phase decomposition);
- :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` that unifies the
  stack's ad-hoc ``*Stats`` objects behind one flat snapshot/delta API,
  plus the :class:`SLOTracker` that finally wires ``ft/monitor.py`` and
  ``core/latency.py`` into the serving path;
- :mod:`repro.obs.hwcounters` — the hardware-witness plane: a
  zero-dependency ``perf_event_open`` binding (grouped counters, one
  ``read()`` per scope, per-thread attach) with counted degradation
  tiers (``perf-hw`` → ``perf-sw`` → ``rusage`` → ``none``), a
  phase-attribution profiler for the serving hot path, and counter
  deltas that ride the trace rings as ordinary records.

Nothing here imports jax (benchmark measurement children stay jax-free),
and with tracing disabled (the default) the hot-path cost is one
attribute check — zero records are written, which CI gates on.  The
same counted-zero contract holds for ``hwcounters.scope_count()``.
"""
from repro.obs import hist, hwcounters, metrics, trace
from repro.obs.hist import Histogram, phase_histograms, phase_report
from repro.obs.hwcounters import Capability, CounterScope, Meter, PROF
from repro.obs.metrics import MetricsRegistry, SLOTracker
from repro.obs.trace import TRACE, TraceView, collect, disable, enable

__all__ = [
    "trace", "hist", "metrics", "hwcounters",
    "TRACE", "TraceView", "collect", "disable", "enable",
    "Histogram", "phase_histograms", "phase_report",
    "MetricsRegistry", "SLOTracker",
    "Capability", "CounterScope", "Meter", "PROF",
]
