"""Hardware-witness plane: `perf_event_open` counters for the hot path.

The paper states its efficiency claims in *instructions* and *LLC
behavior*; wall clock on a small shared CI host swings ~5x.  This module
gives every phase of the serving hot path a hardware witness — counter
deltas read around the same scopes the span tracer times — with the
same disciplines as the rest of `repro.obs`:

- **Zero dependencies.**  The binding is raw ctypes `syscall(2)` +
  `read(2)` + `ioctl(2)`; no `perf` binary, no python-perf, nothing to
  install.
- **One `read()` per scope.**  Counters open as one *group*
  (`PERF_FORMAT_GROUP`) per thread, so a scope boundary costs a single
  syscall returning every counter at once.
- **Graceful degradation, counted.**  Capability is probed once and
  every reading carries its *witness tier*:

  ========== =====================================================
  tier       source
  ========== =====================================================
  `perf-hw`  perf group led by a hardware event (instructions,
             cycles, LLC loads/misses + software events)
  `perf-sw`  perf syscall works but the PMU is hidden (typical VM):
             task-clock, context-switches, page-faults only
  `rusage`   `getrusage(RUSAGE_THREAD)` + `/proc/thread-self/
             schedstat` (paranoid level / seccomp forbids perf)
  `none`     nothing available — scopes are *counted* as
             unavailable, never silently dropped
  ========== =====================================================

- **Disabled means zero.**  Profiling off (the default) costs one
  attribute check per instrumented site (`PROF.enabled`); no fd is ever
  opened and `scope_count()` stays exactly 0 — the same counted
  contract as `trace.emitted_count()`.

When span tracing is *also* enabled, every accounted scope additionally
emits its counter deltas as ordinary 32-byte records on the per-thread
trace rings (kinds ≥ `trace.CTR_FIRST`, delta stored as `t1 - t0`, the
phase kind in `arg`, the request id in `rid`) — so counters join the
cross-process trace export with no new machinery.

Usage::

    from repro.obs import hwcounters as hw

    hw.enable()                       # children spawned after this inherit
    run_workload()
    print(hw.snapshot()["phases"])    # per-phase counter totals
    hw.disable()

Benchmarks that measure a closed region directly (not the serving hot
path) use a standalone :class:`Meter`, which works at the probed tier
regardless of `PROF.enabled`::

    m = hw.Meter()
    with m:
        busy_section()
    m.totals["task_clock_ns"], m.tier

CLI (the CI capability probe)::

    python -m repro.obs.hwcounters --probe --smoke
"""
from __future__ import annotations

import ctypes
import ctypes.util
import json
import os
import platform
import struct
import threading
import time
from typing import Optional

from repro.obs import trace as _trace

# -- perf_event_open ABI ------------------------------------------------------

# syscall numbers by architecture (perf_event_open)
_SYSCALL_NR = {
    "x86_64": 298, "i686": 336, "i386": 336,
    "aarch64": 241, "arm64": 241, "riscv64": 241,
    "ppc64le": 319, "ppc64": 319, "s390x": 331,
}

PERF_TYPE_HARDWARE = 0
PERF_TYPE_SOFTWARE = 1
PERF_TYPE_HW_CACHE = 3

# PERF_TYPE_HARDWARE configs
_HW_CPU_CYCLES = 0
_HW_INSTRUCTIONS = 1
# PERF_TYPE_SOFTWARE configs
_SW_TASK_CLOCK = 1
_SW_PAGE_FAULTS = 2
_SW_CTX_SWITCHES = 3
# PERF_TYPE_HW_CACHE config = id | (op << 8) | (result << 16); LL=2,
# READ=0, ACCESS=0, MISS=1
_LLC_LOADS = 2
_LLC_MISSES = 2 | (1 << 16)

PERF_FORMAT_TOTAL_TIME_ENABLED = 1 << 0
PERF_FORMAT_TOTAL_TIME_RUNNING = 1 << 1
PERF_FORMAT_GROUP = 1 << 3

_IOC_ENABLE = 0x2400
_IOC_RESET = 0x2403
_IOC_FLAG_GROUP = 1

# perf_event_attr, version 0 (64 bytes): type, size, config,
# sample_period, sample_type, read_format, flags bitfield, then two u32
# (wakeup_events, bp_type) we leave zero.  Flags: disabled(0) on the
# leader only, exclude_kernel(5), exclude_hv(6).
_ATTR_SIZE = 64
_ATTR_FMT = "<IIQQQQQII"
_FLAG_DISABLED = 1 << 0
_FLAG_EXCLUDE_KERNEL = 1 << 5
_FLAG_EXCLUDE_HV = 1 << 6

# Counter name → (perf type, config, needs-PMU).  Order is group order:
# the first *openable* event becomes the group leader.
EVENTS = (
    ("instructions", PERF_TYPE_HARDWARE, _HW_INSTRUCTIONS, True),
    ("cycles", PERF_TYPE_HARDWARE, _HW_CPU_CYCLES, True),
    ("llc_loads", PERF_TYPE_HW_CACHE, _LLC_LOADS, True),
    ("llc_misses", PERF_TYPE_HW_CACHE, _LLC_MISSES, True),
    ("task_clock_ns", PERF_TYPE_SOFTWARE, _SW_TASK_CLOCK, False),
    ("ctx_sw", PERF_TYPE_SOFTWARE, _SW_CTX_SWITCHES, False),
    ("page_faults", PERF_TYPE_SOFTWARE, _SW_PAGE_FAULTS, False),
)

#: every counter name any tier may report (rusage adds sched_wait_ns)
COUNTER_NAMES = tuple(e[0] for e in EVENTS) + ("sched_wait_ns",)

TIERS = ("perf-hw", "perf-sw", "rusage", "none")

#: env flag a parent sets so spawned children profile into the same run
ENV_FLAG = "ROCKET_HWPROF"
#: env override capping the tier (degrade-only; tests use it)
ENV_TIER = "ROCKET_HWPROF_TIER"

# serving-phase name → trace span kind (the `arg` of counter records)
PHASES = {
    "ring_poll": _trace.REACTOR_DRAIN,
    "batch_wait": _trace.DISPATCH_WAIT,
    "sg_gather": _trace.GATHER,
    "lease_hold": _trace.LEASE_HOLD,
    "handler": _trace.HANDLER,
    "reserve_fill": _trace.REPLY_FILL,
    "publish": _trace.CH_PUBLISH,
    "governor": _trace.GOV_DECIDE,
    "reply_drain": _trace.CLIENT_RECV,
}
_PHASE_BY_KIND = {v: k for k, v in PHASES.items()}

_libc = None


def _get_libc():
    """The process libc (cached) for raw `syscall(2)` / `ioctl(2)`."""
    global _libc
    if _libc is None:
        _libc = ctypes.CDLL(ctypes.util.find_library("c") or "libc.so.6",
                            use_errno=True)
    return _libc


def _perf_open(typ: int, config: int, group_fd: int, leader: bool,
               exclude_kernel: bool = True) -> int:
    """One `perf_event_open` for the calling thread (pid=0, cpu=-1).

    Returns the fd, or ``-errno`` on failure (never raises)."""
    nr = _SYSCALL_NR.get(platform.machine())
    if nr is None:
        return -38                                   # ENOSYS
    flags = 0
    if exclude_kernel:
        flags |= _FLAG_EXCLUDE_KERNEL | _FLAG_EXCLUDE_HV
    read_format = (PERF_FORMAT_GROUP | PERF_FORMAT_TOTAL_TIME_ENABLED
                   | PERF_FORMAT_TOTAL_TIME_RUNNING)
    if leader:
        flags |= _FLAG_DISABLED
    attr = struct.pack(_ATTR_FMT, typ, _ATTR_SIZE, config,
                       0, 0, read_format, flags, 0, 0)
    buf = ctypes.create_string_buffer(attr, _ATTR_SIZE)
    libc = _get_libc()
    ctypes.set_errno(0)
    fd = libc.syscall(nr, ctypes.byref(buf), 0, -1, group_fd, 0)
    if fd < 0:
        return -(ctypes.get_errno() or 1)
    return fd


def _open_event(name: str, typ: int, config: int, group_fd: int,
                leader: bool) -> int:
    """Open one event with the permission-degradation policy.

    Prefer counting user+kernel (syscall cost belongs to the phase that
    paid it); when the paranoid level forbids that, retry user-only —
    except for ``ctx_sw``, which counts *nothing* in user-only mode
    (switches happen in the kernel), so a kernel-excluded open would be
    a zero that looks like a reading.  Such hosts get ctx_sw
    supplemented from `getrusage` instead."""
    fd = _perf_open(typ, config, group_fd, leader, exclude_kernel=False)
    if fd >= 0:
        return fd
    if name == "ctx_sw":
        return -13                                   # EACCES: use rusage
    return _perf_open(typ, config, group_fd, leader, exclude_kernel=True)


# -- capability probe ---------------------------------------------------------

class Capability:
    """What the host lets us count: resolved tier + probe evidence."""

    def __init__(self, tier: str, paranoid: Optional[int],
                 events: tuple, errors: dict, forced: Optional[str] = None):
        self.tier = tier
        self.paranoid = paranoid          # /proc/sys/kernel/perf_event_paranoid
        self.events = events              # counter names the tier provides
        self.errors = errors              # event name → errno of failed open
        self.forced = forced              # ENV_TIER cap, if it applied

    def to_dict(self) -> dict:
        """JSON-serializable form (recorded into bench artifacts)."""
        return {"tier": self.tier, "paranoid": self.paranoid,
                "events": list(self.events),
                "errors": {k: v for k, v in self.errors.items()},
                "forced": self.forced}

    def __repr__(self) -> str:
        return f"Capability(tier={self.tier!r}, events={self.events!r})"


def _read_paranoid() -> Optional[int]:
    """Current `perf_event_paranoid`, or None off-Linux."""
    try:
        with open("/proc/sys/kernel/perf_event_paranoid") as f:
            return int(f.read().strip())
    except OSError:
        return None


def _rusage_works() -> bool:
    """True when per-thread getrusage is available (Linux)."""
    try:
        import resource
        resource.getrusage(resource.RUSAGE_THREAD)
        return True
    except Exception:
        return False


_CAP: Optional[Capability] = None
_CAP_LOCK = threading.Lock()


def probe(refresh: bool = False) -> Capability:
    """Probe (once, cached) what this host can count.

    Opens a throwaway perf group on the calling thread and closes it;
    applies the ``ROCKET_HWPROF_TIER`` degrade-only cap."""
    global _CAP
    with _CAP_LOCK:
        if _CAP is not None and not refresh:
            return _CAP
        errors: dict = {}
        opened: list = []
        fds: list = []
        group_fd = -1
        for name, typ, config, _hw in EVENTS:
            fd = _open_event(name, typ, config, group_fd,
                             leader=group_fd == -1)
            if fd < 0:
                errors[name] = os.strerror(-fd)
                continue
            fds.append(fd)
            opened.append(name)
            if group_fd == -1:
                group_fd = fd
        for fd in fds:
            os.close(fd)
        if opened and "ctx_sw" not in opened and _rusage_works():
            opened.append("ctx_sw")      # supplemented from getrusage
            errors["ctx_sw"] = errors.get("ctx_sw", "") + " (using rusage)"
        hw_names = {e[0] for e in EVENTS if e[3]}
        if any(n in hw_names for n in opened):
            tier, events = "perf-hw", tuple(opened)
        elif opened:
            tier, events = "perf-sw", tuple(opened)
        elif _rusage_works():
            tier = "rusage"
            events = ("task_clock_ns", "ctx_sw", "page_faults",
                      "sched_wait_ns")
        else:
            tier, events = "none", ()
        forced = os.environ.get(ENV_TIER)
        if forced in TIERS and TIERS.index(forced) > TIERS.index(tier):
            tier = forced                            # degrade only
            if tier == "rusage":
                events = (("task_clock_ns", "ctx_sw", "page_faults",
                           "sched_wait_ns") if _rusage_works() else ())
                if not events:
                    tier = "none"
            elif tier == "none":
                events = ()
            elif tier == "perf-sw":
                events = tuple(n for n in opened if n not in hw_names)
        else:
            forced = None
        _CAP = Capability(tier, _read_paranoid(), events, errors, forced)
        return _CAP


# -- per-thread readers -------------------------------------------------------

class _PerfReader:
    """One thread's enabled perf group; `read()` is a single syscall
    (plus one `getrusage` when ctx_sw needs supplementing — see
    :func:`_open_event`)."""

    __slots__ = ("fds", "names", "_size", "_res")

    def __init__(self, names):
        self.fds: list = []
        self.names: tuple = ()
        got = []
        group_fd = -1
        for name, typ, config, _hw in EVENTS:
            if name not in names:
                continue
            fd = _open_event(name, typ, config, group_fd,
                             leader=group_fd == -1)
            if fd < 0:
                continue
            self.fds.append(fd)
            got.append(name)
            if group_fd == -1:
                group_fd = fd
        self._res = None
        if got and "ctx_sw" not in got and _rusage_works():
            import resource
            self._res = resource
            got.append("ctx_sw")
        self.names = tuple(got)
        if group_fd >= 0:
            libc = _get_libc()
            libc.ioctl(group_fd, _IOC_RESET, _IOC_FLAG_GROUP)
            libc.ioctl(group_fd, _IOC_ENABLE, _IOC_FLAG_GROUP)
        # group read layout: nr, time_enabled, time_running, value×nr
        self._size = 8 * (3 + len(self.fds))

    def read(self) -> Optional[tuple]:
        """Raw cumulative counter values, group-ordered (one syscall)."""
        if not self.fds:
            return None
        try:
            buf = os.read(self.fds[0], self._size)
        except OSError:
            return None
        vals = struct.unpack_from(f"<{len(buf) // 8}Q", buf)
        # vals = (nr, enabled, running, v0, v1, ...); with one group and
        # ≤7 events there is no multiplexing, so values are exact
        out = vals[3:3 + len(self.fds)]
        if self._res is not None:
            ru = self._res.getrusage(self._res.RUSAGE_THREAD)
            out = out + (ru.ru_nvcsw + ru.ru_nivcsw,)
        return out

    def close(self) -> None:
        """Close the group's fds (idempotent)."""
        fds, self.fds = self.fds, []
        for fd in fds:
            try:
                os.close(fd)
            except OSError:
                pass


class _RusageReader:
    """Fallback tier: `getrusage(RUSAGE_THREAD)` + thread schedstat."""

    __slots__ = ("names", "_res", "_sched_fd")

    def __init__(self):
        import resource
        self._res = resource
        self.names = ("task_clock_ns", "ctx_sw", "page_faults",
                      "sched_wait_ns")
        try:
            self._sched_fd = os.open("/proc/thread-self/schedstat",
                                     os.O_RDONLY)
        except OSError:
            self._sched_fd = -1

    def read(self) -> Optional[tuple]:
        """Cumulative (cpu_ns, ctx switches, faults, runqueue-wait ns)."""
        r = self._res
        try:
            ru = r.getrusage(r.RUSAGE_THREAD)
        except Exception:
            return None
        wait_ns = 0
        if self._sched_fd >= 0:
            try:
                parts = os.pread(self._sched_fd, 128, 0).split()
                wait_ns = int(parts[1])
            except (OSError, IndexError, ValueError):
                pass
        return (int((ru.ru_utime + ru.ru_stime) * 1e9),
                ru.ru_nvcsw + ru.ru_nivcsw,
                ru.ru_minflt + ru.ru_majflt,
                wait_ns)

    def close(self) -> None:
        """Release the schedstat fd (idempotent)."""
        fd, self._sched_fd = self._sched_fd, -1
        if fd >= 0:
            try:
                os.close(fd)
            except OSError:
                pass


class _NoneReader:
    """Tier `none`: reads return nothing, scopes still get counted."""

    __slots__ = ()
    names: tuple = ()

    def read(self) -> Optional[tuple]:
        """Always None — the accounting layer counts it as unavailable."""
        return None

    def close(self) -> None:
        """Nothing to release."""


def _make_reader(cap: Capability):
    """Build the per-thread reader matching the resolved tier."""
    if cap.tier in ("perf-hw", "perf-sw"):
        r = _PerfReader(cap.events)
        if r.names:
            return r
        r.close()                          # raced with a capability change
    if cap.tier in ("perf-hw", "perf-sw", "rusage") and _rusage_works():
        return _RusageReader()
    return _NoneReader()


# -- profiler state & accounting ---------------------------------------------

class _ProfState(threading.local):
    """Module profiling switch + per-thread reader slot.

    ``enabled`` is intentionally *not* thread-local — it lives on the
    class so one `enable()` turns every thread's instrumented sites on
    (the thread-local part is only the lazily-built reader)."""

    enabled = False                        # class attr: process-global
    tier = "none"

    def __init__(self):
        self.reader = None


PROF = _ProfState()

_ACC_LOCK = threading.Lock()
_phases: dict = {}                         # phase → {counter/meta → int}
_scopes = 0                                # accounted scopes (the 0-gate)
_unavailable = 0                           # scopes with no reading (tier none)
_readers: list = []                        # every reader built, for disable()


def _thread_reader():
    """This thread's counter reader, built lazily on first scope."""
    r = PROF.reader
    if r is None:
        r = _make_reader(probe())
        PROF.reader = r
        with _ACC_LOCK:
            _readers.append(r)
    return r


def begin() -> Optional[tuple]:
    """Open a counter scope on the calling thread.

    Hot-path protocol (mirrors the tracer's ``t0 = now() if enabled``):
    call only behind a ``PROF.enabled`` check; pass the token to
    :func:`end`.  Returns None when profiling is disabled."""
    if not _ProfState.enabled:
        return None
    r = _thread_reader()
    return (r, r.read(), time.perf_counter_ns())


def end(token: tuple, phase: str, nbytes: int = 0, rid: int = 0) -> None:
    """Close a scope: account counter deltas to ``phase``.

    With tracing also enabled, each nonzero delta is emitted as a
    counter record on this thread's trace ring (kind per counter,
    ``arg`` = the phase's span kind, duration = the delta)."""
    r, c0, t0 = token
    c1 = r.read()
    t1 = time.perf_counter_ns()
    global _scopes, _unavailable
    with _ACC_LOCK:
        _scopes += 1
        acc = _phases.get(phase)
        if acc is None:
            acc = _phases[phase] = {"count": 0, "bytes": 0, "wall_ns": 0}
        acc["count"] += 1
        acc["bytes"] += nbytes
        acc["wall_ns"] += t1 - t0
        if c0 is None or c1 is None:
            _unavailable += 1
            deltas = ()
        else:
            deltas = tuple(max(b - a, 0) for a, b in zip(c0, c1))
            for name, d in zip(r.names, deltas):
                acc[name] = acc.get(name, 0) + d
    if deltas and _trace.TRACE.enabled:
        kind_arg = PHASES.get(phase, 0)
        for name, d in zip(r.names, deltas):
            if d:
                _trace.emit(_trace.CTR_KINDS[name], t0, rid=rid,
                            arg=kind_arg, t1=t0 + d)


def account_wall(phase: str, t0_ns: int, nbytes: int = 0) -> None:
    """Account a wall-clock-only phase (no counter read).

    Used for `lease_hold`, whose delivery and release happen on
    *different* threads — per-thread counter deltas would be
    meaningless, but the hold time still belongs in the phase table."""
    if not _ProfState.enabled:
        return
    global _scopes
    t1 = time.perf_counter_ns()
    with _ACC_LOCK:
        _scopes += 1
        acc = _phases.get(phase)
        if acc is None:
            acc = _phases[phase] = {"count": 0, "bytes": 0, "wall_ns": 0}
        acc["count"] += 1
        acc["bytes"] += nbytes
        acc["wall_ns"] += t1 - t0_ns


class CounterScope:
    """Context-manager face of :func:`begin`/:func:`end` for cold paths.

    ::

        with hwcounters.CounterScope("handler", nbytes=n, rid=rid):
            run_batch()

    A no-op (no fd, no syscall, no accounting) while profiling is
    disabled — the counted-zero contract."""

    __slots__ = ("phase", "nbytes", "rid", "_token")

    def __init__(self, phase: str, nbytes: int = 0, rid: int = 0):
        self.phase = phase
        self.nbytes = nbytes
        self.rid = rid
        self._token = None

    def __enter__(self) -> "CounterScope":
        if _ProfState.enabled:
            self._token = begin()
        return self

    def __exit__(self, *exc) -> None:
        token, self._token = self._token, None
        if token is not None:
            end(token, self.phase, nbytes=self.nbytes, rid=self.rid)


class Meter:
    """Standalone accumulating counter meter for benchmark sections.

    Independent of `PROF.enabled` — constructing one is the explicit
    opt-in.  Reusable: enter/exit repeatedly and deltas accumulate, so
    a benchmark can meter just its busy sections across many steps.

    Attributes: ``tier`` (witness tier of the readings), ``totals``
    (counter name → accumulated delta, plus ``wall_ns``), ``entries``.
    """

    def __init__(self):
        cap = probe()
        self._reader = _make_reader(cap)
        self.tier = (cap.tier if not isinstance(self._reader, _NoneReader)
                     else "none")
        if isinstance(self._reader, _RusageReader):
            self.tier = "rusage" if cap.tier != "none" else "none"
        self.totals: dict = {"wall_ns": 0}
        self.entries = 0
        self._c0 = None
        self._t0 = 0

    def __enter__(self) -> "Meter":
        self._c0 = self._reader.read()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        c1 = self._reader.read()
        self.totals["wall_ns"] += time.perf_counter_ns() - self._t0
        self.entries += 1
        if self._c0 is not None and c1 is not None:
            for name, a, b in zip(self._reader.names, self._c0, c1):
                self.totals[name] = self.totals.get(name, 0) + max(b - a, 0)
        self._c0 = None

    def close(self) -> None:
        """Release the meter's fds."""
        self._reader.close()


# -- lifecycle ----------------------------------------------------------------

def enable(tier: Optional[str] = None) -> str:
    """Turn phase profiling on; returns the resolved witness tier.

    Exports ``ROCKET_HWPROF=1`` (and the tier cap, if given) so
    processes spawned afterwards profile too.  ``tier`` can only
    degrade below the probed capability — you cannot force `perf-hw`
    on a host without a PMU."""
    if tier is not None:
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected {TIERS})")
        os.environ[ENV_TIER] = tier
        probe(refresh=True)
    cap = probe()
    _ProfState.enabled = True
    _ProfState.tier = cap.tier
    os.environ[ENV_FLAG] = "1"
    return cap.tier


def disable() -> None:
    """Turn profiling off and release every thread's counter fds.

    Accumulated phase totals survive (read them with :func:`snapshot`);
    :func:`reset` clears them."""
    _ProfState.enabled = False
    os.environ.pop(ENV_FLAG, None)
    os.environ.pop(ENV_TIER, None)
    with _ACC_LOCK:
        readers, _readers[:] = _readers[:], []
    for r in readers:
        r.close()
    PROF.reader = None


def reset() -> None:
    """Zero the phase accumulators and the scope/unavailable counts."""
    global _scopes, _unavailable
    with _ACC_LOCK:
        _phases.clear()
        _scopes = 0
        _unavailable = 0


def maybe_enable_from_env() -> bool:
    """Child-process half of env inheritance: enable iff the parent did.

    Called at fabric/worker startup (mirrors the tracer's env
    handshake); returns whether profiling is now on."""
    if os.environ.get(ENV_FLAG) == "1" and not _ProfState.enabled:
        enable()
    return _ProfState.enabled


def scope_count() -> int:
    """Scopes accounted since the last :func:`reset` (0 when disabled —
    the counted contract `--check` gates on)."""
    with _ACC_LOCK:
        return _scopes


def snapshot() -> dict:
    """Current profile: tier, scope counts, per-phase counter totals.

    Nested-dict shape flattens under `MetricsRegistry` to keys like
    ``hw.phases.sg_gather.instructions``.  Phases with recorded bytes
    also report ``insn_per_byte`` / ``llc_miss_per_byte`` when the tier
    provides those counters."""
    cap = probe()
    with _ACC_LOCK:
        phases = {p: dict(acc) for p, acc in _phases.items()}
        scopes, unavailable = _scopes, _unavailable
    for acc in phases.values():
        b = acc.get("bytes", 0)
        if b > 0:
            if acc.get("instructions"):
                acc["insn_per_byte"] = round(acc["instructions"] / b, 4)
            if acc.get("llc_misses"):
                acc["llc_miss_per_byte"] = round(acc["llc_misses"] / b, 6)
    return {"tier": _ProfState.tier if _ProfState.enabled else cap.tier,
            "enabled": int(_ProfState.enabled),
            "scopes": scopes, "unavailable": unavailable,
            "phases": phases}


def phase_totals() -> dict:
    """Flat copy of the raw per-phase accumulators:
    ``{phase: {counter: int}}`` (no derived ratios) — cheap to diff."""
    with _ACC_LOCK:
        return {p: dict(acc) for p, acc in _phases.items()}


def counters_from_view(view) -> dict:
    """Reduce counter records in a collected trace to per-phase sums.

    Returns ``{phase_name: {counter_name: total}}`` — the cross-process
    join: counter records written by any traced process land on its
    rings and fold together here, keyed by the phase kind in ``arg``."""
    out: dict = {}
    for name, kind in _trace.CTR_KINDS.items():
        recs = view.records_of(kind)
        for rec in recs:
            phase = _PHASE_BY_KIND.get(int(rec["arg"]), f"kind{rec['arg']}")
            acc = out.setdefault(phase, {})
            acc[name] = acc.get(name, 0) + int(rec["t1"]) - int(rec["t0"])
    return out


# -- CLI: the CI capability probe + smoke -------------------------------------

def _smoke() -> dict:
    """Meter a known busy loop; returns the readings for the gate.

    The gate: if the probe claims a perf tier but the smoke reads all
    zeros, something is broken (not merely unavailable) — fail."""
    m = Meter()
    deadline = time.perf_counter() + 0.05
    x = 0
    while time.perf_counter() < deadline:
        with m:
            for i in range(20000):
                x += i * i
    m.close()
    return {"tier": m.tier, "entries": m.entries, "totals": m.totals,
            "spin_result": x % 7}


def main(argv=None) -> int:
    """`python -m repro.obs.hwcounters [--probe] [--smoke] [--json]`."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--probe", action="store_true",
                    help="print host capability (tier, paranoid, events)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the busy-loop smoke; fail if a perf tier "
                         "reads all zeros")
    ap.add_argument("--json", action="store_true", help="JSON output")
    args = ap.parse_args(argv)
    if not (args.probe or args.smoke):
        args.probe = args.smoke = True
    out: dict = {}
    if args.probe:
        out["capability"] = probe(refresh=True).to_dict()
    rc = 0
    if args.smoke:
        s = _smoke()
        out["smoke"] = s
        if s["tier"].startswith("perf"):
            if not any(v for k, v in s["totals"].items() if k != "wall_ns"):
                out["error"] = ("probe claims perf tier "
                                f"{s['tier']!r} but smoke read zeros")
                rc = 1
    if args.json:
        print(json.dumps(out, indent=2, sort_keys=True))
    else:
        cap = out.get("capability", {})
        if cap:
            print(f"tier={cap['tier']} paranoid={cap['paranoid']} "
                  f"events={','.join(cap['events']) or '-'}")
            for name, err in sorted(cap.get("errors", {}).items()):
                print(f"  unavailable: {name}: {err}")
        if "smoke" in out:
            t = out["smoke"]["totals"]
            keys = ", ".join(f"{k}={v}" for k, v in sorted(t.items()))
            print(f"smoke[{out['smoke']['tier']}] "
                  f"entries={out['smoke']['entries']}: {keys}")
        if "error" in out:
            print(f"FAIL: {out['error']}")
    return rc


if __name__ == "__main__":                           # pragma: no cover
    raise SystemExit(main())
