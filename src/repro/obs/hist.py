"""Log-bucket latency histograms: fixed int arrays, mergeable anywhere.

A :class:`Histogram` is 64 int64 bucket counts (bucket *b* holds values
``v`` with ``v.bit_length() == b``, i.e. ``2^(b-1) <= v < 2^b``; bucket 0
holds zeros) plus exact count/sum side totals.  That representation is:

- **fixed-size** — no allocation while recording, safe in hot paths;
- **mergeable** — merging is element-wise addition, so per-process (or
  per-ring) histograms combine into one cross-process distribution
  without resampling;
- **good enough for decomposition** — log buckets answer "which phase
  eats the microseconds" questions (p50/p95 within a factor of 2), which
  is the resolution the fig12 phase report needs.

Histograms are usually built straight from collected trace records
(:func:`phase_histograms`); :func:`phase_report` renders the per-phase
decomposition table used by ``benchmarks/fig12_decomposition.py``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

N_BUCKETS = 64


def _bucket_of(values: np.ndarray) -> np.ndarray:
    """Vectorized ``int.bit_length`` (log2 bucket index, 0 for zeros)."""
    v = np.asarray(values, np.float64)
    out = np.zeros(v.shape, np.int64)
    pos = v > 0
    out[pos] = np.floor(np.log2(v[pos])).astype(np.int64) + 1
    return np.clip(out, 0, N_BUCKETS - 1)


class Histogram:
    """Fixed 64-bucket log2 histogram with exact count/sum side totals."""

    __slots__ = ("counts", "n", "total")

    def __init__(self, counts: Optional[np.ndarray] = None,
                 n: int = 0, total: int = 0):
        self.counts = (np.zeros(N_BUCKETS, np.int64) if counts is None
                       else np.asarray(counts, np.int64).copy())
        self.n = int(n)
        self.total = int(total)

    # -- recording ----------------------------------------------------------
    def add(self, value: int) -> None:
        """Record one non-negative value (e.g. a span duration in ns)."""
        v = max(int(value), 0)
        self.counts[min(v.bit_length(), N_BUCKETS - 1)] += 1
        self.n += 1
        self.total += v

    def add_many(self, values: np.ndarray) -> None:
        """Record an array of values in one vectorized pass."""
        v = np.maximum(np.asarray(values, np.int64), 0)
        if v.size == 0:
            return
        self.counts += np.bincount(_bucket_of(v), minlength=N_BUCKETS)
        self.n += int(v.size)
        self.total += int(v.sum())

    # -- merging ------------------------------------------------------------
    def merge(self, other: "Histogram") -> "Histogram":
        """Fold another histogram into this one (element-wise add)."""
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        return self

    def __iadd__(self, other: "Histogram") -> "Histogram":
        return self.merge(other)

    # -- queries ------------------------------------------------------------
    @property
    def mean(self) -> float:
        """Exact mean of recorded values (side totals, not buckets)."""
        return self.total / self.n if self.n else 0.0

    def percentile(self, p: float) -> int:
        """Upper bound of the bucket containing the p-th percentile
        (log-bucket resolution: within 2x of the true value)."""
        if self.n == 0:
            return 0
        rank = max(1, int(np.ceil(self.n * p / 100.0)))
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank))
        return (1 << b) - 1 if b else 0

    def to_dict(self) -> dict:
        """Serializable form (registry snapshots, JSON records)."""
        return {"counts": self.counts.tolist(), "n": self.n,
                "total": self.total}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        """Rebuild from :meth:`to_dict` output."""
        return cls(np.asarray(d["counts"], np.int64), d["n"], d["total"])

    @classmethod
    def from_durations(cls, durations_ns: np.ndarray) -> "Histogram":
        """Histogram of an array of span durations."""
        h = cls()
        h.add_many(durations_ns)
        return h

    def __repr__(self) -> str:
        return (f"Histogram(n={self.n}, mean={self.mean / 1e3:.1f}us, "
                f"p95<={self.percentile(95) / 1e3:.1f}us)")


def phase_histograms(view) -> dict:
    """Per-phase duration histograms from a collected
    :class:`~repro.obs.trace.TraceView` — kind name → :class:`Histogram`."""
    from repro.obs.trace import CTR_FIRST, KIND_NAMES
    out = {}
    for kind, name in KIND_NAMES.items():
        if kind >= CTR_FIRST:
            continue            # counter deltas, not wall durations
        d = view.durations_ns(kind)
        if len(d):
            out[name] = Histogram.from_durations(d)
    return out


def phase_report(view, per: int = 1) -> str:
    """Text decomposition table: per-phase count, total, mean, p95.

    ``per`` divides totals into a per-item rate (e.g. pass the request
    count to read µs *per request* directly).
    """
    hists = phase_histograms(view)
    lines = [f"{'phase':<26}{'count':>8}{'total_ms':>12}"
             f"{'us/item':>12}{'mean_us':>10}{'p95_us':>10}"]
    for name in sorted(hists, key=lambda k: -hists[k].total):
        h = hists[name]
        lines.append(
            f"{name:<26}{h.n:>8}{h.total / 1e6:>12.2f}"
            f"{h.total / 1e3 / max(per, 1):>12.1f}"
            f"{h.mean / 1e3:>10.1f}{h.percentile(95) / 1e3:>10.1f}")
    if view.total_drops:
        lines.append(f"(dropped {view.total_drops} records to ring "
                     f"wraparound — totals are floors)")
    return "\n".join(lines)
