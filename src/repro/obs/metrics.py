"""Unified metrics plane: one snapshot/delta API over the ad-hoc stats.

The stack grew nine independent ``*Stats`` objects (Channel, CopyEngine,
Engine, Reactor, Heap, Ring, Governor, Dispatcher, Pool) with three
different shapes: plain dataclasses, objects with ``snapshot()``, and raw
dicts.  :class:`MetricsRegistry` flattens all of them into labeled dot
keys (``"reactor.sweeps"``, ``"governor.decisions"``) behind one
``snapshot()``/``delta()`` pair, so callers read the *whole* runtime in
one call and can diff two snapshots to get per-interval rates — the
"stats completeness" fix for ``ShmTransport.stats()`` and
``ServingFabric.stats()``.

:class:`SLOTracker` wires the previously-orphaned serving SLO pieces —
``ft/monitor.py``'s :class:`~repro.ft.monitor.StepTimer` /
:class:`~repro.ft.monitor.StragglerMonitor` and ``core/latency.py``'s
:class:`~repro.core.latency.LatencyModel` — into the request path: the
fabric observes every request's service time, the straggler monitor
flags tail blowups against the rolling median, and the latency model
turns into a live predicted-vs-observed ratio instead of dead code.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional, Union

from repro.core.latency import LatencyModel
from repro.ft.monitor import StepTimer, StragglerMonitor

_MB = float(1 << 20)


def _materialize(source) -> dict:
    """One source → a plain dict: call it, ``snapshot()`` it, copy it, or
    fall back to ``vars()`` (plain dataclass stats)."""
    if callable(source):
        source = source()
    snap = getattr(source, "snapshot", None)
    if callable(snap):
        source = snap()
    if isinstance(source, dict):
        return dict(source)
    return dict(vars(source))


def _flatten(prefix: str, value, out: dict) -> None:
    """Recursively flatten nested dicts into ``a.b.c`` keys."""
    if isinstance(value, dict):
        for k, v in value.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    else:
        out[prefix] = value


class MetricsRegistry:
    """Named metric sources unified behind flat snapshot/delta calls.

    A *source* may be a stats object (dataclass or ``snapshot()``-bearing),
    a dict, or a zero-arg callable returning any of those — so dynamic
    collections (per-connection transports, a lazily-created governor)
    register once as a closure and stay current.
    """

    def __init__(self):
        self._sources: dict[str, object] = {}

    def register(self, name: str,
                 source: Union[object, dict, Callable]) -> None:
        """Add (or replace) a named source."""
        self._sources[name] = source

    def unregister(self, name: str) -> None:
        """Drop a source (idempotent)."""
        self._sources.pop(name, None)

    def names(self) -> list:
        """Registered source names (sorted)."""
        return sorted(self._sources)

    def snapshot(self) -> dict:
        """Flat ``source.field`` → value dict across every source.

        A source that raises is reported as ``"<name>.error"`` instead of
        poisoning the rest of the snapshot (stats must never take the
        data path down)."""
        out: dict = {}
        for name in sorted(self._sources):
            try:
                _flatten(name, _materialize(self._sources[name]), out)
            except Exception as e:               # pragma: no cover - defensive
                out[f"{name}.error"] = repr(e)
        return out

    @staticmethod
    def delta(prev: dict, cur: dict) -> dict:
        """Numeric difference ``cur - prev`` per key (non-numeric values
        and keys missing from ``prev`` pass through as-is) — turns two
        lifetime-counter snapshots into a per-interval reading."""
        out = {}
        for k, v in cur.items():
            p = prev.get(k)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                out[k] = v
            elif isinstance(p, (int, float)) and not isinstance(p, bool):
                out[k] = v - p
            else:
                out[k] = v
        return out


class SLOTracker:
    """Per-request serving-latency SLO monitor for the fabric.

    Feeds every completed request's service time (reactor delivery →
    reply sent) into a rolling :class:`StepTimer` (p50/p95) and a
    :class:`StragglerMonitor` (tail blowups vs. the rolling median), and
    — when a :class:`LatencyModel` is present — tracks the EWMA ratio of
    observed to predicted service time, making the model a live
    calibration check instead of dead code.
    """

    def __init__(self, latency: Optional[LatencyModel] = None,
                 window: int = 256, straggler_threshold: float = 4.0,
                 patience: int = 3):
        self.model = latency
        # StepTimer's dataclass default deque is pinned at maxlen=64;
        # widen it to the requested window
        self.timer = StepTimer(window=window, times=deque(maxlen=window))
        self.straggler = StragglerMonitor(threshold=straggler_threshold,
                                          patience=patience)
        self.requests = 0
        self.bytes_in = 0
        self.deadline_misses = 0
        self._window = window
        self._lanes: dict = {}       # priority -> per-lane timer + counters
        self._ratio_ewma = 0.0

    def _lane(self, priority: int) -> dict:
        lane = self._lanes.get(priority)
        if lane is None:
            lane = self._lanes[priority] = {
                "timer": StepTimer(window=self._window,
                                   times=deque(maxlen=self._window)),
                "requests": 0, "misses": 0}
        return lane

    def observe(self, seconds: float, nbytes: int = 0,
                lane: int = 0, miss: bool = False) -> None:
        """Record one request's observed service time (and payload size,
        which the latency model predicts from).  ``lane`` is the request's
        priority class; ``miss`` marks a reply that landed past its
        deadline (counted globally and per lane)."""
        self.requests += 1
        self.bytes_in += int(nbytes)
        self.timer.record(seconds)
        self.straggler.record_step(seconds)
        entry = self._lane(lane)
        entry["timer"].record(seconds)
        entry["requests"] += 1
        if miss:
            self.deadline_misses += 1
            entry["misses"] += 1
        if self.model is not None and nbytes > 0:
            predicted_s = self.model.predict_us(nbytes) * 1e-6
            if predicted_s > 0:
                ratio = seconds / predicted_s
                self._ratio_ewma = (ratio if self._ratio_ewma == 0.0 else
                                    0.9 * self._ratio_ewma + 0.1 * ratio)

    def snapshot(self) -> dict:
        """Flat SLO counters: volume, p50/p95 ms, deadline misses,
        straggler events, the observed/predicted latency-model ratio
        (0 = no model/data), and a per-priority-lane breakdown
        (flattened by the registry to ``slo.lane0.p99_ms``-style keys)."""
        out = {
            "requests": self.requests,
            "mb_in": self.bytes_in / _MB,
            "p50_ms": self.timer.median() * 1e3,
            "p95_ms": self.timer.p95() * 1e3,
            "deadline_misses": self.deadline_misses,
            "stragglers": len(self.straggler.events),
            "consecutive_slow": self.straggler.consecutive_slow,
            "model_ratio": self._ratio_ewma,
            "model_l_fixed_us": (self.model.l_fixed_us
                                 if self.model else 0.0),
            "model_alpha_us_per_mb": (self.model.alpha_us_per_mb
                                      if self.model else 0.0),
        }
        for prio in sorted(self._lanes):
            entry = self._lanes[prio]
            timer = entry["timer"]
            out[f"lane{prio}"] = {
                "requests": entry["requests"],
                "misses": entry["misses"],
                "p50_ms": timer.median() * 1e3,
                "p95_ms": timer.p95() * 1e3,
                "p99_ms": timer.p99() * 1e3,
            }
        return out
