"""Cross-process span tracing on shared-memory event rings.

Design (mirrors the data path's own disciplines so tracing cannot distort
what it measures):

- **One ring per writing thread**, lazily created on first emit, backed by
  a :class:`~repro.ipc.shm.SharedMemoryArena` — the same single-writer
  atomic-store discipline as the IPC rings, so emitting a span is a
  ``struct.pack_into`` + one aligned int64 cursor store: no locks, no
  allocation, no pickling.
- **Fixed 32-byte binary records**: ``u32 kind | u32 arg | u64 t0 |
  u64 t1 | u64 rid`` with ``t0``/``t1`` from ``time.perf_counter_ns()``
  (CLOCK_MONOTONIC on Linux — one timebase for every process on the
  host, so records join across processes without clock translation).
- **Wraparound overwrites the oldest record** and the monotonic cursor
  makes the loss *counted*: ``drops = max(0, cursor - capacity)``.
- **Discovery without IPC**: rings are named
  ``rt-<session>-<pid>-<seq>``; spawned children inherit the session id
  through the environment (`ROCKET_TRACE`/`ROCKET_TRACE_SESSION`), and
  the collector lists ``/dev/shm`` by prefix and maps every ring
  read-only.  Rings are unregistered from the stdlib resource tracker at
  creation so a child's rings *survive its exit* for post-mortem
  collection; the collector (or :func:`disable`) owns the unlink.
- **Disabled means zero**: with tracing off (the default) instrumented
  code performs one attribute check and writes nothing — no ring is ever
  created, and :func:`emitted_count` returning 0 is CI-gated.

The request id (:func:`mint_rid`) is ``pid << 32 | seq`` — unique across
processes without coordination — and rides the existing binary wire meta
under the reserved header key :data:`RID_KEY`, so one request's client
send, reactor drain, gather, handler, and reply spans share a join key.
"""
from __future__ import annotations

import atexit
import itertools
import json
import os
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# -- record layout ----------------------------------------------------------
RECORD_DTYPE = np.dtype([("kind", "<u4"), ("arg", "<u4"),
                         ("t0", "<u8"), ("t1", "<u8"), ("rid", "<u8")])
RECORD_BYTES = RECORD_DTYPE.itemsize            # 32
_RECORD_FMT = "<IIQQQ"                          # kind, arg, t0, t1, rid
assert struct.calcsize(_RECORD_FMT) == RECORD_BYTES

# ring control words (see SharedMemoryArena.control_words)
_W_CURSOR, _W_CAPACITY, _W_PID, _W_TID = 0, 1, 2, 3

ENV_FLAG = "ROCKET_TRACE"
ENV_SESSION = "ROCKET_TRACE_SESSION"
ENV_CAPACITY = "ROCKET_TRACE_CAPACITY"
_PREFIX = "rt"
_SHM_DIR = "/dev/shm"
DEFAULT_CAPACITY = 1 << 14                      # records/ring (512 KB)

# reserved wire-meta header key carrying the request id (the same
# pop-on-arrival idiom as channel.py's heap extent key); only ever added
# while tracing is enabled, so disabled wire bytes are unchanged
RID_KEY = "__rocket_rid__"

# -- span kinds -------------------------------------------------------------
CLIENT_SEND = 1        # RemoteDispatcherClient.request: send on the wire
CLIENT_RECV = 2        # reply decoded client-side (instant)
QUERY_WAIT = 3         # RemoteDispatcherClient.query: wait for completion
CH_SEND = 4            # DataChannel.send wall time (any route)
CH_PUBLISH = 5         # slot claim→publish→doorbell inside _publish
RING_WAIT = 6          # ring slow path: blocked on a slot state flip
REACTOR_DRAIN = 7      # one batched drain pull (recv_many + handoff)
DISPATCH_WAIT = 8      # dispatcher batch window: first request → batch closed
GATHER = 9             # SG gather of leased views into the batch slab
LEASE_HOLD = 10        # zero-copy lease lifetime: delivery → release
HANDLER = 11           # handler/model execution for one batch
REPLY_FILL = 12        # reply reserve-then-fill on the client's transport
GOV_DECIDE = 13        # governor route decision
GOV_OBSERVE = 14       # governor cost observation (instant)
COPY_JOB = 15          # one CopyEngine SG descriptor's memcpy loop
SERVE_BATCH = 16       # BatchedServer.generate_batch (prefill+decode)

KIND_NAMES = {
    CLIENT_SEND: "client.send",
    CLIENT_RECV: "client.recv",
    QUERY_WAIT: "client.query_wait",
    CH_SEND: "channel.send",
    CH_PUBLISH: "channel.publish",
    RING_WAIT: "ring.wait",
    REACTOR_DRAIN: "reactor.drain",
    DISPATCH_WAIT: "dispatcher.batch_wait",
    GATHER: "dispatcher.gather",
    LEASE_HOLD: "lease.hold",
    HANDLER: "dispatcher.handler",
    REPLY_FILL: "reactor.reply_fill",
    GOV_DECIDE: "governor.decide",
    GOV_OBSERVE: "governor.observe",
    COPY_JOB: "copyengine.copy",
    SERVE_BATCH: "serve.generate_batch",
}

# Counter records (the hardware-witness plane, obs/hwcounters.py) share
# the rings and record layout but carry a *counter delta*, not a wall
# interval: duration ``t1 - t0`` is the delta, ``arg`` is the span kind
# of the phase the delta belongs to.  Kinds ≥ CTR_FIRST are therefore
# excluded from wall-time phase totals/histograms.
CTR_FIRST = 32
CTR_KINDS = {
    "instructions": 32,
    "cycles": 33,
    "llc_loads": 34,
    "llc_misses": 35,
    "task_clock_ns": 36,
    "ctx_sw": 37,
    "page_faults": 38,
    "sched_wait_ns": 39,
}
KIND_NAMES.update({v: f"ctr.{k}" for k, v in CTR_KINDS.items()})


class _State:
    """Process-wide tracing switch; ``TRACE.enabled`` is THE hot-path guard."""
    __slots__ = ("enabled", "session", "capacity")

    def __init__(self):
        self.enabled = os.environ.get(ENV_FLAG) == "1"
        self.session = os.environ.get(ENV_SESSION, "")
        self.capacity = int(os.environ.get(ENV_CAPACITY, DEFAULT_CAPACITY))


TRACE = _State()

now = time.perf_counter_ns

_rid_seq = itertools.count(1)
_ring_seq = itertools.count()
_tls = threading.local()
_rings_lock = threading.Lock()
_rings: list["_TraceRing"] = []                 # rings created by THIS process


def mint_rid() -> int:
    """A u64 request id unique across processes: ``pid << 32 | seq``."""
    return ((os.getpid() & 0xFFFFFFFF) << 32) | (next(_rid_seq) & 0xFFFFFFFF)


def _untrack(shm) -> None:
    """Stop the resource tracker auto-unlinking this segment at process
    exit — a spawned child's rings must outlive it for collection; the
    collector (or :func:`disable`) owns the unlink instead."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass


def _unlink_quiet(name: str) -> None:
    """Destroy a ring segment by name without touching the resource
    tracker (every handle was unregistered at open, so the stdlib
    ``SharedMemory.unlink`` — which also unregisters — would unbalance
    the tracker's ledger and make it print KeyErrors at exit)."""
    try:
        import _posixshmem
        _posixshmem.shm_unlink(name if name.startswith("/") else "/" + name)
    except FileNotFoundError:
        pass
    except ImportError:                  # pragma: no cover - non-POSIX
        try:
            from multiprocessing import shared_memory
            seg = shared_memory.SharedMemory(name, create=False)
            seg.close()
            seg.unlink()
        except FileNotFoundError:
            pass


class _TraceRing:
    """One thread's single-writer span ring in shared memory."""

    def __init__(self, arena, capacity: int):
        self._arena = arena
        self._words = arena.control_words()
        self._buf = arena.view(0, capacity * RECORD_BYTES)
        self._capacity = capacity
        self._cursor = int(self._words[_W_CURSOR])
        self.session = TRACE.session
        self.closed = False

    @classmethod
    def create(cls, name: str, capacity: int) -> "_TraceRing":
        """Mint a ring segment (creator side; detached from the tracker)."""
        from repro.ipc.shm import SharedMemoryArena  # runtime import: obs
        # must not import repro.ipc at module load (ipc imports obs.trace)
        arena = SharedMemoryArena(name, size=capacity * RECORD_BYTES,
                                  create=True)
        _untrack(arena._shm)
        words = arena.control_words()
        words[_W_CAPACITY] = capacity
        words[_W_PID] = os.getpid()
        words[_W_TID] = threading.get_ident() & 0x7FFFFFFF
        return cls(arena, capacity)

    def write(self, kind: int, t0: int, t1: int, rid: int, arg: int) -> None:
        """Append one record: pack in place, then one cursor store."""
        struct.pack_into(_RECORD_FMT, self._buf,
                         (self._cursor % self._capacity) * RECORD_BYTES,
                         kind & 0xFFFFFFFF, arg & 0xFFFFFFFF,
                         t0, t1, rid & 0xFFFFFFFFFFFFFFFF)
        self._cursor += 1
        self._words[_W_CURSOR] = self._cursor   # single aligned int64 store

    @property
    def cursor(self) -> int:
        """Monotonic records-written count (drops = cursor - capacity)."""
        return self._cursor

    @property
    def drops(self) -> int:
        """Records overwritten by wraparound (counted, never silent)."""
        return max(0, self._cursor - self._capacity)

    def close(self, unlink: bool = True) -> None:
        """Unmap (and by default destroy) this ring's segment."""
        if self.closed:
            return
        self.closed = True
        self._buf = None
        self._words = None
        self._arena.close()
        if unlink:
            _unlink_quiet(self._arena.name)


def _ring() -> _TraceRing:
    """This thread's ring for the current session (lazily created)."""
    r = getattr(_tls, "ring", None)
    if r is None or r.closed or r.session != TRACE.session:
        name = (f"{_PREFIX}-{TRACE.session}-{os.getpid()}"
                f"-{next(_ring_seq)}")
        r = _TraceRing.create(name, TRACE.capacity)
        _tls.ring = r
        with _rings_lock:
            _rings.append(r)
    return r


# -- emit API ---------------------------------------------------------------

def emit(kind: int, t0: int, rid: int = 0, arg: int = 0,
         t1: Optional[int] = None) -> None:
    """Record one span ``[t0, t1]`` (``t1`` defaults to now). No-op when
    tracing is disabled — callers pre-guard with ``TRACE.enabled`` so the
    disabled cost stays one attribute check."""
    if not TRACE.enabled:
        return
    _ring().write(kind, t0, now() if t1 is None else t1, rid, arg)


def instant(kind: int, rid: int = 0, arg: int = 0) -> None:
    """Record a zero-duration event at the current time."""
    if not TRACE.enabled:
        return
    t = now()
    _ring().write(kind, t, t, rid, arg)


class _Span:
    """Context manager emitting one span on exit (cold paths and tests;
    hot paths inline the guard + :func:`emit` instead)."""
    __slots__ = ("kind", "rid", "arg", "_t0")

    def __init__(self, kind: int, rid: int = 0, arg: int = 0):
        self.kind, self.rid, self.arg = kind, rid, arg
        self._t0 = 0

    def __enter__(self):
        if TRACE.enabled:
            self._t0 = now()
        return self

    def __exit__(self, *exc):
        if TRACE.enabled and self._t0:
            emit(self.kind, self._t0, self.rid, self.arg)
        return False


def span(kind: int, rid: int = 0, arg: int = 0) -> _Span:
    """``with span(KIND, rid): ...`` — convenience span recorder."""
    return _Span(kind, rid, arg)


# -- lifecycle --------------------------------------------------------------

def enable(capacity: Optional[int] = None,
           session: Optional[str] = None) -> str:
    """Turn tracing on process-wide and return the session id.

    The flag, session id, and ring capacity are exported through the
    environment so processes spawned *after* this call inherit them and
    trace into the same session without any further coordination.
    """
    session = session or f"{os.getpid():x}{time.monotonic_ns() & 0xFFFFFF:x}"
    capacity = capacity or TRACE.capacity or DEFAULT_CAPACITY
    os.environ[ENV_FLAG] = "1"
    os.environ[ENV_SESSION] = session
    os.environ[ENV_CAPACITY] = str(capacity)
    TRACE.session = session
    TRACE.capacity = capacity
    TRACE.enabled = True
    return session


def disable(unlink: bool = True) -> None:
    """Turn tracing off and release this process's rings (idempotent)."""
    TRACE.enabled = False
    os.environ.pop(ENV_FLAG, None)
    os.environ.pop(ENV_SESSION, None)
    os.environ.pop(ENV_CAPACITY, None)
    with _rings_lock:
        rings, _rings[:] = list(_rings), []
    for r in rings:
        try:
            r.close(unlink=unlink)
        except Exception:
            pass


def _close_local_rings() -> None:
    """atexit: unmap this process's rings WITHOUT unlinking them — the
    records must survive for the collector, but leaving live memoryview
    exports to interpreter teardown makes ``SharedMemory.__del__`` print
    ignored BufferErrors in every traced child."""
    with _rings_lock:
        rings, _rings[:] = list(_rings), []
    for r in rings:
        try:
            r.close(unlink=False)
        except Exception:
            pass


atexit.register(_close_local_rings)


def emitted_count() -> int:
    """Records written by THIS process (0 when tracing never ran — the
    counted gate behind "tracing disabled writes exactly 0 records")."""
    with _rings_lock:
        return sum(r.cursor for r in _rings)


def dropped_count() -> int:
    """Records lost to wraparound in this process's rings."""
    with _rings_lock:
        return sum(r.drops for r in _rings)


# -- collection -------------------------------------------------------------

@dataclass
class RingDump:
    """One collected ring: identity, loss accounting, and its records."""
    name: str
    pid: int
    tid: int
    drops: int
    records: np.ndarray                 # RECORD_DTYPE, oldest → newest


@dataclass
class TraceView:
    """Every collected ring of a session, with join/export helpers."""
    rings: list = field(default_factory=list)

    @property
    def total_records(self) -> int:
        """Records actually collected across all rings."""
        return sum(len(r.records) for r in self.rings)

    @property
    def total_drops(self) -> int:
        """Records lost to ring wraparound across all rings."""
        return sum(r.drops for r in self.rings)

    @property
    def pids(self) -> set:
        """Distinct writer processes seen in this view."""
        return {r.pid for r in self.rings}

    def records_of(self, kind: int) -> np.ndarray:
        """All records of one span kind, merged across rings."""
        parts = [r.records[r.records["kind"] == kind] for r in self.rings]
        parts = [p for p in parts if len(p)]
        if not parts:
            return np.empty(0, RECORD_DTYPE)
        return np.concatenate(parts)

    def durations_ns(self, kind: int) -> np.ndarray:
        """``t1 - t0`` (ns) for every span of one kind."""
        recs = self.records_of(kind)
        return (recs["t1"] - recs["t0"]).astype(np.int64)

    def kinds_for_rid(self, rid: int) -> dict:
        """kind → (pid, t0, t1) spans carrying this request id."""
        out = {}
        for r in self.rings:
            hit = r.records[r.records["rid"] == rid]
            for rec in hit:
                out.setdefault(int(rec["kind"]), []).append(
                    (r.pid, int(rec["t0"]), int(rec["t1"])))
        return out

    def phase_totals(self) -> dict:
        """kind name → ``(count, total_ns)`` across the whole view.

        Wall-time spans only — counter records (kinds ≥ ``CTR_FIRST``,
        whose "duration" is a counter delta) are excluded; reduce those
        with :func:`repro.obs.hwcounters.counters_from_view`."""
        out = {}
        for kind, name in KIND_NAMES.items():
            if kind >= CTR_FIRST:
                continue
            d = self.durations_ns(kind)
            if len(d):
                out[name] = (int(len(d)), int(d.sum()))
        return out

    def chrome_events(self) -> list:
        """Chrome-trace ``X`` (complete) events, one per record."""
        events = []
        for r in self.rings:
            for rec in r.records:
                kind = int(rec["kind"])
                if kind >= CTR_FIRST:
                    # counter record: the "duration" is a counter delta —
                    # render as a zero-width instant carrying the value
                    events.append({
                        "name": KIND_NAMES.get(kind, f"kind{kind}"),
                        "cat": "hwctr", "ph": "i", "s": "t",
                        "pid": r.pid, "tid": r.tid,
                        "ts": int(rec["t0"]) / 1e3,      # µs
                        "args": {"rid": int(rec["rid"]),
                                 "phase_kind": int(rec["arg"]),
                                 "delta": int(rec["t1"]) - int(rec["t0"])},
                    })
                    continue
                events.append({
                    "name": KIND_NAMES.get(kind, f"kind{kind}"),
                    "cat": "rocket", "ph": "X",
                    "pid": r.pid, "tid": r.tid,
                    "ts": int(rec["t0"]) / 1e3,          # µs
                    "dur": max(int(rec["t1"]) - int(rec["t0"]), 0) / 1e3,
                    "args": {"rid": int(rec["rid"]), "arg": int(rec["arg"])},
                })
        events.sort(key=lambda e: e["ts"])
        return events

    def chrome_trace(self) -> dict:
        """The full Chrome/Perfetto trace object (``traceEvents`` form)."""
        return {"traceEvents": self.chrome_events(),
                "displayTimeUnit": "ms",
                "otherData": {"drops": self.total_drops,
                              "rings": len(self.rings)}}

    def save_chrome(self, path: str) -> None:
        """Write ``trace.json`` loadable by Perfetto / chrome://tracing."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def discover(session: Optional[str] = None) -> list:
    """Ring segment names of a session, found by listing ``/dev/shm``."""
    session = session or TRACE.session
    prefix = f"{_PREFIX}-{session}-"
    try:
        names = os.listdir(_SHM_DIR)
    except OSError:
        return []
    return sorted(n for n in names if n.startswith(prefix))


def _read_ring(name: str) -> Optional[RingDump]:
    """Map one ring read-only and copy out its valid records in order."""
    from repro.ipc.shm import SharedMemoryArena  # runtime import (cycle)
    try:
        arena = SharedMemoryArena(name, create=False)
    except (FileNotFoundError, ValueError):
        return None
    _untrack(arena._shm)            # attach registers again in some setups
    try:
        words = arena.control_words()
        cap = int(words[_W_CAPACITY])
        cur = int(words[_W_CURSOR])
        pid = int(words[_W_PID])
        tid = int(words[_W_TID])
        if cap <= 0:
            return None
        recs = np.frombuffer(arena.view(0, cap * RECORD_BYTES), RECORD_DTYPE)
        if cur <= cap:
            out = recs[:cur].copy()
        else:                       # wrapped: oldest record sits at cursor%cap
            head = cur % cap
            out = np.concatenate([recs[head:], recs[:head]])
        del recs, words
        return RingDump(name=name, pid=pid, tid=tid,
                        drops=max(0, cur - cap), records=out)
    finally:
        arena.close()


def collect(session: Optional[str] = None, unlink: bool = False) -> TraceView:
    """Map every ring of a session read-only and return the joined view.

    ``unlink=True`` destroys the segments after reading (the collector
    owns cleanup — writer processes never unlink their own rings, so a
    client's records survive its exit)."""
    view = TraceView()
    for name in discover(session):
        dump = _read_ring(name)
        if dump is not None:
            view.rings.append(dump)
        if unlink:
            _unlink_quiet(name)
    return view
