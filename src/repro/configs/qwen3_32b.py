"""qwen3-32b — dense, GQA + qk_norm [hf:Qwen/Qwen3-8B family; hf].

64L, d_model=5120, 64H (kv=8, head_dim=128), d_ff=25600, vocab 151936.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1.0e6,
        fsdp=True,
    )
