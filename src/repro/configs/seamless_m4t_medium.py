"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596; hf].

12L encoder + 12L decoder, d_model=1024, 16H (kv=16), d_ff=4096,
vocab 256206.  The speech/text modality frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (batch, src_len, d_model).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=24,            # 12 enc + 12 dec
        enc_layers=12,
        dec_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        mlp_type="gelu",
        norm_type="layernorm",
        frontend="frame_stub",
        rope_theta=10_000.0,
    )
