"""zamba2-2.7b — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242; hf].

54 Mamba2 layers, d_model=2560, ssm_state=64; one weight-shared attention+MLP
block (32H, kv=32, d_ff=10240) applied every 6 layers, vocab 32000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,             # shared attention block
        num_kv_heads=32,
        d_ff=10240,               # shared block MLP
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        shared_attn_every=6,
        tie_embeddings=True,
    )
