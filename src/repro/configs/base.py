"""Configuration system for ROCKET-JAX.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances.  ``(arch, shape)``
cells are enumerated by :func:`cells` with explicit skip reasons (e.g.
``long_500k`` for pure full-attention architectures).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- block variants -----------------------------------------------------
    mlp_type: str = "swiglu"        # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    qk_norm: bool = False
    rope_theta: float = 1.0e6
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- hybrid (zamba2-style shared attention block) -------------------------
    shared_attn_every: int = 0      # apply the weight-shared attn+MLP block every k layers

    # --- xLSTM ----------------------------------------------------------------
    slstm_every: int = 0            # every k-th block is an sLSTM block (rest mLSTM)
    mlstm_proj_factor: float = 2.0

    # --- encoder-decoder -------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # --- modality frontends (STUBS: precomputed embeddings) --------------------
    frontend: str = "none"          # none | patch_stub | frame_stub
    num_patches: int = 0            # vlm: patch embeddings prepended to the sequence

    # --- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"         # activation dtype
    param_dtype: str = "bfloat16"   # parameter storage dtype

    # --- scale / sharding hints ---------------------------------------------------
    fsdp: bool = False              # shard parameters over the data axis too
    remat: bool = True              # rematerialize block internals

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid / linear-attention families."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        """All assigned archs have a decoder (none are encoder-only)."""
        return True

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        from repro.models.registry import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params_analytic
        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Shape configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch        # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


# ---------------------------------------------------------------------------
# Smoke-test reduction
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant: few layers, narrow width, tiny tables."""
    kv = max(2, min(cfg.num_kv_heads, 2))
    changes = dict(
        num_layers=max(2, min(cfg.num_layers, 2)),
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=128,
        dtype="float32",
        param_dtype="float32",
        fsdp=False,
        remat=False,
    )
    if cfg.num_experts:
        # cf=4.0 makes the tiny config dropless (cap >= group size), so the
        # prefill-vs-decode consistency tests are exact.
        changes.update(num_experts=4, num_experts_per_token=2,
                       moe_capacity_factor=4.0)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.shared_attn_every:
        # keep the hybrid pattern visible: 4 ssm layers, shared block every 2
        changes.update(num_layers=4, shared_attn_every=2)
    if cfg.slstm_every:
        changes.update(num_layers=2, slstm_every=2)
    if cfg.enc_layers:
        changes.update(enc_layers=2, dec_layers=2)
    if cfg.num_patches:
        changes.update(num_patches=8)
    return replace(cfg, **changes)


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
SMOKE_DECODE_SHAPE = ShapeConfig("smoke_decode", "decode", 32, 2)
SMOKE_PREFILL_SHAPE = ShapeConfig("smoke_prefill", "prefill", 32, 2)


# ---------------------------------------------------------------------------
# Cell enumeration (arch x shape) with skip reasons
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    skip_reason: Optional[str] = None

    @property
    def runnable(self) -> bool:
        return self.skip_reason is None


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 512k-token quadratic KV decode is "
                "sub-quadratic-only per assignment (see DESIGN.md §5)")
    return None


def cells(arch_ids=None, shape_names=None) -> list[Cell]:
    from repro.configs import ARCHS, get_config
    out = []
    for a in (arch_ids or list(ARCHS)):
        cfg = get_config(a)
        for s in (shape_names or list(SHAPES)):
            out.append(Cell(a, s, cell_skip_reason(cfg, SHAPES[s])))
    return out
