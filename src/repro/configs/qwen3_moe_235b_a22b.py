"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B family; hf].

94L, d_model=4096, 64H (kv=4, head_dim=128), expert d_ff=1536, vocab 151936.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,                 # per-expert FFN width
        vocab_size=151936,
        num_experts=128,
        num_experts_per_token=8,
        qk_norm=True,
        rope_theta=1.0e6,
        fsdp=True,
    )
