"""minitron-8b — pruned nemotron [arXiv:2407.14679; hf].

32L, d_model=4096, 32H (kv=8, head_dim=128), d_ff=16384, vocab 256000.
Keeps nemotron's squared-ReLU MLP.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "minitron-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        mlp_type="squared_relu",
        norm_type="layernorm",
        rope_theta=10_000.0,
        fsdp=True,
    )
