"""nemotron-4-15b — dense, GQA, squared-ReLU MLP [arXiv:2402.16819; unverified].

32L, d_model=6144, 48H (kv=8, head_dim=128), d_ff=24576, vocab 256000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "nemotron-4-15b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        mlp_type="squared_relu",
        norm_type="layernorm",
        rope_theta=10_000.0,
        fsdp=True,
    )
