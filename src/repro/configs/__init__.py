"""Architecture registry: the 10 assigned architectures × 4 assigned shapes."""
from __future__ import annotations

from repro.configs.base import (
    Cell,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SMOKE_DECODE_SHAPE,
    SMOKE_PREFILL_SHAPE,
    SMOKE_SHAPE,
    cell_skip_reason,
    cells,
    reduce_for_smoke,
)

from repro.configs import (
    xlstm_350m,
    seamless_m4t_medium,
    zamba2_2_7b,
    qwen3_32b,
    nemotron_4_15b,
    granite_8b,
    minitron_8b,
    qwen3_moe_235b_a22b,
    granite_moe_1b_a400m,
    phi_3_vision_4_2b,
)

_MODULES = (
    xlstm_350m,
    seamless_m4t_medium,
    zamba2_2_7b,
    qwen3_32b,
    nemotron_4_15b,
    granite_8b,
    minitron_8b,
    qwen3_moe_235b_a22b,
    granite_moe_1b_a400m,
    phi_3_vision_4_2b,
)

ARCHS: dict[str, object] = {m.ARCH_ID: m for m in _MODULES}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return reduce_for_smoke(get_config(arch_id))


def list_archs() -> list[str]:
    return list(ARCHS)


__all__ = [
    "ARCHS", "Cell", "ModelConfig", "ShapeConfig", "SHAPES",
    "SMOKE_SHAPE", "SMOKE_DECODE_SHAPE", "SMOKE_PREFILL_SHAPE",
    "cells", "cell_skip_reason", "get_config", "get_smoke_config",
    "list_archs", "reduce_for_smoke",
]
