"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24 blocks, d_model=1024, 4 heads, d_ff=0 (blocks carry their own up/down
projections), vocab 50304.  7:1 mLSTM:sLSTM ratio -> every 8th block sLSTM.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "xlstm-350m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        slstm_every=8,
        mlstm_proj_factor=2.0,
        norm_type="layernorm",
        tie_embeddings=True,
    )
