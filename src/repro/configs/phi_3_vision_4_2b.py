"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend STUB
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L, d_model=3072, 32H (kv=32 — MHA, head_dim=96), d_ff=8192, vocab 32064.
The CLIP vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (batch, num_patches, d_model) fused at the head of the sequence.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=10_000.0,
        frontend="patch_stub",
        num_patches=576,           # 336px CLIP-style patch grid
    )
