"""granite-moe-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L, d_model=1024, 16H (kv=8, head_dim=64), expert d_ff=512, vocab 49155.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-moe-1b-a400m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                  # per-expert FFN width
        vocab_size=49155,
        num_experts=32,
        num_experts_per_token=8,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
