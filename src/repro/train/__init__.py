from repro.train.planner import RuntimePlan, plan_train
from repro.train.step import TrainConfig, init_train_state, make_train_step

__all__ = ["RuntimePlan", "TrainConfig", "init_train_state",
           "make_train_step", "plan_train"]
