"""Training step: loss -> grads (with sharding-local microbatch accumulation)
-> AdamW update.

Tier-2 ROCKET movement modes are applied *around* this function by the
launcher via sharding specs (sync = all-reduce baseline; pipelined = ZeRO-1
moment sharding -> reduce-scatter + all-gather; compression = bf16 grad sync
via ``AdamWConfig.grad_sync_dtype``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.registry import ModelAPI
from repro.optim import adamw
from repro.sharding import api as shard_api


@dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    microbatches: int = 1
    accum_dtype: str = "float32"
    # manual data parallelism over these mesh axes (shard_map): gradients are
    # accumulated *locally* and reduced ONCE per step — the explicit analogue
    # of deferring completion checks to batch granularity (paper's pipelined
    # mode), instead of GSPMD's per-layer in-loop all-reduces.  Requires
    # replicated parameters over these axes (layout dp_only for model axis).
    manual_dp_axes: tuple = ()


def _split_microbatches(batch, m: int):
    """(B, ...) -> (M, B//M, ...) preserving per-device row locality:
    reshape (B,...)->(B//M, M, ...) keeps each device's rows in place, then
    the scan axis is moved to the front (a transpose over a replicated dim).
    """
    def fn(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return jnp.swapaxes(x.reshape(b // m, m, *x.shape[1:]), 0, 1)
    return jax.tree.map(fn, batch)


def make_train_step(model: ModelAPI, tcfg: TrainConfig):
    def loss_fn(params, mb):
        return model.loss(params, mb)

    def grads_of(params, batch):
        """loss/grads with local microbatch accumulation."""
        m = tcfg.microbatches
        if m > 1:
            mbs = _split_microbatches(batch, m)
            acc_dt = jnp.dtype(tcfg.accum_dtype)

            def mb_step(gacc, mb):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), gacc, grads)
                return gacc, (loss, metrics)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            gacc, (losses, metricss) = jax.lax.scan(mb_step, g0, mbs)
            grads = jax.tree.map(lambda g: g / m, gacc)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)),
                                   metricss)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if tcfg.manual_dp_axes:
            loss, metrics, grads = _manual_dp_grads(
                model, tcfg, grads_of, params, batch)
        else:
            loss, metrics, grads = grads_of(params, batch)
        params, opt_state, om = adamw.update(tcfg.opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def _manual_dp_grads(model, tcfg, grads_of, params, batch):
    """shard_map manual data parallelism: per-shard backward with *local*
    gradient accumulation, one ``pmean`` per step (batch-granularity
    completion, ROCKET pipelined mode at tier 2)."""
    from jax.sharding import PartitionSpec as P
    mesh = shard_api.get_mesh()
    axes = tuple(a for a in tcfg.manual_dp_axes if a in mesh.axis_names)

    def shard_fn(params, batch):
        loss, metrics, grads = grads_of(params, batch)
        sync_dt = tcfg.opt.grad_sync_dtype
        if sync_dt:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(sync_dt)), grads)
        grads = jax.lax.pmean(grads, axes)
        loss = jax.lax.pmean(loss, axes)
        metrics = jax.tree.map(lambda x: jax.lax.pmean(
            x.astype(jnp.float32), axes), metrics)
        return loss, metrics, grads

    batch_specs = jax.tree.map(
        lambda x: P(axes, *([None] * (x.ndim - 1))), batch)
    param_specs = jax.tree.map(lambda _: P(), params)
    out_specs = (P(), jax.tree.map(lambda _: P(), {"ce": 0, "aux": 0,
                                                   "tokens": 0}),
                 jax.tree.map(lambda _: P(), params))
    with shard_api.manual_mode():
        return compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(param_specs, batch_specs),
            out_specs=out_specs, check_vma=False)(params, batch)


def init_train_state(model: ModelAPI, rng):
    params = model.init(rng)
    return params, adamw.init(params)
