"""Runtime planning: choose microbatch count / accumulation dtype per
(arch × shape × mesh) from an activation-memory budget.

The same napkin math the paper applies to transfer sizes (Table III) applied
to activation residency: saved bytes per microbatch ≈
L_scan · (B_dev/µ) · S · D · bytes(act) (block boundaries only, full remat).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding import api as shard_api
from repro.sharding import rules


@dataclass(frozen=True)
class RuntimePlan:
    microbatches: int = 1
    accum_dtype: str = "float32"
    remat: bool = True

    def describe(self) -> str:
        return (f"microbatches={self.microbatches} accum={self.accum_dtype} "
                f"remat={self.remat}")


ACT_BUDGET_BYTES = int(2.0 * 2 ** 30)      # ~2 GB of saved activations/device


def plan_train(cfg: ModelConfig, shape: ShapeConfig,
               budget: int = ACT_BUDGET_BYTES) -> RuntimePlan:
    bsz = rules.batch_axis_size()
    b_dev = max(shape.global_batch // max(bsz, 1), 1)
    act_bytes = np.dtype(cfg.dtype).itemsize
    # per-device saved activations with microbatches=1 (block boundaries)
    saved = cfg.num_layers * b_dev * shape.seq_len * cfg.d_model * act_bytes
    m = 1
    while saved / m > budget and m < b_dev:
        m *= 2
    # grad accumulation buffers are fp32 param-sized; for very large models
    # accumulate in bf16 to halve resident bytes (documented precision trade)
    n_params = cfg.param_count()
    mesh = shard_api.get_mesh()
    mesh_devices = mesh.size if mesh is not None else 1
    accum = "float32"
    if m > 1 and n_params * 4 / max(mesh_devices, 1) > 2 * 2 ** 30:
        accum = "bfloat16"
    # remat only pays when activations would not fit: below half the budget
    # the recompute (≈ +1/3 compute, + layer re-reads) is pure waste
    remat = (saved / m) > budget // 2
    return RuntimePlan(microbatches=m, accum_dtype=accum, remat=remat)
