from repro.data.pipeline import (
    InputPipeline,
    IPCSource,
    SyntheticLMSource,
    make_source,
)

__all__ = ["InputPipeline", "IPCSource", "SyntheticLMSource", "make_source"]
