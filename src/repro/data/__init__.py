from repro.data.pipeline import InputPipeline, SyntheticLMSource

__all__ = ["InputPipeline", "SyntheticLMSource"]
