"""Input data pipeline with ROCKET tier-1 execution modes.

The host→device feed is the literal IPC analogue from the paper: each step's
batch is a multi-MB message from a producer process (here: the tokenizer /
synthetic source) to the consumer (the device step).  The pipeline supports

- ``sync``      — produce + transfer on the critical path (paper's cpu/DTO);
- ``async``     — next batch transferred while the current step runs;
- ``pipelined`` — depth-k prefetch queue, staging buffers reused from the
  persistent pool, completion checks deferred to batch granularity.

State (source position / PRNG) is checkpointable for fault tolerance.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.engine import AsyncTransferEngine
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy


# ---------------------------------------------------------------------------
# synthetic sources (self-contained substrate: no external data dependency)
# ---------------------------------------------------------------------------

class SyntheticLMSource:
    """Deterministic, seekable token source.

    Generates skewed token streams with short-range structure (a copy/induction
    pattern) so a real model actually learns measurable structure from it.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 batch_override: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = 0
        self.batch = batch_override or shape.global_batch

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    def _tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        base = rng.zipf(1.5, size=(b, s + 1)).astype(np.int64) % (v // 2)
        # induction structure: second half repeats the first half shifted
        half = (s + 1) // 2
        base[:, half:half * 2] = (base[:, :half] + 1) % (v // 2)
        return base.astype(np.int32)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        b, s = self.batch, self.shape.seq_len
        cfg = self.cfg
        toks = self._tokens(rng, b, s)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "audio":
            batch["frame_embeds"] = rng.standard_normal(
                (b, s, cfg.d_model), dtype=np.float32)
        if cfg.family == "vlm":
            p = cfg.num_patches
            st = max(s - p, 1)
            batch = {"tokens": toks[:, :st], "labels": toks[:, 1:st + 1],
                     "patch_embeds": rng.standard_normal(
                         (b, p, cfg.d_model), dtype=np.float32)}
        return batch


# ---------------------------------------------------------------------------
# IPC source: batches produced in a *separate process*, received over the
# shared-memory transport (repro.ipc) — the paper's producer↔consumer IPC
# made real instead of thread-simulated
# ---------------------------------------------------------------------------

class IPCSource:
    """Drop-in source whose batches come from a producer process.

    Deterministic contract: for the same ``(cfg, shape, seed)`` this yields
    byte-identical batches to an in-process :class:`SyntheticLMSource` —
    the transport moves bytes, it never transforms them.  ``state`` /
    ``restore`` are forwarded to the producer over the control channel
    (``seek``), so checkpoint replay works across the process boundary.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 batch_override: Optional[int] = None,
                 policy: Optional[OffloadPolicy] = None,
                 data_slots: int = 4,
                 data_slot_bytes: Optional[int] = None,
                 recv_timeout_s: float = 120.0):
        from repro.ipc import start_producer, tree_nbytes
        from repro.ipc.transport import TransportSpec

        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = 0
        self._timeout = recv_timeout_s
        if data_slot_bytes is None:
            # size slots from a locally produced sample batch (cheap: the
            # synthetic source is deterministic and stateless per step)
            sample = next(iter(SyntheticLMSource(cfg, shape, seed=seed,
                                                 batch_override=batch_override)))
            data_slot_bytes = max(tree_nbytes(sample) * 2, 1 << 20)
        spec = {"kind": "synthetic_lm", "cfg": cfg, "shape": shape,
                "seed": seed, "batch_override": batch_override}
        self._producer = start_producer(
            spec, policy=policy,
            spec=TransportSpec(data_slots=data_slots,
                               data_slot_bytes=data_slot_bytes))

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])
        gen = self._producer.seek(self.step, seed=self.seed)
        # drain in-flight batches from before the seek: only a batch carrying
        # the new generation is really the restored stream (a stale slot can
        # coincidentally hold the right step number — or the wrong seed)
        while True:
            batch, header = self._producer.recv_batch(self._timeout)
            if header.get("gen") != gen:
                continue
            if header.get("eof"):
                raise RuntimeError("producer ended during restore")
            if header.get("step") == self.step:
                self._replay = (batch, header)
                return

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        replay = getattr(self, "_replay", None)
        if replay is not None:
            self._replay = None
            batch, header = replay
        else:
            batch, header = self._producer.recv_batch(self._timeout)
            if header.get("eof"):
                raise StopIteration
        self.step = int(header["step"]) + 1
        return batch

    def close(self) -> None:
        self._producer.stop()


def make_source(cfg: ModelConfig, shape: ShapeConfig, source: str = "synthetic",
                seed: int = 0, **kwargs):
    """Source factory: ``synthetic`` (in-process) or ``ipc`` (real producer
    process over the shared-memory transport).

    Transport-only kwargs (``policy``, ``data_slots``, ...) are accepted for
    both kinds and ignored by ``synthetic``, so callers can flip the
    ``source`` flag without changing their call site.
    """
    if source == "synthetic":
        kwargs = {k: v for k, v in kwargs.items()
                  if k not in ("policy", "data_slots", "data_slot_bytes",
                               "recv_timeout_s")}
        return SyntheticLMSource(cfg, shape, seed=seed, **kwargs)
    if source == "ipc":
        return IPCSource(cfg, shape, seed=seed, **kwargs)
    raise ValueError(f"unknown source kind {source!r} "
                     "(expected 'synthetic' or 'ipc')")


# ---------------------------------------------------------------------------
# the pipeline: source -> staging pool -> transfer engine -> device
# ---------------------------------------------------------------------------

@dataclass
class PipelineStats:
    steps: int = 0
    produce_s: float = 0.0
    wait_s: float = 0.0


class InputPipeline:
    """ROCKET-mode input feeding; iterate to get device-resident batches."""

    def __init__(self, source, policy: OffloadPolicy = OffloadPolicy(),
                 latency: Optional[LatencyModel] = None,
                 sharding=None, engine: Optional[AsyncTransferEngine] = None):
        self.source = iter(source)
        self._src = source
        self.policy = policy
        self.sharding = sharding
        self.engine = engine or AsyncTransferEngine(policy, latency)
        self._pending: list = []
        self.stats = PipelineStats()

    def _submit_next(self):
        import time
        t0 = time.perf_counter()
        host_batch = next(self.source)
        self.stats.produce_s += time.perf_counter() - t0
        job = self.engine.submit(host_batch, self.sharding)
        self._pending.append(job)

    def __iter__(self):
        return self

    def __next__(self):
        import time
        depth = {ExecutionMode.SYNC: 1,
                 ExecutionMode.ASYNC: 2,
                 ExecutionMode.PIPELINED: self.policy.pipeline_depth + 1}[
                     self.policy.mode]
        while len(self._pending) < depth:
            self._submit_next()
        job = self._pending.pop(0)
        t0 = time.perf_counter()
        out = job.get()
        self.stats.wait_s += time.perf_counter() - t0
        self.stats.steps += 1
        return out

    def state(self) -> dict:
        # un-consumed prefetched batches are replayed on restore
        return {"source": self._src.state(),
                "inflight": len(self._pending)}

    def restore(self, state: dict) -> None:
        src_state = dict(state["source"])
        src_state["step"] = src_state["step"] - state.get("inflight", 0)
        self._src.restore(src_state)
        self._pending.clear()

    def close(self):
        self.engine.close()
        if hasattr(self._src, "close"):
            self._src.close()          # IPC sources stop their producer
