"""Serving-side ROCKET runtime: request dispatcher, handlers, query handler.

Mirrors the paper's server architecture (Fig. 7 / Listing 1):

- clients call ``request(mode=..., op=..., data=...)`` -> job id (or a
  blocking result in sync mode);
- a :class:`RequestDispatcher` routes messages to registered per-op
  handlers; in pipelined mode requests are *batched* (application-level
  request batching, §IV-C) before the handler runs;
- a :class:`QueryHandler` tracks completions; ``query(job_id)`` applies the
  hybrid polling strategy (size-aware deferral + short passive waits).

**Zero-copy batch formation** (the single-copy serving datapath): a request
may arrive carrying a :class:`~repro.ipc.channel.RecvLease` — its ``data``
is then a numpy view straight into the client's shared-memory ring slot.
During batch formation the dispatcher *gathers* those views into a pooled
batch buffer (one scatter-gather descriptor per batch on the process-wide
:class:`~repro.core.copyengine.CopyEngine` — the only server-side payload
memcpy per request) and releases every lease immediately after the gather,
before the handler runs, so ring slots recycle at copy speed rather than
model speed.  Handlers registered with ``slab_fn`` receive the pooled
batch buffer directly (no second per-row packing copy); plain ``batch_fn``
handlers receive row views into it.

**SLO lanes** (deadline-aware serving): every request carries a
``(priority, deadline_ns)`` pair (defaults: lane 0, no deadline).  Batch
formation pops a priority heap ordered ``(priority, deadline, seq)``
instead of a FIFO — lane 0 drains first, earliest deadline first within a
lane — and at pop time a :class:`~repro.core.latency.ServiceTimeModel`
(observed per-op service EWMA over the transfer model) predicts whether
the request can still make its deadline; one that can't is **shed**:
counted in ``DispatcherStats.shed`` and completed immediately with
:class:`DeadlineExceeded` (an error reply on the wire, never a silent
drop).  Completions that ran anyway but landed late count
``deadline_miss``.
"""
from __future__ import annotations

import heapq
import itertools
import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.copyengine import SGList, get_engine
from repro.core.latency import LatencyModel, ServiceTimeModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.core.queuepair import BufferPool
from repro.ft import inject as _inject
from repro.obs import hwcounters as _hw
from repro.obs import trace as _trace


class CircuitOpen(RuntimeError):
    """Fast-fail error for an op quarantined by its circuit breaker.

    A handler that keeps failing gets its op *contained*: instead of
    burning batch slots (and dispatcher worker time) on work that will
    fail anyway, every request for the op is completed immediately with
    this error until a half-open probe succeeds.  Like a shed, it is a
    counted error reply (``DispatcherStats.breaker_fast_fails``) — never
    a silent drop.
    """


class DeadlineExceeded(RuntimeError):
    """A request was shed (or would complete) past its deadline.

    Raised to the submitter through the normal completion path — a shed is
    an *immediate error reply*, never a silent drop: the request is counted
    (``DispatcherStats.shed``), its lease released, and its callback/query
    completed with this exception before any batch slot is spent on it.
    """


@dataclass
class Request:
    job_id: int
    op: str
    data: Any
    mode: ExecutionMode
    submit_t: float = field(default_factory=time.perf_counter)
    nbytes: int = 0
    # completion callback (multi-client serving): when set, the worker thread
    # calls ``callback(job_id, result_or_exception)`` instead of parking the
    # result in the QueryHandler — the IPC fabric uses this to demultiplex
    # batched results back to the right client transport.
    callback: Optional[Callable[[int, Any], None]] = None
    # zero-copy serving: the ring-slot lease backing ``data``.  The
    # dispatcher owns its release: after the batch gather (pipelined), or
    # after completion for solo execution.  Anything with a ``release()``
    # and a ``held`` attribute qualifies (tests pass stubs).
    lease: Optional[Any] = None
    # trace request id (0 = untraced): propagated from the wire by the
    # serving fabric so dispatcher spans join the cross-process timeline
    rid: int = 0
    # SLO lane: 0 = highest priority; batch formation pops lanes in order
    priority: int = 0
    # absolute deadline in time.perf_counter_ns() ticks (0 = none); set by
    # the client (cross-process CLOCK_MONOTONIC timebase) or the fabric's
    # default.  A request the service model predicts past this is shed.
    deadline_ns: int = 0

    def _release_lease(self) -> None:
        if self.lease is not None:
            lease, self.lease = self.lease, None
            try:
                lease.release()
            except Exception:
                # the client's transport may already be reaped (client died
                # mid-batch): a stale lease has nothing left to recycle, and
                # a release failure must never kill the serving worker loop
                pass


@dataclass
class _Failure:
    """Wrapper parking a handler exception in the QueryHandler (so a result
    that happens to *be* an Exception instance is not misread as an error)."""
    error: Exception


@dataclass
class DispatcherStats:
    requests: int = 0
    batches: int = 0
    batched_requests: int = 0
    queries: int = 0
    query_polls: int = 0
    mean_batch: float = 0.0
    gathers: int = 0             # batch-formation gathers (SG submissions)
    gathered_requests: int = 0   # requests copied slot → batch buffer
    slab_batches: int = 0        # batches handed to a slab_fn handler
    shed: int = 0                # requests refused pre-execution (counted,
                                 # each one got a DeadlineExceeded reply)
    deadline_miss: int = 0       # requests completed but past their deadline
    lane_requests: dict = field(default_factory=dict)  # per-priority intake
    lane_shed: dict = field(default_factory=dict)      # per-priority sheds
    breaker_opened: int = 0      # closed->open transitions (incl. reopen)
    breaker_recovered: int = 0   # half-open probe succeeded: op back in service
    breaker_fast_fails: int = 0  # requests fast-failed with CircuitOpen
    dedup_hits: int = 0          # replayed requests served from the window


class _LaneQueue:
    """Priority-lane request queue: min-heap on (priority, deadline, seq).

    Replaces the FIFO batch-formation feed: the front of the queue is
    always the most urgent pending request — lowest priority value first,
    earliest deadline inside a lane (no-deadline requests sort last in
    their lane), submit order as the final tiebreak.

    ``get(match=...)`` only pops while the *front* satisfies the
    predicate: when a higher-urgency request of a different op/lane
    arrives mid-window, the batch closes instead of reordering past it.
    A ``put(None)`` sentinel sorts after all real work and stops one
    worker (push one per worker).
    """

    _NO_DEADLINE = 1 << 62

    def __init__(self):
        self._heap: list = []
        self._cond = threading.Condition()
        self._seq = itertools.count()

    def put(self, req: Optional[Request]) -> None:
        with self._cond:
            if req is None:
                entry = (1 << 30, self._NO_DEADLINE, next(self._seq), None)
            else:
                entry = (req.priority, req.deadline_ns or self._NO_DEADLINE,
                         next(self._seq), req)
            heapq.heappush(self._heap, entry)
            self._cond.notify()

    def get(self, timeout: Optional[float] = None,
            match: Optional[Callable[[Request], bool]] = None
            ) -> Optional[Request]:
        """Pop the front request; ``None`` = stop sentinel.  Raises
        :class:`queue.Empty` on timeout or (with ``match``) when the
        front request doesn't satisfy the predicate."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        with self._cond:
            while True:
                if self._heap:
                    front = self._heap[0][3]
                    if front is None:
                        heapq.heappop(self._heap)
                        return None
                    if match is not None and not match(front):
                        raise queue.Empty
                    return heapq.heappop(self._heap)[3]
                remain = (deadline - time.perf_counter()
                          if deadline is not None else None)
                if remain is not None and remain <= 0:
                    raise queue.Empty
                self._cond.wait(remain)

    def __len__(self) -> int:
        with self._cond:
            return len(self._heap)


class _CircuitBreaker:
    """Per-op failure containment: closed → open → half-open → closed.

    ``threshold`` consecutive handler-invocation failures open the
    breaker; while open, requests fast-fail with :class:`CircuitOpen`.
    After ``cooldown_s`` the breaker goes half-open and admits exactly
    ONE probe invocation — success closes it (op back in service),
    failure reopens it for another cooldown.  Failures are counted per
    handler *invocation* (a failing batch is one failure, not K), so the
    breaker tracks "the handler is broken", not "traffic is heavy".
    """

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self._consecutive = 0
        self._opened_t = 0.0
        self._probing = False
        self._lock = threading.Lock()

    def admit(self) -> bool:
        """May a request for this op run right now?  (Half-open: only the
        single probe.)"""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                if time.perf_counter() - self._opened_t < self.cooldown_s:
                    return False
                self.state = "half-open"
                self._probing = False
            if self._probing:           # half-open: one probe at a time
                return False
            self._probing = True
            return True

    def record(self, ok: bool) -> Optional[str]:
        """Feed one handler-invocation outcome; returns the transition it
        caused (``"opened"``/``"recovered"``) or ``None``."""
        with self._lock:
            if ok:
                self._consecutive = 0
                if self.state != "closed":
                    self.state = "closed"
                    self._probing = False
                    return "recovered"
                return None
            self._consecutive += 1
            if self.state == "half-open":
                self.state = "open"
                self._opened_t = time.perf_counter()
                self._probing = False
                return "opened"
            if self.state == "closed" and self._consecutive >= self.threshold:
                self.state = "open"
                self._opened_t = time.perf_counter()
                return "opened"
            return None

    def export(self) -> dict:
        """Replicable breaker state (perf_counter stamps don't cross
        processes, so the open-cooldown clock restarts on import)."""
        with self._lock:
            return {"state": self.state, "consecutive": self._consecutive}

    def import_state(self, st: dict) -> None:
        """Adopt a peer's breaker state; an imported ``open`` breaker
        starts a fresh cooldown from now (conservative: the replica
        re-probes no earlier than the primary would have)."""
        with self._lock:
            self.state = st.get("state", "closed")
            self._consecutive = int(st.get("consecutive", 0))
            self._probing = False
            if self.state == "half-open":
                self.state = "open"
            if self.state == "open":
                self._opened_t = time.perf_counter()


class _DedupWindow:
    """Bounded idempotency window for exactly-once request replay.

    A reconnecting client resubmits requests whose replies it never saw;
    the original may (a) never have arrived, (b) still be executing, or
    (c) have completed with the reply lost on the torn-down transport.
    Keyed by the client's idempotent id, the window turns all three into
    exactly-once *execution*: (a) runs normally, (b) attaches the replay's
    reply callback to the in-flight entry, (c) replies immediately from
    the cached result.  Entries are LRU-evicted past ``capacity`` —
    sized (``OffloadPolicy.retry.dedup_window``) to comfortably cover a
    client's unacked window across a reconnect.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()   # key -> [state, payload]
        self._lock = threading.Lock()

    def admit(self, key) -> tuple:
        """Register ``key`` as in-flight; returns ``(is_replay, state,
        cached)`` where state is ``"new"``/``"inflight"``/``"done"``."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self._entries[key] = ["inflight", []]
                while len(self._entries) > self.capacity:
                    self._entries.popitem(last=False)
                return False, "new", None
            self._entries.move_to_end(key)
            if ent[0] == "done":
                return True, "done", ent[1]
            return True, "inflight", None

    def attach(self, key, callback) -> bool:
        """Queue a replay's callback behind the in-flight original; False
        if the entry completed meanwhile (caller replies from cache)."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None and ent[0] == "inflight":
                ent[1].append(callback)
                return True
            return False

    def result(self, key):
        with self._lock:
            ent = self._entries.get(key)
            return ent[1] if ent is not None and ent[0] == "done" else None

    def settle(self, key, out) -> list:
        """Record the original's completion; returns the queued replay
        callbacks to fire with the same result."""
        with self._lock:
            ent = self._entries.get(key)
            waiters = ent[1] if ent is not None and ent[0] == "inflight" \
                else []
            self._entries[key] = ["done", out]
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
            return waiters

    def export(self) -> list:
        """Settled entries as ``(key, result)`` pairs, LRU order —
        the replication-delta half of exactly-once: a standby importing
        these suppresses re-execution of everything the primary already
        completed.  In-flight entries are NOT exported (their results
        don't exist yet; replays will re-execute on the replica, still
        producing exactly one reply since the original's died with the
        primary)."""
        with self._lock:
            return [(k, v[1]) for k, v in self._entries.items()
                    if v[0] == "done"]

    def import_entries(self, entries) -> int:
        """Install settled entries from a peer's :meth:`export`; returns
        how many landed (the LRU cap still applies)."""
        n = 0
        with self._lock:
            for key, out in entries:
                self._entries[key] = ["done", out]
                self._entries.move_to_end(key)
                n += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
        return n


class QueryHandler:
    """Completion tracking + hybrid polling for result queries."""

    def __init__(self, latency: LatencyModel, policy: OffloadPolicy):
        self._results: dict[int, Any] = {}
        self._events: dict[int, threading.Event] = {}
        self._meta: dict[int, Request] = {}
        self._lock = threading.Lock()
        self.latency = latency
        self.policy = policy
        self.polls = 0

    def register(self, req: Request) -> None:
        with self._lock:
            self._events[req.job_id] = threading.Event()
            self._meta[req.job_id] = req

    def complete(self, job_id: int, result: Any) -> None:
        with self._lock:
            self._results[job_id] = result
            ev = self._events.get(job_id)
        if ev is not None:
            ev.set()

    def query(self, job_id: int, timeout: float = 60.0) -> Any:
        with self._lock:
            ev = self._events.get(job_id)
            req = self._meta.get(job_id)
        if ev is None:
            raise KeyError(f"unknown job {job_id}")
        if not ev.is_set() and req is not None:
            # size-aware deferral before polling (remaining predicted latency)
            pred = self.latency.defer_seconds(req.nbytes, self.policy.defer_fraction)
            remain = pred - (time.perf_counter() - req.submit_t)
            if remain > 0:
                time.sleep(min(remain, timeout))
        deadline = time.perf_counter() + timeout
        quantum = self.policy.poll_interval_us * 1e-6
        while not ev.is_set():
            self.polls += 1
            if time.perf_counter() > deadline:
                raise TimeoutError(f"job {job_id} timed out")
            ev.wait(quantum)
        with self._lock:
            out = self._results.pop(job_id)
            self._events.pop(job_id, None)
            self._meta.pop(job_id, None)
        return out


class RequestDispatcher:
    """Routes requests to registered handlers; batches in pipelined mode."""

    def __init__(self, policy: OffloadPolicy = OffloadPolicy(),
                 latency: Optional[LatencyModel] = None,
                 max_batch_wait_s: float = 0.002,
                 workers: int = 1,
                 breaker_threshold: int = 5,
                 breaker_cooldown_s: float = 0.25):
        self.policy = policy
        self.latency = latency or LatencyModel()
        self.queries = QueryHandler(self.latency, policy)
        self.stats = DispatcherStats()
        # admission predictor: per-op observed service EWMA over the
        # transfer model — drives deadline-miss shedding in the serve loop
        self.service = ServiceTimeModel(self.latency)
        # per-op circuit breakers (containment): ``breaker_threshold``
        # consecutive handler failures quarantine the op with fast-fail
        # CircuitOpen replies until a half-open probe recovers it; 0
        # disables breakers entirely
        self._breaker_threshold = breaker_threshold
        self._breaker_cooldown_s = breaker_cooldown_s
        self._breakers: dict[str, _CircuitBreaker] = {}
        # exactly-once replay window for reconnecting clients (idempotent
        # request ids from the wire; see _DedupWindow)
        self._dedup = _DedupWindow(policy.retry.dedup_window)
        self._handlers: dict[str, Callable] = {}
        self._batch_handlers: dict[str, Callable] = {}
        self._slab_handlers: dict[str, Callable] = {}
        self._pool = BufferPool(max_per_key=4)   # pooled batch buffers
        self._q = _LaneQueue()
        self._ids = itertools.count()
        self._max_wait = max_batch_wait_s
        self._slock = threading.Lock()           # stats (workers > 1 race)
        self._running = True
        # a worker pool (sized to the fabric's reactor shards) lets batches
        # execute concurrently — all workers pop the same lane queue, so
        # global lane order is preserved even with several execution lanes
        self._workers = [threading.Thread(target=self._serve_loop,
                                          daemon=True)
                         for _ in range(max(1, workers))]
        for w in self._workers:
            w.start()
        self._worker = self._workers[0]          # backwards-compat alias

    # -- handler registration (paper: workload-specific handlers) ------------
    def register_handler(self, op: str, fn: Callable,
                         batch_fn: Optional[Callable] = None,
                         slab_fn: Optional[Callable] = None) -> None:
        """``fn(data) -> result``; optional ``batch_fn(list[data]) -> list``;
        optional ``slab_fn(slab, shapes) -> list`` receiving the pooled
        gather buffer directly — ``slab[i]``'s leading ``shapes[i]`` region
        holds request *i*'s payload (zero-padded to the batch max), so the
        handler consumes the batch with **no additional packing copy**."""
        self._handlers[op] = fn
        if batch_fn is not None:
            self._batch_handlers[op] = batch_fn
        if slab_fn is not None:
            self._slab_handlers[op] = slab_fn

    # -- containment: per-op circuit breakers ---------------------------------
    def _breaker(self, op: str) -> Optional[_CircuitBreaker]:
        if self._breaker_threshold <= 0:
            return None
        br = self._breakers.get(op)
        if br is None:
            br = self._breakers.setdefault(
                op, _CircuitBreaker(self._breaker_threshold,
                                    self._breaker_cooldown_s))
        return br

    def breaker_state(self, op: str) -> str:
        """This op's breaker state (``closed``/``open``/``half-open``) —
        introspection for tests and dashboards."""
        br = self._breakers.get(op)
        return br.state if br is not None else "closed"

    def _breaker_note(self, br: Optional[_CircuitBreaker], ok: bool) -> None:
        """Feed one handler-invocation outcome; count transitions."""
        if br is None:
            return
        transition = br.record(ok)
        if transition == "opened":
            with self._slock:
                self.stats.breaker_opened += 1
        elif transition == "recovered":
            with self._slock:
                self.stats.breaker_recovered += 1

    def _call_handler(self, fn: Callable, *args):
        """Every handler invocation funnels through here: the
        ``dispatcher.handler.error`` injection site (a stand-in for an
        arbitrary handler bug) guards the call."""
        if _inject._PLANE is not None \
                and _inject.fire("dispatcher.handler.error") is not None:
            raise _inject.InjectedFault("injected handler failure")
        return fn(*args)

    # -- client API (paper Listing 1) -----------------------------------------
    def request(self, op: str, data: Any,
                mode: ExecutionMode | str | None = None,
                priority: int = 0, deadline_ns: int = 0) -> int | Any:
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        req = Request(next(self._ids), op, data, mode,
                      nbytes=int(np.asarray(data).nbytes)
                      if isinstance(data, np.ndarray) else 0,
                      priority=priority, deadline_ns=deadline_ns)
        self._count_in(req)
        if mode == ExecutionMode.SYNC:
            # inline fast path — still SLO-accounted (an expired deadline
            # sheds here too, a late completion is a counted miss) and
            # still breaker-contained (a quarantined op fast-fails inline
            # callers exactly like queued ones)
            err = self._shed_verdict(req)
            if err is not None:
                raise err
            br = self._breaker(op)
            if br is not None and not br.admit():
                with self._slock:
                    self.stats.breaker_fast_fails += 1
                raise CircuitOpen(f"op {op!r} quarantined (circuit open)")
            t0 = time.perf_counter()
            try:
                out = self._call_handler(self._handlers[op], data)
            except Exception:
                self._breaker_note(br, False)
                raise
            self._breaker_note(br, True)
            self.service.observe(op, time.perf_counter() - t0)
            self._note_late(req)
            return out
        self.queries.register(req)
        self._q.put(req)
        return req.job_id

    def _dedup_admit(self, key: Any,
                     on_complete: Optional[Callable[[int, Any], None]],
                     lease: Optional[Any]) -> tuple[bool, Optional[Callable]]:
        """Exactly-once admission for an idempotent request id.

        Returns ``(handled, callback)``.  ``handled`` means the request is
        a replay and was fully resolved here (cached result replied, or
        the caller's callback attached to the in-flight original) — do not
        enqueue it.  Otherwise ``callback`` is the (possibly wrapped)
        completion callback to enqueue with: for a first-seen key it
        settles the dedup window and fires any waiters that attached while
        the request was in flight."""
        if key is None:
            return False, on_complete
        is_replay, state, cached = self._dedup.admit(key)
        if not is_replay:
            def settle(job_id, out, _key=key, _cb=on_complete):
                # the cached copy outlives any lease/slab the result may
                # alias — materialize before it enters the window
                if isinstance(out, np.ndarray):
                    out = np.array(out)
                waiters = self._dedup.settle(_key, out)
                if _cb is not None:
                    _cb(job_id, out)
                for w in waiters:
                    try:
                        w(job_id, out)
                    except Exception:
                        pass
            return False, settle
        with self._slock:
            self.stats.dedup_hits += 1
        if lease is not None:        # replay never consumes the payload
            try:
                lease.release()
            except Exception:
                pass
        if state == "inflight" and (
                on_complete is None
                or self._dedup.attach(key, on_complete)):
            return True, None        # original completion will reply
        cached = self._dedup.result(key) if cached is None else cached
        if on_complete is not None:
            try:
                on_complete(-1, cached)
            except Exception:
                pass
        return True, None

    def submit(self, op: str, data: Any,
               mode: ExecutionMode | str | None = None,
               on_complete: Optional[Callable[[int, Any], None]] = None,
               lease: Optional[Any] = None,
               priority: int = 0, deadline_ns: int = 0,
               dedup: Any = None) -> int:
        """Enqueue a request without ever blocking the caller.

        Unlike :meth:`request`, sync mode is *not* executed inline: every
        mode goes through the worker thread (sync/async solo, pipelined
        batchable), so a polling thread — the IPC reactor — can hand off
        work from many clients without stalling its sweep.  When
        ``on_complete`` is given it fires from the worker thread with
        ``(job_id, result_or_exception)`` and the result bypasses the
        QueryHandler; otherwise fetch it with :meth:`query`.

        ``lease`` is the zero-copy ring-slot lease backing ``data`` (views
        into shared memory); the dispatcher releases it after batch gather
        or solo completion — never before the payload has been consumed.

        ``dedup`` is an optional idempotent request id (any hashable):
        a key already seen inside the dedup window is NOT re-executed —
        a cached result is replied immediately, or the callback is
        attached to the in-flight original (requires ``on_complete``).
        This is the server half of reconnect-with-replay: a client may
        resubmit after a lost reply without double-executing the handler.
        """
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        handled, on_complete = self._dedup_admit(dedup, on_complete, lease)
        if handled:
            return -1
        req = Request(next(self._ids), op, data, mode,
                      nbytes=int(np.asarray(data).nbytes)
                      if isinstance(data, np.ndarray) else 0,
                      callback=on_complete, lease=lease,
                      priority=priority, deadline_ns=deadline_ns)
        self._count_in(req)
        if on_complete is None:
            self.queries.register(req)
        self._q.put(req)
        return req.job_id

    def submit_many(self, items: Sequence[dict]) -> list[int]:
        """Enqueue a batch of requests in one pass (same semantics per
        item as :meth:`submit`; keys: ``op``, ``data``, optional ``mode``,
        ``on_complete``, ``lease``).

        This is the reactor's frame-drain feed: a client's coalesced
        frame arrives as one list, and all K requests land in the batch
        window together — the serve loop's first ``get`` then assembles
        the whole batch without waiting out ``max_batch_wait_s`` between
        members, so a microbatch on the wire becomes a batch in the
        handler without K separate submit round-trips.  Optional item
        keys ``priority`` and ``deadline_ns`` place the request in its
        SLO lane (see :class:`_LaneQueue`); optional key ``dedup`` is the
        idempotent request id (see :meth:`submit`) — replayed items are
        resolved from the dedup window and report job id ``-1``."""
        reqs = []
        jobs = []
        for it in items:
            mode = it.get("mode")
            mode = (ExecutionMode(mode) if mode is not None
                    else self.policy.mode)
            data = it["data"]
            handled, cb = self._dedup_admit(
                it.get("dedup"), it.get("on_complete"), it.get("lease"))
            if handled:
                jobs.append(-1)
                continue
            req = Request(
                next(self._ids), it["op"], data, mode,
                nbytes=int(np.asarray(data).nbytes)
                if isinstance(data, np.ndarray) else 0,
                callback=cb, lease=it.get("lease"),
                rid=it.get("rid", 0), priority=it.get("priority", 0),
                deadline_ns=it.get("deadline_ns", 0))
            reqs.append(req)
            jobs.append(req.job_id)
        for req in reqs:
            self._count_in(req)
            if req.callback is None:
                self.queries.register(req)
            self._q.put(req)
        return jobs

    def query(self, job_id: int, timeout: float = 60.0) -> Any:
        self.stats.queries += 1
        out = self.queries.query(job_id, timeout)
        self.stats.query_polls = self.queries.polls
        if isinstance(out, _Failure):
            raise out.error
        return out

    # -- admission: counted intake + deadline-miss shedding ---------------------
    def _count_in(self, req: Request) -> None:
        with self._slock:
            self.stats.requests += 1
            lanes = self.stats.lane_requests
            lanes[req.priority] = lanes.get(req.priority, 0) + 1

    def _shed_verdict(self, req: Request) -> Optional[DeadlineExceeded]:
        """Counted shed decision: when the service model predicts the
        request past its deadline, count it (total + per lane) and return
        the error to deliver; ``None`` admits the request."""
        if not req.deadline_ns:
            return None
        now_ns = time.perf_counter_ns()
        pred_ns = int(self.service.predict_s(req.op, req.nbytes) * 1e9)
        if now_ns + pred_ns <= req.deadline_ns:
            return None
        with self._slock:
            self.stats.shed += 1
            lane = self.stats.lane_shed
            lane[req.priority] = lane.get(req.priority, 0) + 1
        late_ms = (now_ns + pred_ns - req.deadline_ns) / 1e6
        return DeadlineExceeded(
            f"shed op={req.op!r} lane={req.priority}: predicted completion "
            f"{late_ms:.2f} ms past deadline")

    def _note_late(self, req: Request) -> None:
        """Count a completion that landed past its deadline (ran anyway)."""
        if req.deadline_ns and time.perf_counter_ns() > req.deadline_ns:
            with self._slock:
                self.stats.deadline_miss += 1

    def _maybe_shed(self, req: Request) -> bool:
        """Shed a request the service model predicts past its deadline.

        Called at pop time (batch formation), where queueing delay has
        already consumed part of the budget.  A shed is never silent: the
        lease is released, ``stats.shed`` counted, and the submitter gets
        an immediate :class:`DeadlineExceeded` completion instead of a
        batch slot."""
        err = self._shed_verdict(req)
        if err is None:
            return False
        req._release_lease()
        self._complete(req, err)
        return True

    # -- server loop -----------------------------------------------------------
    def _serve_loop(self) -> None:
        while self._running:
            try:
                req = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if req is None:
                break
            if self._maybe_shed(req):
                continue
            if req.mode == ExecutionMode.PIPELINED:
                t0 = _trace.now() if _trace.TRACE.enabled else 0
                c0 = _hw.begin() if _hw.PROF.enabled else None

                def same_lane(r, _op=req.op, _prio=req.priority):
                    return (r.op == _op and r.priority == _prio
                            and r.mode == ExecutionMode.PIPELINED)

                batch = [req]
                deadline = time.perf_counter() + self._max_wait
                while len(batch) < self.policy.max_batch:
                    remain = deadline - time.perf_counter()
                    if remain <= 0:
                        break
                    try:
                        # lane-ordered batch fill: only pop while the queue
                        # front matches this batch's (op, lane); a more
                        # urgent arrival closes the window instead of being
                        # reordered behind it (it stays at the front for
                        # the next iteration)
                        nxt = self._q.get(timeout=remain, match=same_lane)
                    except queue.Empty:
                        break
                    if nxt is None:
                        self._running = False
                        break
                    if self._maybe_shed(nxt):
                        continue
                    batch.append(nxt)
                if t0:      # the batch-formation window wait, per batch
                    _trace.emit(_trace.DISPATCH_WAIT, t0, rid=batch[0].rid,
                                arg=len(batch))
                if c0 is not None:
                    _hw.end(c0, "batch_wait", rid=batch[0].rid)
                self._execute(batch)
            else:
                self._execute([req])

    # -- batch formation: slot views → pooled batch buffer ---------------------
    #: ceiling on one pooled gather slab: with the bulk-heap datapath a
    #: "row" can be hundreds of MB, and padding every row of a batch to the
    #: largest one would multiply that by max_batch — beyond this the batch
    #: falls back to per-row handling on the leased views (still zero
    #: receive copies; just no slab)
    GATHER_SLAB_MAX_BYTES = 256 << 20

    def _gatherable(self, batch: list[Request]) -> bool:
        datas = [r.data for r in batch]
        if not (all(isinstance(d, np.ndarray) and d.ndim >= 1 for d in datas)
                and len({d.dtype for d in datas}) == 1
                and len({d.ndim for d in datas}) == 1):
            return False
        ndim = datas[0].ndim
        maxdims = tuple(max(d.shape[k] for d in datas) for k in range(ndim))
        slab_bytes = (len(datas) * int(np.prod(maxdims))
                      * datas[0].dtype.itemsize)
        return slab_bytes <= self.GATHER_SLAB_MAX_BYTES

    def _gather(self, batch: list[Request]):
        """One SG gather per batch: copy every request's payload view into
        a pooled slab (THE server-side payload memcpy), zero the padding,
        then release every lease — the slots recycle before the handler
        runs.  Returns ``(slab, shapes, rows)``."""
        t0 = _trace.now() if _trace.TRACE.enabled else 0
        c0 = _hw.begin() if _hw.PROF.enabled else None
        datas = [r.data for r in batch]
        ndim = datas[0].ndim
        maxdims = tuple(max(d.shape[k] for d in datas) for k in range(ndim))
        slab = self._pool.acquire((len(batch),) + maxdims, datas[0].dtype)
        sg = SGList()
        rows = []
        for i, d in enumerate(datas):
            if d.shape != maxdims:
                slab[i].fill(0)          # pad region (memset, not a copy)
            dst = slab[i][tuple(slice(0, s) for s in d.shape)]
            sg.add_array(d, dst)
            rows.append(dst)
        get_engine().run_sg(sg, injection=self.policy.injection_enabled(),
                            tag="gather")
        with self._slock:
            self.stats.gathers += 1
            self.stats.gathered_requests += len(batch)
        for r in batch:
            r._release_lease()           # released right after the gather
        if t0:
            _trace.emit(_trace.GATHER, t0, rid=batch[0].rid, arg=len(batch))
        if c0 is not None:
            _hw.end(c0, "sg_gather", rid=batch[0].rid,
                    nbytes=sum(d.nbytes for d in datas))
        return slab, [d.shape for d in datas], rows

    def _recycle_slab(self, slab: np.ndarray, results: Sequence) -> None:
        # a handler may legally return views into the slab (echo-style);
        # recycling it would let the next batch overwrite live results, so
        # only pooled-reuse when nothing aliases it
        for out in results:
            if isinstance(out, np.ndarray) and np.may_share_memory(out, slab):
                return
        self._pool.release(slab)

    def _execute(self, batch: list[Request]) -> None:
        if not batch:
            return
        op = batch[0].op
        br = self._breaker(op)
        if br is not None and not br.admit():
            # quarantined op: fast-fail the whole batch with error replies
            # instead of invoking the handler — leases still released
            err = CircuitOpen(f"op {op!r} quarantined (circuit open)")
            with self._slock:
                self.stats.breaker_fast_fails += len(batch)
            for r in batch:
                r._release_lease()
                self._complete(r, err)
            return
        with self._slock:
            self.stats.batches += 1
            self.stats.batched_requests += len(batch)
            self.stats.mean_batch = (self.stats.batched_requests
                                     / self.stats.batches)
        t_exec = time.perf_counter()
        sfn = self._slab_handlers.get(op)
        bfn = self._batch_handlers.get(op)
        leased = any(r.lease is not None for r in batch)
        pipelined = batch[0].mode == ExecutionMode.PIPELINED
        slab = None
        t0 = _trace.now() if _trace.TRACE.enabled else 0
        c0 = _hw.begin() if _hw.PROF.enabled else None
        # errors are contained per request: a failing handler completes its
        # job(s) with the exception instead of killing the worker loop
        try:
            if (pipelined and (sfn is not None or bfn is not None)
                    and (leased or sfn is not None)
                    and self._gatherable(batch)):
                try:
                    slab, shapes, rows = self._gather(batch)
                    if sfn is not None:
                        self.stats.slab_batches += 1
                        results = self._call_handler(sfn, slab, shapes)
                    else:
                        results = self._call_handler(bfn, rows)
                    if len(results) != len(batch):
                        # surface the handler bug now — zip truncation would
                        # leave the tail requests uncompleted forever
                        raise RuntimeError(
                            f"batch handler for {op!r} returned "
                            f"{len(results)} results for {len(batch)} "
                            f"requests")
                    self._breaker_note(br, True)
                except Exception as e:
                    results = [e] * len(batch)
                    self._breaker_note(br, False)
            elif bfn is not None and len(batch) > 1:
                try:
                    results = self._call_handler(
                        bfn, [r.data for r in batch])
                    if len(results) != len(batch):
                        raise RuntimeError(
                            f"batch handler for {op!r} returned "
                            f"{len(results)} results for {len(batch)} "
                            f"requests")
                    self._breaker_note(br, True)
                except Exception as e:
                    results = [e] * len(batch)
                    self._breaker_note(br, False)
            else:
                # solo path: each call is its own handler invocation, so
                # each feeds the breaker individually (a batch counts once)
                results = []
                for r in batch:
                    try:
                        results.append(
                            self._call_handler(self._handlers[op], r.data))
                        self._breaker_note(br, True)
                    except Exception as e:
                        results.append(e)
                        self._breaker_note(br, False)
            if t0:      # batch compute: gather (nested sub-span) + handler
                _trace.emit(_trace.HANDLER, t0, rid=batch[0].rid,
                            arg=len(batch))
            if c0 is not None:
                # like the HANDLER span, this contains sg_gather as a
                # nested sub-scope; handler-only = handler − sg_gather
                _hw.end(c0, "handler", rid=batch[0].rid,
                        nbytes=sum(r.nbytes for r in batch))
            # feed the admission predictor with each request's share of
            # the batch wall time, and count completions that nonetheless
            # landed past their deadline (miss ≠ shed: the work ran)
            share_s = (time.perf_counter() - t_exec) / len(batch)
            self.service.observe(op, share_s)
            now_ns = time.perf_counter_ns()
            late = sum(1 for r in batch
                       if r.deadline_ns and now_ns > r.deadline_ns)
            if late:
                with self._slock:
                    self.stats.deadline_miss += late
            for r, out in zip(batch, results):
                # a query-path result computed from a still-leased view (or
                # the recyclable slab) must not alias memory about to be
                # reused — copy it out before the lease/slab goes away
                if (r.callback is None and isinstance(out, np.ndarray)
                        and r.lease is not None and isinstance(r.data,
                                                               np.ndarray)
                        and np.may_share_memory(out, r.data)):
                    out = np.array(out)
                self._complete(r, out)
        finally:
            # solo/fallback paths executed on the leased views directly:
            # release only now, after replies/results are materialized
            for r in batch:
                r._release_lease()
            if slab is not None:
                self._recycle_slab(slab, results)

    def _complete(self, req: Request, out: Any) -> None:
        if req.callback is not None:
            try:
                req.callback(req.job_id, out)
            except Exception:
                # reply path failed (e.g. client transport already gone);
                # the job is still settled — don't kill the worker loop
                pass
        else:
            self.queries.complete(
                req.job_id, _Failure(out) if isinstance(out, Exception)
                else out)

    # -- state replication (warm-standby failover) ------------------------------
    def export_state(self) -> dict:
        """The dispatcher's fast-moving replicable state: settled dedup
        entries (exactly-once across promotion), per-op breaker states,
        and the service-time EWMAs that drive deadline shedding.  This is
        the "delta log" a warm standby pulls between full snapshots —
        small (no params), picklable, and refreshed on every pull."""
        return {
            "dedup": self._dedup.export(),
            "breakers": {op: br.export()
                         for op, br in self._breakers.items()},
            "service": dict(self.service._per_op),
        }

    def import_state(self, state: dict) -> dict:
        """Adopt a peer dispatcher's :meth:`export_state`; returns counts
        of what landed (``dedup_entries``/``breakers``/``service_ops``)."""
        n_dedup = self._dedup.import_entries(state.get("dedup", []))
        breakers = state.get("breakers", {})
        for op, st in breakers.items():
            br = self._breaker(op)
            if br is not None:
                br.import_state(st)
        service = state.get("service", {})
        self.service._per_op.update(service)
        return {"dedup_entries": n_dedup, "breakers": len(breakers),
                "service_ops": len(service)}

    def close(self) -> None:
        self._running = False
        for _ in self._workers:
            self._q.put(None)            # one stop sentinel per worker
        for w in self._workers:
            w.join(timeout=self.policy.retry.join_timeout_s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
