"""Serving-side ROCKET runtime: request dispatcher, handlers, query handler.

Mirrors the paper's server architecture (Fig. 7 / Listing 1):

- clients call ``request(mode=..., op=..., data=...)`` -> job id (or a
  blocking result in sync mode);
- a :class:`RequestDispatcher` routes messages to registered per-op
  handlers; in pipelined mode requests are *batched* (application-level
  request batching, §IV-C) before the handler runs;
- a :class:`QueryHandler` tracks completions; ``query(job_id)`` applies the
  hybrid polling strategy (size-aware deferral + short passive waits).
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy


@dataclass
class Request:
    job_id: int
    op: str
    data: Any
    mode: ExecutionMode
    submit_t: float = field(default_factory=time.perf_counter)
    nbytes: int = 0


@dataclass
class DispatcherStats:
    requests: int = 0
    batches: int = 0
    batched_requests: int = 0
    queries: int = 0
    query_polls: int = 0
    mean_batch: float = 0.0


class QueryHandler:
    """Completion tracking + hybrid polling for result queries."""

    def __init__(self, latency: LatencyModel, policy: OffloadPolicy):
        self._results: dict[int, Any] = {}
        self._events: dict[int, threading.Event] = {}
        self._meta: dict[int, Request] = {}
        self._lock = threading.Lock()
        self.latency = latency
        self.policy = policy
        self.polls = 0

    def register(self, req: Request) -> None:
        with self._lock:
            self._events[req.job_id] = threading.Event()
            self._meta[req.job_id] = req

    def complete(self, job_id: int, result: Any) -> None:
        with self._lock:
            self._results[job_id] = result
            ev = self._events.get(job_id)
        if ev is not None:
            ev.set()

    def query(self, job_id: int, timeout: float = 60.0) -> Any:
        with self._lock:
            ev = self._events.get(job_id)
            req = self._meta.get(job_id)
        if ev is None:
            raise KeyError(f"unknown job {job_id}")
        if not ev.is_set() and req is not None:
            # size-aware deferral before polling (remaining predicted latency)
            pred = self.latency.defer_seconds(req.nbytes, self.policy.defer_fraction)
            remain = pred - (time.perf_counter() - req.submit_t)
            if remain > 0:
                time.sleep(min(remain, timeout))
        deadline = time.perf_counter() + timeout
        quantum = self.policy.poll_interval_us * 1e-6
        while not ev.is_set():
            self.polls += 1
            if time.perf_counter() > deadline:
                raise TimeoutError(f"job {job_id} timed out")
            ev.wait(quantum)
        with self._lock:
            out = self._results.pop(job_id)
            self._events.pop(job_id, None)
            self._meta.pop(job_id, None)
        return out


class RequestDispatcher:
    """Routes requests to registered handlers; batches in pipelined mode."""

    def __init__(self, policy: OffloadPolicy = OffloadPolicy(),
                 latency: Optional[LatencyModel] = None,
                 max_batch_wait_s: float = 0.002):
        self.policy = policy
        self.latency = latency or LatencyModel()
        self.queries = QueryHandler(self.latency, policy)
        self.stats = DispatcherStats()
        self._handlers: dict[str, Callable] = {}
        self._batch_handlers: dict[str, Callable] = {}
        self._q: "queue.Queue[Optional[Request]]" = queue.Queue()
        self._ids = itertools.count()
        self._max_wait = max_batch_wait_s
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._running = True
        self._worker.start()

    # -- handler registration (paper: workload-specific handlers) ------------
    def register_handler(self, op: str, fn: Callable,
                         batch_fn: Optional[Callable] = None) -> None:
        """``fn(data) -> result``; optional ``batch_fn(list[data]) -> list``."""
        self._handlers[op] = fn
        if batch_fn is not None:
            self._batch_handlers[op] = batch_fn

    # -- client API (paper Listing 1) -----------------------------------------
    def request(self, op: str, data: Any,
                mode: ExecutionMode | str | None = None) -> int | Any:
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        req = Request(next(self._ids), op, data, mode,
                      nbytes=int(np.asarray(data).nbytes)
                      if isinstance(data, np.ndarray) else 0)
        self.stats.requests += 1
        if mode == ExecutionMode.SYNC:
            return self._handlers[op](data)
        self.queries.register(req)
        self._q.put(req)
        return req.job_id

    def query(self, job_id: int, timeout: float = 60.0) -> Any:
        self.stats.queries += 1
        out = self.queries.query(job_id, timeout)
        self.stats.query_polls = self.queries.polls
        return out

    # -- server loop -----------------------------------------------------------
    def _serve_loop(self) -> None:
        while self._running:
            try:
                req = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if req is None:
                break
            if req.mode == ExecutionMode.PIPELINED:
                batch = [req]
                deadline = time.perf_counter() + self._max_wait
                while len(batch) < self.policy.max_batch:
                    remain = deadline - time.perf_counter()
                    if remain <= 0:
                        break
                    try:
                        nxt = self._q.get(timeout=remain)
                    except queue.Empty:
                        break
                    if nxt is None:
                        self._running = False
                        break
                    if nxt.op != req.op or nxt.mode != ExecutionMode.PIPELINED:
                        self._execute([nxt])
                        continue
                    batch.append(nxt)
                self._execute(batch)
            else:
                self._execute([req])

    def _execute(self, batch: list[Request]) -> None:
        if not batch:
            return
        op = batch[0].op
        self.stats.batches += 1
        self.stats.batched_requests += len(batch)
        self.stats.mean_batch = self.stats.batched_requests / self.stats.batches
        bfn = self._batch_handlers.get(op)
        if bfn is not None and len(batch) > 1:
            results = bfn([r.data for r in batch])
        else:
            results = [self._handlers[op](r.data) for r in batch]
        for r, out in zip(batch, results):
            self.queries.complete(r.job_id, out)

    def close(self) -> None:
        self._running = False
        self._q.put(None)
        self._worker.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
