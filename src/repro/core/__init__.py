"""ROCKET core: the paper's contribution as a composable runtime.

- :mod:`repro.core.policy`    — execution modes / offload control / injection
- :mod:`repro.core.latency`   — size-aware latency model + calibration
- :mod:`repro.core.copyengine`— process-wide software-DSA copy engine
  (SG descriptors, work queues, batched doorbells, completion records)
- :mod:`repro.core.engine`    — tier-1 async transfer engine (host→device)
- :mod:`repro.core.queuepair` — persistent buffer pools / queue pairs
- :mod:`repro.core.dispatcher`— serving request dispatcher / query handler
"""
from repro.core.policy import (
    ASYNC_OFFLOAD,
    Device,
    ExecutionMode,
    OffloadPolicy,
    PIPELINED_OFFLOAD,
    SYNC_INLINE,
    SYNC_OFFLOAD,
)
from repro.core.governor import ChannelGovernor, GovernorStats, size_class
from repro.core.latency import LatencyModel, calibrate
from repro.core.copyengine import (
    CopyEngine,
    CopyJob,
    Descriptor,
    HybridPollStats,
    SGList,
    get_engine,
    set_engine,
)
from repro.core.engine import AsyncTransferEngine, EngineStats, TransferJob
from repro.core.queuepair import BufferPool, QueuePair
from repro.core.dispatcher import QueryHandler, RequestDispatcher

__all__ = [
    "ASYNC_OFFLOAD", "AsyncTransferEngine", "BufferPool", "ChannelGovernor",
    "CopyEngine", "CopyJob", "Descriptor", "Device", "EngineStats",
    "ExecutionMode", "GovernorStats", "HybridPollStats", "LatencyModel",
    "OffloadPolicy", "PIPELINED_OFFLOAD", "QueryHandler", "QueuePair",
    "RequestDispatcher", "SGList", "SYNC_INLINE", "SYNC_OFFLOAD",
    "TransferJob", "calibrate", "get_engine", "set_engine", "size_class",
]
