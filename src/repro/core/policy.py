"""ROCKET offload policy: execution modes, offload control, cache injection.

Direct transcription of the paper's configuration surface (§IV-B):

- ``mode``  ∈ {sync, async, pipelined} — synchronization/overlap strategy;
- ``device`` ∈ {inline, offload} — the paper's {cpu, dsa} knob; ``inline``
  keeps the movement on the compute stream, ``offload`` delegates it to the
  async engine (host thread-pool / TPU DMA, tier-dependent);
- ``cache_injection`` — the paper's LLC-injection knob; on TPU this is VMEM
  residency (kernels) / device-buffer pinning (tier 1).  ``None`` applies the
  paper's mode-specific default: on for sync, conditional for async
  (single-client only), off for pipelined (Table III, §V).
- ``offload_threshold_bytes`` — size-based offload control (Table III "Data
  Size"): transfers below the threshold stay inline.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Optional


class ExecutionMode(str, enum.Enum):
    SYNC = "sync"
    ASYNC = "async"
    PIPELINED = "pipelined"


class Device(str, enum.Enum):
    INLINE = "inline"      # paper: cpu memcpy
    OFFLOAD = "offload"    # paper: dsa engine


@dataclass(frozen=True)
class RetryPolicy:
    """Every timeout, retry, and liveness deadline in one place.

    Before this existed, ``worker.py``/``reactor.py`` carried the same
    four magic numbers (2.0/5.0/10.0/60.0 s) as scattered keyword
    defaults; heartbeat staleness and reconnect backoff would have become
    two more.  All of them are *policy*, so they live on
    ``OffloadPolicy.retry`` and are tuned in one place.
    """
    # -- request/reply deadlines ------------------------------------------
    reply_timeout_s: float = 5.0        # server-side reply publish
    query_timeout_s: float = 60.0       # client-side completion wait
    connect_timeout_s: float = 30.0     # listener rendezvous + arena attach
    # -- shutdown deadlines -----------------------------------------------
    shutdown_send_timeout_s: float = 2.0   # best-effort control sends at close
    join_timeout_s: float = 10.0        # process/thread join at stop()
    linger_timeout_s: float = 30.0      # producer drain-then-exit deadline
    recv_poll_s: float = 0.05           # serve-loop blocking-recv quantum
    # -- client reconnect/backoff (ft plane) ------------------------------
    max_reconnects: int = 4             # bounded: give up after this many
    backoff_initial_s: float = 0.05     # first retry delay, doubled per try
    backoff_max_s: float = 1.0          # backoff ceiling
    # -- liveness (heartbeat words, transport control words 12/13) --------
    heartbeat_interval_s: float = 0.2   # min gap between stamps per side
    heartbeat_stale_s: float = 2.0      # no stamp for this long => peer dead
    # -- server-side exactly-once dedup window (replayed requests) --------
    dedup_window: int = 1024            # cached reply ids per fabric

    def backoff_s(self, attempt: int) -> float:
        """Delay before reconnect ``attempt`` (0-based): doubling from
        ``backoff_initial_s`` capped at ``backoff_max_s``."""
        return min(self.backoff_initial_s * (2.0 ** attempt),
                   self.backoff_max_s)


@dataclass(frozen=True)
class OffloadPolicy:
    mode: ExecutionMode = ExecutionMode.PIPELINED
    device: Device = Device.OFFLOAD
    cache_injection: Optional[bool] = None
    offload_threshold_bytes: int = 1 << 20       # breakeven well above 4KB raw [23]
    pipeline_depth: int = 2                      # outstanding transfers (pipelined)
    max_batch: int = 8                           # request batching (pipelined)
    # hybrid polling (§IV-C): sleep defer_fraction*L, then short-interval poll
    defer_fraction: float = 0.95
    poll_interval_us: float = 25.0               # UMWAIT-quantum analogue
    # busy-yield window before the quantum sleeps: on kernels with coarse
    # timer granularity (sleep(25us) can cost ~1ms) a short spin keeps
    # streaming paths at memcpy speed while staying CPU-polite when idle
    spin_us: float = 200.0
    # single-copy serving datapath: the reactor receives requests as
    # zero-copy leases and the dispatcher gathers slot views straight into
    # pooled batch buffers (one payload memcpy per request server-side);
    # False restores the copy-out receive path (the pre-CopyEngine
    # behaviour, kept for fig13_copy_path A/B measurement)
    zero_copy_serving: bool = True
    # large-message datapath (ipc/heap.py): a payload >= this goes through
    # the connection's bulk heap instead of a ring slot whenever a heap is
    # attached (payloads larger than the slot *must*; smaller ones may,
    # keeping fat streams out of the slot arena).  The ring then carries
    # only the compact extent descriptor.
    heap_threshold_bytes: int = 8 << 20
    # chunk size for offloaded heap fills: async/pipelined sends split the
    # fill into chunk-sized SG submissions on the channel's work queue so
    # the copy of message k+1 overlaps the peer's drain of message k and a
    # single fat fill cannot monopolize an engine worker between doorbells
    heap_chunk_bytes: int = 8 << 20
    # small-message fast path (send coalescing): async/pipelined messages
    # at/below coalesce_bytes are packed into one ring slot as a microbatch
    # frame of up to coalesce_max sub-messages (FLAG_COALESCED), amortizing
    # slot claim, meta encode, and doorbell K-ways.  0 disables the static
    # path; the adaptive governor may still coalesce (it uses
    # coalesce_limit_bytes() as the structural cap).  A partially filled
    # frame is flushed by the next non-coalesced send, an explicit
    # flush()/handle.wait(), or the first send after coalesce_window_us.
    coalesce_bytes: int = 0
    coalesce_max: int = 8
    coalesce_window_us: float = 200.0
    # wire-meta integrity: when True every published slot carries a CRC32
    # of its meta bytes in slot-header word 5 (FLAG_CRC) and the receiver
    # verifies before decode — a corrupt slot is quarantined as a counted
    # ``corrupt_drops`` skip instead of crashing the drain loop on an
    # unpicklable/undecodable header
    meta_checksum: bool = False
    # consolidated timeout/retry/liveness deadlines (heartbeats, reconnect
    # backoff, reply/shutdown timeouts) — see RetryPolicy
    retry: RetryPolicy = RetryPolicy()
    # per-message strategy selection: "static" keeps the threshold
    # constants above; "adaptive" installs a core.governor.ChannelGovernor
    # per channel that picks inline/offload/coalesce/heap from measured
    # per-size-class cost EWMAs and queue occupancy (the paper's hybrid
    # coordination as a feedback loop — Table III learned, not hardcoded)
    governor: str = "static"

    def coalesce_limit_bytes(self) -> int:
        """Structural coalescing cap: the static knob when set, else the
        128 KB default the adaptive governor explores under.  Coalescing
        amortizes *fixed* control-plane cost; past ~128 KB the payload
        copy dominates and batching K copies behind one publish only
        coarsens pipelining granularity (the consumer idles while a
        multi-MB frame fills), so the governor does not explore there."""
        return self.coalesce_bytes if self.coalesce_bytes > 0 else 128 << 10

    def should_offload(self, nbytes: int) -> bool:
        if self.device == Device.INLINE:
            return False
        return nbytes >= self.offload_threshold_bytes

    def injection_enabled(self, concurrency: int = 1) -> bool:
        """Paper's default injection policy (Table III / §V):
        sync -> on; async -> on iff single-threaded; pipelined -> off."""
        if self.cache_injection is not None:
            return self.cache_injection
        if self.mode == ExecutionMode.SYNC:
            return True
        if self.mode == ExecutionMode.ASYNC:
            return concurrency <= 1
        return False

    def with_mode(self, mode: ExecutionMode | str) -> "OffloadPolicy":
        return replace(self, mode=ExecutionMode(mode))

    def with_device(self, device: Device | str) -> "OffloadPolicy":
        return replace(self, device=Device(device))


SYNC_INLINE = OffloadPolicy(mode=ExecutionMode.SYNC, device=Device.INLINE)
SYNC_OFFLOAD = OffloadPolicy(mode=ExecutionMode.SYNC, device=Device.OFFLOAD)
ASYNC_OFFLOAD = OffloadPolicy(mode=ExecutionMode.ASYNC, device=Device.OFFLOAD)
PIPELINED_OFFLOAD = OffloadPolicy(mode=ExecutionMode.PIPELINED, device=Device.OFFLOAD)
