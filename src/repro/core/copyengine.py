"""Process-wide software-DSA copy engine (§IV "unified runtime capability").

The paper's central design point is that memory-operation offloading only
pays off when *one* engine coordinates submission, completion, and cache
visibility for every IPC path.  This module is that engine for the repro:
a single per-process :class:`CopyEngine` that the tier-1 transfer engine
(:mod:`repro.core.engine`), every IPC :class:`~repro.ipc.channel.DataChannel`,
and the serving dispatcher's batch-gather all submit to.  It models the DSA
hardware interface faithfully:

- **scatter-gather descriptors** — one :class:`Descriptor` per pytree
  submission carrying an :class:`SGList` of per-leaf copy entries (one
  submission per tree, *not* one task per leaf);
- **work queues** — submissions name a ``wq`` key; descriptors on the same
  key execute serially in FIFO order (a dedicated WQ), distinct keys run
  concurrently on the worker pool (shared engines behind the WQs), and a
  stalled queue never head-of-line blocks the others: a build that cannot
  proceed (full ring) raises :class:`WouldBlock` and the engine *parks*
  that queue with a retry deadline instead of letting the worker wait
  inside it;
- **batched doorbells** — a submitter only "rings" (condition notify) when
  its queue goes non-empty; submissions that land while the engine is
  already busy piggyback on the outstanding doorbell
  (``stats.submitted - stats.doorbells`` = doorbells saved by batching);
- **completion records** — every submission returns a :class:`CopyJob`
  whose ``wait()`` applies the repo-wide hybrid polling (size-aware
  deferral from the calibrated latency model, then short passive waits);
- **cache-injection hint** — per-descriptor ``injection`` tags the copy
  *temporal* (the paper's LLC-injection path: the consumer finds the
  bytes warm) or *streaming* (data not re-read soon; on hardware this
  would use non-temporal stores).  numpy exposes no non-temporal store,
  so the hint drives the per-kind counters the benchmarks read rather
  than a different copy loop (see ``_copy_entry`` for why a chunked
  Python-level "streaming" loop is actively harmful under the GIL).
  The default follows
  :meth:`repro.core.policy.OffloadPolicy.injection_enabled`.

Every memcpy the runtime performs on a datapath — engine staging, channel
sends, receive-side unpack copies, dispatcher batch gathers, reply slot
fills — is executed or at least *counted* here, tagged by path, which is
what makes copies-per-request a counted (not timed) regression metric
(see ``benchmarks/fig13_copy_path.py`` and ``tests/test_copy_path.py``).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.core.latency import LatencyModel
from repro.core.policy import OffloadPolicy
from repro.obs import trace as _trace



# ---------------------------------------------------------------------------
# shared stats (deduplicates the old EngineStats/ChannelStats copy-paste)
# ---------------------------------------------------------------------------

@dataclass
class HybridPollStats:
    """Hybrid-polling + offload-split counters shared by every movement
    path (tier-1 engine, IPC channels, copy-engine jobs): one dataclass
    instead of per-layer copy-pasted fields."""
    inline: int = 0              # below-threshold/sync work done by the caller
    offloaded: int = 0           # submissions delegated to an engine thread
    polls: int = 0               # completion-flag checks after deferral
    deferred_sleep_s: float = 0.0   # predicted-latency sleeps (hidden time)
    blocked_wait_s: float = 0.0     # residual synchronous waiting

    def snapshot(self) -> dict:
        """A plain-dict copy (for logging/benchmark rows)."""
        return dict(self.__dict__)


@dataclass
class CopyEngineStats(HybridPollStats):
    """Engine-wide submission/copy/doorbell counters, plus per-tag copy
    and byte counts (``tagged``/``tagged_bytes``) for the counted
    copies-per-request metric."""
    submitted: int = 0           # descriptors submitted
    completed: int = 0
    failed: int = 0
    sg_entries: int = 0          # leaf copy entries across all descriptors
    copies: int = 0              # memcpys executed/accounted
    bytes_copied: int = 0
    temporal: int = 0            # cache-injected (plain copyto) copies
    streaming: int = 0           # chunked streaming copies
    doorbells: int = 0           # times a submitter actually rang
    wakeups: int = 0             # worker wakeups that found work
    parked: int = 0              # WouldBlock retries (stalled-queue backoff)
    tagged: dict = field(default_factory=lambda: defaultdict(int))
    tagged_bytes: dict = field(default_factory=lambda: defaultdict(int))
    # counted control-plane events (no timing): integrated paths report
    # e.g. coalesced frames/messages and per-send pickle calls here so
    # doorbells-per-message and pickle-calls-per-send are process-wide
    # counted metrics the CI gate can read, like copies-per-request
    events: dict = field(default_factory=lambda: defaultdict(int))

    def snapshot(self) -> dict:
        """Plain-dict copy with the tag maps materialized."""
        out = dict(self.__dict__)
        out["tagged"] = dict(self.tagged)
        out["tagged_bytes"] = dict(self.tagged_bytes)
        out["events"] = dict(self.events)
        return out


# ---------------------------------------------------------------------------
# scatter-gather descriptors
# ---------------------------------------------------------------------------

class WouldBlock(Exception):
    """Raised by ``Descriptor.build`` when its resource (typically a ring
    slot) is not available yet: the engine *parks* the work queue and
    retries after ``retry_after_s`` instead of letting a worker thread
    block inside the build — so a stalled channel (full ring, slow
    consumer) costs zero engine workers and can never head-of-line block
    the other datapaths."""

    def __init__(self, retry_after_s: float = 5e-4):
        super().__init__(f"resource not ready; retry in {retry_after_s}s")
        self.retry_after_s = retry_after_s


class SGEntry:
    """One leaf copy: contiguous ``src`` bytes into same-size ``dst``."""

    __slots__ = ("src", "dst", "nbytes")

    def __init__(self, src: np.ndarray, dst: np.ndarray, nbytes: int):
        self.src = src
        self.dst = dst
        self.nbytes = nbytes


class SGList:
    """A scatter-gather list: the copy entries of one descriptor, plus a
    free-form ``ctx`` slot the prologue can use to pass state (a slot
    writer, a staged tree) to the completion callback."""

    __slots__ = ("entries", "nbytes", "ctx")

    def __init__(self):
        self.entries: list[SGEntry] = []
        self.nbytes = 0
        self.ctx: Any = None

    def add(self, src, dst) -> None:
        """Append one entry; ``src`` is flattened to a contiguous u8 view,
        ``dst`` may be an ndarray or a writable buffer slice."""
        src = np.asarray(src)
        if not src.flags["C_CONTIGUOUS"]:
            src = np.ascontiguousarray(src)
        self.entries.append(SGEntry(src, dst, src.nbytes))
        self.nbytes += src.nbytes

    def add_array(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Append a same-shape array→array entry (no flattening), for
        gathers into typed batch-buffer slices."""
        self.entries.append(SGEntry(src, dst, np.asarray(src).nbytes))
        self.nbytes += np.asarray(src).nbytes

    def __len__(self) -> int:
        return len(self.entries)


def split_sg(sg: SGList, chunk_bytes: int) -> list[SGList]:
    """Split one SG list into <= ``chunk_bytes`` chunks for pipelined
    submission (the heap fill path: each chunk is its own descriptor on
    the same work queue, so FIFO holds while completion granularity and
    doorbell batching stay fine-grained on multi-hundred-MB payloads).

    Entries must be flat same-length uint8 views (how the heap fill builds
    them); a logical copy split across chunks is *accounted* once by the
    submitter via ``count_copies``, not once per chunk.
    """
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    chunks: list[SGList] = [SGList()]
    for e in sg.entries:
        if e.src.dtype != np.uint8 or e.src.ndim != 1:
            raise ValueError("split_sg requires flat uint8 entries")
        off = 0
        while off < e.nbytes:
            cur = chunks[-1]
            take = min(chunk_bytes - cur.nbytes, e.nbytes - off)
            if take <= 0:
                chunks.append(SGList())
                continue
            cur.entries.append(SGEntry(e.src[off:off + take],
                                       e.dst[off:off + take], take))
            cur.nbytes += take
            off += take
    return [c for c in chunks if c.entries]


class Descriptor:
    """One submission: an SG list (given up front or built late by
    ``build`` on the engine thread — e.g. after a blocking slot acquire),
    an optional ``complete`` callback (publish/doorbell; its return value
    becomes the job result), an ``injection`` hint, and a path ``tag``."""

    __slots__ = ("sg", "build", "complete", "nbytes", "injection", "tag",
                 "count_copies")

    def __init__(self, sg: Optional[SGList] = None,
                 build: Optional[Callable[[], Optional[SGList]]] = None,
                 complete: Optional[Callable[[Optional[SGList]], Any]] = None,
                 nbytes: int = 0, injection: Optional[bool] = None,
                 tag: str = "copy", count_copies: Optional[int] = None):
        self.sg = sg
        self.build = build
        self.complete = complete
        self.nbytes = nbytes
        self.injection = injection
        self.tag = tag
        # logical copies this descriptor represents (default: one per SG
        # entry).  Chunked submissions — one leaf split over many entries/
        # descriptors — pass the leaf count here so copies-per-request
        # stays a *logical* counted metric (bytes stay exact either way).
        self.count_copies = count_copies


# ---------------------------------------------------------------------------
# completion records
# ---------------------------------------------------------------------------

class CopyJob:
    """Completion record for one descriptor (the paper's completion flag +
    job id); ``wait()`` is the hybrid-polling check shared by the tier-1
    engine's :class:`~repro.core.engine.TransferJob` and the channels'
    :class:`~repro.ipc.channel.SendHandle`."""

    _ids = itertools.count()

    def __init__(self, nbytes: int, policy: OffloadPolicy,
                 latency: LatencyModel,
                 stats: Optional[HybridPollStats] = None):
        self.job_id = next(self._ids)
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self.finished_t: Optional[float] = None
        self._policy = policy
        self._latency = latency
        self._stats = stats
        self._event = threading.Event()
        self._value: Any = None
        self._exc: Optional[BaseException] = None

    # -- engine side ----------------------------------------------------------
    def _finish(self, value: Any) -> None:
        self._value = value
        # completion-record timestamp: submit_t..finished_t is the
        # submitter-visible cost of the offloaded route (queue wait + copy
        # + publish), the feedback the adaptive governor learns from —
        # no extra clock reads on the submitter's hot path
        self.finished_t = time.perf_counter()
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self.finished_t = time.perf_counter()
        self._event.set()

    # -- submitter side -------------------------------------------------------
    def done(self) -> bool:
        """True once the engine posted the completion record (never blocks)."""
        return self._event.is_set()

    def failed(self) -> bool:
        """True when the descriptor completed with an exception."""
        return self._event.is_set() and self._exc is not None

    def wait(self, timeout_s: float = 30.0) -> Any:
        """Hybrid-polling completion: size-aware deferral (sleep most of
        the predicted copy latency), a short yield-only spin, then passive
        ``poll_interval_us`` waits; raises the descriptor's exception or
        ``TimeoutError``."""
        if not self._event.is_set():
            stats = self._stats
            pol, lat = self._policy, self._latency
            if self.nbytes > 0:
                pred = lat.defer_seconds(self.nbytes, pol.defer_fraction)
                remain = pred - (time.perf_counter() - self.submit_t)
                if remain > 0:
                    remain = min(remain, timeout_s)
                    time.sleep(remain)
                    if stats is not None:
                        stats.deferred_sleep_s += remain
            t0 = time.perf_counter()
            deadline = t0 + timeout_s
            spin_deadline = t0 + pol.spin_us * 1e-6
            while not self._event.is_set():          # spin phase
                if stats is not None:
                    stats.polls += 1
                if time.perf_counter() >= spin_deadline:
                    break
                time.sleep(0)
            quantum = pol.poll_interval_us * 1e-6
            while not self._event.is_set():          # quantum phase (UMWAIT)
                if stats is not None:
                    stats.polls += 1
                if time.perf_counter() > deadline:
                    if stats is not None:
                        stats.blocked_wait_s += time.perf_counter() - t0
                    raise TimeoutError(
                        f"copy job {self.job_id} not complete in {timeout_s}s")
                self._event.wait(quantum)
            if stats is not None:
                stats.blocked_wait_s += time.perf_counter() - t0
        if self._exc is not None:
            raise self._exc
        return self._value


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class CopyEngine:
    """Process-wide copy engine: work queues + worker pool + completion
    records.  Construct directly for tests; production code shares one
    instance via :func:`get_engine`."""

    def __init__(self, policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None, workers: int = 4):
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = CopyEngineStats()
        self._queues: dict = {}            # wq key -> deque[(descr, job)]
        self._ready: deque = deque()       # keys with work, no active worker
        self._parked: dict = {}            # wq key -> retry-not-before time
        self._active: set = set()
        self._cv = threading.Condition()
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"rocket-copyeng-{i}")
            for i in range(max(1, workers))
        ]
        for t in self._threads:
            t.start()

    # -- copy execution (also used inline via run_sg) -------------------------
    def _copy_entry(self, e: SGEntry, streaming: bool) -> None:
        # the injection hint selects *accounting* (temporal vs streaming
        # counters), not a different copy loop: numpy has no non-temporal
        # stores, and a Python-level chunk loop re-acquires the GIL between
        # chunks — with any other thread runnable (a client's receiver, the
        # reactor) each re-acquisition can wait out the 5 ms GIL switch
        # interval, turning a ~1 ms 4 MB copy into ~25 ms (measured).  One
        # copyto = one GIL release = full memcpy bandwidth.
        del streaming
        src, dst = e.src, e.dst
        if isinstance(dst, np.ndarray) and dst.shape == src.shape:
            np.copyto(dst, src)
        else:
            np.copyto(dst, src.reshape(-1).view(np.uint8))

    def run_sg(self, sg: SGList, injection: Optional[bool] = None,
               tag: str = "copy", count_copies: Optional[int] = None,
               account: bool = True) -> None:
        """Execute an SG list on the *caller's* thread (inline/below-
        threshold paths), with the same injection selection and counting
        as an offloaded descriptor.  ``count_copies`` overrides the
        logical copy count (chunked fills: one leaf, many entries).
        ``account=False`` skips the counter update — for per-message
        copies inside a coalesced frame, which the channel accounts once
        per frame via :meth:`count` (identical totals, one engine-lock
        round-trip instead of K on the small-message hot path)."""
        inject = (self.policy.injection_enabled() if injection is None
                  else injection)
        t0 = _trace.now() if _trace.TRACE.enabled else 0
        for e in sg.entries:
            self._copy_entry(e, streaming=not inject)
        if t0:
            _trace.emit(_trace.COPY_JOB, t0,
                        arg=min(sg.nbytes, 0xFFFFFFFF))
        if account:
            self._account(sg.entries, sg.nbytes, inject, tag, count_copies)

    def count_event(self, name: str, n: int = 1) -> None:
        """Count a control-plane event (frame published, message coalesced,
        meta pickle call) — the non-copy analogue of :meth:`count`, read by
        the benchmark gates as a timing-independent metric."""
        with self._cv:
            self.stats.events[name] += n

    def count(self, tag: str, copies: int, nbytes: int,
              injection: bool = True) -> None:
        """Account copies performed by an integrated path without routing
        the memcpy itself through the engine (e.g. ``recv(copy=True)``
        unpack copies) — keeps the copies-per-request metric complete."""
        with self._cv:
            self.stats.copies += copies
            self.stats.bytes_copied += nbytes
            if injection:
                self.stats.temporal += copies
            else:
                self.stats.streaming += copies
            self.stats.tagged[tag] += copies
            self.stats.tagged_bytes[tag] += nbytes

    def _account(self, entries, nbytes: int, inject: bool, tag: str,
                 count: Optional[int] = None) -> None:
        count = len(entries) if count is None else count
        with self._cv:
            self.stats.sg_entries += len(entries)
            self.stats.copies += count
            self.stats.bytes_copied += nbytes
            if inject:
                self.stats.temporal += count
            else:
                self.stats.streaming += count
            self.stats.tagged[tag] += count
            self.stats.tagged_bytes[tag] += nbytes

    # -- submission -----------------------------------------------------------
    def submit(self, descr: Descriptor, wq: Any = None,
               policy: Optional[OffloadPolicy] = None,
               latency: Optional[LatencyModel] = None,
               stats: Optional[HybridPollStats] = None) -> CopyJob:
        """Queue one descriptor (ENQCMD analogue) and return its completion
        record.  ``wq`` keys serialize: descriptors on the same key run
        FIFO; ``wq=None`` gives the descriptor a private key (unordered,
        maximally parallel).  ``policy``/``latency``/``stats`` configure
        the *submitter's* hybrid-polling wait and counters."""
        job = CopyJob(descr.nbytes, policy or self.policy,
                      latency or self.latency, stats)
        key = object() if wq is None else wq
        with self._cv:
            if self._stop:
                raise RuntimeError("CopyEngine is closed")
            self.stats.submitted += 1
            q = self._queues.get(key)
            if q is None:
                q = self._queues[key] = deque()
            q.append((descr, job))
            # batched doorbell: ring only when this key just became
            # runnable — work landing behind an outstanding doorbell (or an
            # active worker) piggybacks without a second ring
            if key not in self._active and len(q) == 1:
                self._ready.append(key)
                self.stats.doorbells += 1
                self._cv.notify()
        return job

    # -- worker loop ----------------------------------------------------------
    def _execute(self, descr: Descriptor, job: CopyJob) -> Optional[float]:
        """Run one descriptor; returns a retry delay when its build parked
        (WouldBlock), None when the job completed (either way)."""
        try:
            sg = descr.sg
            if descr.build is not None:
                built = descr.build()
                sg = built if sg is None else sg
            if sg is not None and len(sg):
                inject = (self.policy.injection_enabled()
                          if descr.injection is None else descr.injection)
                t0 = _trace.now() if _trace.TRACE.enabled else 0
                for e in sg.entries:
                    self._copy_entry(e, streaming=not inject)
                if t0:
                    _trace.emit(_trace.COPY_JOB, t0,
                                arg=min(sg.nbytes, 0xFFFFFFFF))
                self._account(sg.entries, sg.nbytes, inject, descr.tag,
                              descr.count_copies)
            value = descr.complete(sg) if descr.complete is not None else None
            with self._cv:
                self.stats.completed += 1
            job._finish(value)
        except WouldBlock as wb:                 # park: retry, don't block
            return wb.retry_after_s
        except BaseException as e:               # completion carries the error
            with self._cv:
                self.stats.failed += 1
            job._fail(e)
        # drop the descriptor's buffer exports now: an idle worker's loop
        # locals would otherwise pin shared-memory views (slot writers,
        # heap extents) until the next submission, turning transport close
        # into a BufferError
        descr.sg = descr.build = descr.complete = None
        return None

    def _pop_ready(self) -> Optional[tuple]:
        """Under the cv: next (key, descr, job) to run, unparking due keys;
        None when nothing is runnable (caller computes the wait)."""
        now = time.perf_counter()
        for key in [k for k, t in self._parked.items() if t <= now]:
            del self._parked[key]
            self._ready.append(key)
        if not self._ready:
            return None
        key = self._ready.popleft()
        self._active.add(key)
        descr, job = self._queues[key].popleft()
        self.stats.wakeups += 1
        return key, descr, job

    def _worker(self) -> None:
        while True:
            with self._cv:
                while True:
                    item = self._pop_ready()
                    if item is not None or self._stop:
                        break
                    wait = 0.1
                    if self._parked:
                        wait = min(wait, max(
                            1e-4, min(self._parked.values())
                            - time.perf_counter()))
                    self._cv.wait(wait)
                if item is None:                 # stopping, nothing runnable
                    if self._parked:             # fail parked work loudly
                        for key in list(self._parked):
                            del self._parked[key]
                            for descr, job in self._queues.pop(key, ()):
                                job._fail(RuntimeError(
                                    "CopyEngine closed while the submission "
                                    "waited for its resource"))
                        continue
                    return
                key, descr, job = item
            retry_after = self._execute(descr, job)
            with self._cv:
                self._active.discard(key)
                if retry_after is not None:      # parked: keep FIFO, back off
                    self._queues[key].appendleft((descr, job))
                    self._parked[key] = time.perf_counter() + retry_after
                    self.stats.parked += 1
                    self._cv.notify()            # sleepers recompute waits
                    continue
                q = self._queues.get(key)
                if q:
                    self._ready.append(key)
                    self._cv.notify()
                else:
                    self._queues.pop(key, None)

    # -- introspection / lifecycle --------------------------------------------
    def tagged_snapshot(self) -> dict:
        """Copy/byte counts per path tag (stable dict copies)."""
        with self._cv:
            return {"copies": dict(self.stats.tagged),
                    "bytes": dict(self.stats.tagged_bytes)}

    def queue_depth(self) -> int:
        """Descriptors queued but not yet picked up (all work queues)."""
        with self._cv:
            return sum(len(q) for q in self._queues.values())

    def close(self, timeout_s: float = 5.0) -> None:
        """Stop the workers after the queues drain (owned engines only —
        never call on the shared :func:`get_engine` instance mid-flight)."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout_s)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# the process-wide instance
# ---------------------------------------------------------------------------

_default: Optional[CopyEngine] = None
_default_lock = threading.Lock()


def get_engine() -> CopyEngine:
    """The process-wide engine every datapath shares (created lazily, so
    spawned children build their own on first use)."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = CopyEngine()
    return _default


def set_engine(engine: Optional[CopyEngine]) -> Optional[CopyEngine]:
    """Swap the process-wide engine (tests); returns the previous one."""
    global _default
    with _default_lock:
        prev, _default = _default, engine
    return prev
