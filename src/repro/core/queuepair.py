"""Persistent shared-buffer management (the paper's queue pairs, §IV-C).

ROCKET eliminates page faults by pre-mapping a fixed memory pool per client
connection and reusing it for every transfer.  The JAX analogues:

- :class:`BufferPool` — preallocated, reused host staging buffers (numpy),
  so the input pipeline never re-allocates per step (first-touch/remap cost
  is paid once);
- :class:`QueuePair` — a client's persistent tx/rx slot rings for the
  serving runtime (fixed shapes -> no recompilation, stable addresses);
- ``donate`` conventions — step-persistent device buffers (params, optimizer
  state, KV cache) are donated through jit so XLA reuses the allocation.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class PoolStats:
    hits: int = 0            # reused an existing buffer (pinned-path analogue)
    misses: int = 0          # had to allocate (page-fault-path analogue)
    released: int = 0

    @property
    def reuse_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class BufferPool:
    """Reusable host staging buffers keyed by (shape, dtype)."""

    def __init__(self, max_per_key: int = 8):
        self._free: dict = defaultdict(list)
        self._lock = threading.Lock()
        self._max = max_per_key
        self.stats = PoolStats()

    def acquire(self, shape, dtype) -> np.ndarray:
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            free = self._free[key]
            if free:
                self.stats.hits += 1
                return free.pop()
            self.stats.misses += 1
        buf = np.empty(shape, dtype)
        buf.fill(0)           # first-touch now (pre-mapping), not at use time
        return buf

    def release(self, buf: np.ndarray) -> None:
        key = (tuple(buf.shape), buf.dtype.str)
        with self._lock:
            if len(self._free[key]) < self._max:
                self._free[key].append(buf)
            self.stats.released += 1

    def preallocate(self, shape, dtype, count: int) -> None:
        """Pre-map the pool at connection setup (paper §IV-C)."""
        bufs = [self.acquire(shape, dtype) for _ in range(count)]
        with self._lock:
            self.stats.misses -= count       # setup cost is not a runtime miss
        for b in bufs:
            self.release(b)


def drain_to_depth(inflight, lock: threading.Lock, depth: int,
                   wait_fn) -> None:
    """Bounded-queue-pair backpressure: while more than ``depth`` jobs are
    in flight, pop the oldest under ``lock`` and block on it *outside* the
    lock, so concurrent submitters/drainers aren't serialized behind a full
    transfer latency.  Shared by the tier-1 engine and the IPC channels;
    ``inflight`` is a :class:`collections.deque` (O(1) popleft — the old
    ``list.pop(0)`` was O(n) per prune).
    """
    while True:
        with lock:
            if len(inflight) <= depth:
                return
            oldest = inflight.popleft()
        wait_fn(oldest)


@dataclass
class Slot:
    buf: np.ndarray
    seq: int = -1             # request sequence occupying the slot (-1 = free)


class QueuePair:
    """Persistent per-client tx/rx slot rings (RDMA-QP-inspired, §IV-C)."""

    def __init__(self, n_slots: int, tx_shape, rx_shape, dtype=np.float32):
        self.tx = [Slot(np.zeros(tx_shape, dtype)) for _ in range(n_slots)]
        self.rx = [Slot(np.zeros(rx_shape, dtype)) for _ in range(n_slots)]
        self._next = 0
        self._lock = threading.Lock()

    def acquire_tx(self, seq: int) -> Optional[Slot]:
        with self._lock:
            for _ in range(len(self.tx)):
                slot = self.tx[self._next]
                self._next = (self._next + 1) % len(self.tx)
                if slot.seq < 0:
                    slot.seq = seq
                    return slot
        return None            # ring full -> caller applies backpressure

    def release(self, slot: Slot) -> None:
        with self._lock:
            slot.seq = -1
