"""Size-aware transfer-latency model  L = L_fixed + α · size_MB  (§IV-C).

The paper calibrates (L_fixed, α) per machine with a helper script and uses
``sleep(0.95·L)`` to defer completion checks before passive waiting.  We do
the same for tier-1 (host→device) transfers, and reuse the same model
*structurally* for tier-3: the DMA pipeline depth of a kernel is chosen so
that one block's compute covers one block's predicted copy latency.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, asdict
from typing import Callable, Optional, Sequence

import numpy as np

MB = float(1 << 20)


@dataclass(frozen=True)
class LatencyModel:
    l_fixed_us: float = 73.6          # paper's measured constants as priors
    alpha_us_per_mb: float = 33.4
    rel_std: float = 0.0              # calibration dispersion (<2% in paper)

    def predict_us(self, nbytes: int) -> float:
        return self.l_fixed_us + self.alpha_us_per_mb * (nbytes / MB)

    def defer_seconds(self, nbytes: int, fraction: float = 0.95) -> float:
        return fraction * self.predict_us(nbytes) * 1e-6

    # -- roofline helpers (tier 2/3: structural use of the same model) ------
    def bandwidth_gbps(self) -> float:
        """Asymptotic bandwidth implied by α."""
        if self.alpha_us_per_mb <= 0:
            return float("inf")
        return (MB / (self.alpha_us_per_mb * 1e-6)) / 1e9

    def pipeline_depth_for(self, block_bytes: int, compute_us_per_block: float,
                           max_depth: int = 8) -> int:
        """Buffers needed so compute hides the predicted copy latency."""
        if compute_us_per_block <= 0:
            return max_depth
        need = int(np.ceil(self.predict_us(block_bytes) / compute_us_per_block)) + 1
        return int(np.clip(need, 2, max_depth))


class ServiceTimeModel:
    """Online service-time predictor for SLO admission (load shedding).

    Layers a per-op EWMA of *observed* handler service time over the
    structural transfer model: ``predict_s(op, nbytes)`` returns the max
    of the transfer-latency prediction and the op's observed EWMA, so the
    dispatcher can ask "will this request make its deadline if I run it
    now?" before spending a batch slot on it.  Before the first
    observation the transfer model alone answers (microseconds — the
    model never sheds a request it knows nothing about), and every
    completed batch tightens the estimate (`observe` with the per-request
    share of the batch's wall time).
    """

    def __init__(self, latency: Optional[LatencyModel] = None,
                 ewma: float = 0.2):
        self.latency = latency or LatencyModel()
        self.ewma = ewma
        self._per_op: dict = {}

    def observe(self, op: str, seconds: float) -> None:
        """Feed one request's observed service time (batch share)."""
        prev = self._per_op.get(op)
        self._per_op[op] = (seconds if prev is None
                            else (1 - self.ewma) * prev + self.ewma * seconds)

    def predict_s(self, op: str, nbytes: int = 0) -> float:
        """Predicted service seconds for one request of ``op``."""
        floor = self.latency.predict_us(nbytes) * 1e-6
        return max(floor, self._per_op.get(op, 0.0))

    def snapshot(self) -> dict:
        """Per-op EWMA milliseconds (introspection/metrics)."""
        return {f"{op}_ms": s * 1e3 for op, s in sorted(self._per_op.items())}


def calibrate(transfer_fn: Callable[[np.ndarray], None],
              sizes_bytes: Sequence[int] = (1 << 16, 1 << 18, 1 << 20,
                                            1 << 22, 1 << 23),
              repeats: int = 20) -> LatencyModel:
    """The paper's per-node recalibration helper: measure, fit, check std-dev.

    ``transfer_fn`` performs (and completes) one transfer of the given buffer.
    """
    xs, ys, rels = [], [], []
    for size in sizes_bytes:
        buf = np.ones(size, np.uint8)
        transfer_fn(buf)                                   # warm-up / first-touch
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            transfer_fn(buf)
            ts.append((time.perf_counter() - t0) * 1e6)
        ts = np.asarray(ts)
        med = float(np.median(ts))
        xs.append(size / MB)
        ys.append(med)
        rels.append(float(np.std(ts) / max(med, 1e-9)))
    a, b = np.polyfit(np.asarray(xs), np.asarray(ys), 1)
    return LatencyModel(l_fixed_us=max(float(b), 0.0),
                        alpha_us_per_mb=max(float(a), 0.0),
                        rel_std=float(np.mean(rels)))


# ---------------------------------------------------------------------------
# persistence (per-node cache, like the paper's deployment-time profiling)
# ---------------------------------------------------------------------------

def save(model: LatencyModel, path: str) -> None:
    with open(path, "w") as f:
        json.dump(asdict(model), f)


def load(path: str) -> Optional[LatencyModel]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return LatencyModel(**json.load(f))
