"""Asynchronous transfer engine — tier-1 ROCKET (host→device movement).

The paper's DSA engine abstraction (§IV-C "Asynchronous DSA Engine") mapped
onto the host side of a JAX program:

- *submission*   = handing a host batch to the engine (returns a job id
  immediately in async/pipelined modes — ENQCMD analogue);
- *the engine*   = a dedicated transfer thread pool performing staging-copy +
  ``jax.device_put`` off the critical path (the CPU cycles the paper frees);
- *completion*   = hybrid polling (§IV-C): size-aware deferral (sleep
  0.95·L_predicted) followed by short-interval passive waits (the UMWAIT
  quantum analogue);
- *queue pairs*  = persistent staging buffers from :mod:`repro.core.queuepair`.

Instrumented (submissions, polls, wait time, overlap) so the benchmark
harness can reproduce the paper's Figs. 3/10/12/13 counters.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import LatencyModel
from repro.core.policy import Device, ExecutionMode, OffloadPolicy
from repro.core.queuepair import BufferPool, drain_to_depth


def _nbytes(tree) -> int:
    return sum(np.asarray(x).nbytes if not hasattr(x, "nbytes") else x.nbytes
               for x in jax.tree.leaves(tree))


@dataclass
class EngineStats:
    submitted: int = 0
    inline: int = 0                  # below-threshold transfers kept on CPU path
    offloaded: int = 0
    polls: int = 0                   # completion-flag checks after deferral
    deferred_sleep_s: float = 0.0    # predicted-latency sleeps (hidden time)
    blocked_wait_s: float = 0.0      # residual synchronous waiting
    bytes_moved: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


class TransferJob:
    """Completion handle (the paper's completion flag + job id)."""

    _ids = itertools.count()

    def __init__(self, nbytes: int, engine: "AsyncTransferEngine",
                 future: Optional[Future] = None, value: Any = None):
        self.job_id = next(self._ids)
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self._future = future
        self._value = value
        self._engine = engine

    def done(self) -> bool:
        return self._future is None or self._future.done()

    def get(self) -> Any:
        """Hybrid-polling completion (deferral + short-interval waits)."""
        if self._future is None:
            return self._value
        eng = self._engine
        if not self._future.done():
            # size-aware deferral: sleep the *remaining* predicted latency
            pred = eng.latency.defer_seconds(self.nbytes, eng.policy.defer_fraction)
            elapsed = time.perf_counter() - self.submit_t
            remain = pred - elapsed
            if remain > 0:
                time.sleep(remain)
                eng.stats.deferred_sleep_s += remain
            quantum = eng.policy.poll_interval_us * 1e-6
            t0 = time.perf_counter()
            while not self._future.done():      # passive short waits (UMWAIT)
                eng.stats.polls += 1
                try:
                    self._value = self._future.result(timeout=quantum)
                    self._future = None
                    eng.stats.blocked_wait_s += time.perf_counter() - t0
                    return self._value
                except (TimeoutError, FuturesTimeout):
                    continue
            eng.stats.blocked_wait_s += time.perf_counter() - t0
        self._value = self._future.result()
        self._future = None
        return self._value


class AsyncTransferEngine:
    """ROCKET tier-1 engine: modes sync / async / pipelined for host→device."""

    def __init__(self, policy: OffloadPolicy = OffloadPolicy(),
                 latency: Optional[LatencyModel] = None,
                 put_fn: Optional[Callable] = None,
                 workers: int = 2, stage: bool = True):
        self.policy = policy
        self.latency = latency or LatencyModel()
        self.pool = BufferPool()
        self.stats = EngineStats()
        self._put = put_fn or jax.device_put
        self._custom_put = put_fn is not None
        self._stage = stage
        self._executor = ThreadPoolExecutor(max_workers=workers,
                                            thread_name_prefix="rocket-dma")
        self._inflight: list[TransferJob] = []
        self._lock = threading.Lock()

    def _stage_copy(self, batch):
        """Copy into persistent pinned staging buffers (the shared-memory
        write of the paper's IPC path; pre-mapped, so no first-touch cost)."""
        def one(x):
            arr = np.asarray(x)
            buf = self.pool.acquire(arr.shape, arr.dtype)
            np.copyto(buf, arr)
            return buf
        return jax.tree.map(one, batch)

    def _device_copy(self, staged, sharding):
        # on the CPU backend device_put may alias host memory; force a real
        # copy so staging buffers can be recycled safely (and so the
        # benchmark actually measures a transfer)
        if self._custom_put:
            out = self._put(staged, sharding)
        elif sharding is not None:
            out = self._put(staged, sharding)
        elif jax.default_backend() == "cpu":
            out = jax.tree.map(jnp.array, staged)
        else:
            out = self._put(staged)
        jax.block_until_ready(out)
        return out

    # -- submission ----------------------------------------------------------
    def submit(self, batch, sharding=None) -> TransferJob:
        nbytes = _nbytes(batch)
        self.stats.submitted += 1
        self.stats.bytes_moved += nbytes

        def do_move():
            # offload path: the *engine thread* performs the staging copy and
            # the device transfer — the caller's cycles are freed (the DSA
            # model); inline path: the caller runs this synchronously.
            staged = self._stage_copy(batch) if self._stage else batch
            out = self._device_copy(staged, sharding)
            if self._stage:
                jax.tree.map(self.pool.release, staged)
            return out

        if (self.policy.mode == ExecutionMode.SYNC
                or not self.policy.should_offload(nbytes)):
            self.stats.inline += 1
            return TransferJob(nbytes, self, value=do_move())

        self.stats.offloaded += 1
        job = TransferJob(nbytes, self, future=self._executor.submit(do_move))
        if self.policy.mode == ExecutionMode.PIPELINED:
            with self._lock:
                self._inflight.append(job)
            # backpressure at pipeline depth (bounded queue-pair ring)
            drain_to_depth(self._inflight, self._lock,
                           self.policy.pipeline_depth, lambda j: j.get())
        return job

    # -- batch-level completion (pipelined mode defers checks to here) --------
    def drain(self) -> list:
        with self._lock:
            jobs, self._inflight = self._inflight, []
        return [j.get() for j in jobs]

    def close(self) -> None:
        self.drain()
        self._executor.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
