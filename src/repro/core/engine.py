"""Asynchronous transfer engine — tier-1 ROCKET (host→device movement).

The paper's DSA engine abstraction (§IV-C "Asynchronous DSA Engine") mapped
onto the host side of a JAX program:

- *submission*   = handing a host batch to the engine (returns a job id
  immediately in async/pipelined modes — ENQCMD analogue);
- *the engine*   = the process-wide :class:`~repro.core.copyengine.CopyEngine`
  performing staging-copy (one scatter-gather descriptor per pytree) +
  ``jax.device_put`` off the critical path — the same engine every IPC
  channel submits to, so one runtime coordinates all movement;
- *completion*   = hybrid polling (§IV-C): size-aware deferral (sleep
  0.95·L_predicted) followed by short-interval passive waits (the UMWAIT
  quantum analogue), implemented once in
  :class:`~repro.core.copyengine.CopyJob`;
- *queue pairs*  = persistent staging buffers from :mod:`repro.core.queuepair`.

Instrumented (submissions, polls, wait time, overlap) so the benchmark
harness can reproduce the paper's Figs. 3/10/12/13 counters.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.copyengine import (
    CopyEngine,
    CopyJob,
    Descriptor,
    HybridPollStats,
    SGList,
    get_engine,
)
from repro.core.latency import LatencyModel
from repro.core.policy import Device, ExecutionMode, OffloadPolicy
from repro.core.queuepair import BufferPool, drain_to_depth


def _nbytes(tree) -> int:
    return sum(np.asarray(x).nbytes if not hasattr(x, "nbytes") else x.nbytes
               for x in jax.tree.leaves(tree))


@dataclass
class EngineStats(HybridPollStats):
    """Tier-1 counters: the shared hybrid-polling fields plus submission
    and byte totals."""
    submitted: int = 0
    bytes_moved: int = 0


class TransferJob:
    """Completion handle (the paper's completion flag + job id), backed by
    a copy-engine :class:`~repro.core.copyengine.CopyJob` when offloaded."""

    def __init__(self, nbytes: int, engine: "AsyncTransferEngine",
                 job: Optional[CopyJob] = None, value: Any = None):
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self._job = job
        self._value = value
        self.job_id = job.job_id if job is not None else -1

    def done(self) -> bool:
        """True once the transfer's completion record is posted."""
        return self._job is None or self._job.done()

    def get(self, timeout_s: float = 600.0) -> Any:
        """Hybrid-polling completion (deferral + short passive waits)."""
        if self._job is not None:
            self._value = self._job.wait(timeout_s)
            self._job = None
        return self._value


class AsyncTransferEngine:
    """ROCKET tier-1 engine: modes sync / async / pipelined for host→device.

    The staging copy and device transfer run on the shared
    :class:`~repro.core.copyengine.CopyEngine` (one SG descriptor per
    pytree, unordered work queues so independent transfers overlap);
    ``copy_engine`` overrides the shared instance for tests.
    """

    def __init__(self, policy: OffloadPolicy = OffloadPolicy(),
                 latency: Optional[LatencyModel] = None,
                 put_fn: Optional[Callable] = None,
                 workers: int = 2, stage: bool = True,
                 copy_engine: Optional[CopyEngine] = None):
        del workers                      # engine pool is process-wide now
        self.policy = policy
        self.latency = latency or LatencyModel()
        self.pool = BufferPool()
        self.stats = EngineStats()
        self._put = put_fn or jax.device_put
        self._custom_put = put_fn is not None
        self._stage = stage
        self._copyeng = copy_engine or get_engine()
        self._inflight: deque[TransferJob] = deque()
        self._lock = threading.Lock()

    def _device_copy(self, staged, sharding):
        # on the CPU backend device_put may alias host memory; force a real
        # copy so staging buffers can be recycled safely (and so the
        # benchmark actually measures a transfer)
        if self._custom_put:
            out = self._put(staged, sharding)
        elif sharding is not None:
            out = self._put(staged, sharding)
        elif jax.default_backend() == "cpu":
            out = jax.tree.map(jnp.array, staged)
        else:
            out = self._put(staged)
        jax.block_until_ready(out)
        return out

    def _make_descriptor(self, batch, sharding, nbytes: int) -> Descriptor:
        """One SG descriptor per pytree: gather every leaf into persistent
        staging buffers (the pre-mapped shared-memory write of the paper's
        IPC path), then the device transfer as the completion callback."""

        def build() -> SGList:
            sg = SGList()
            if not self._stage:
                sg.ctx = batch
                return sg

            def one(x):
                arr = np.asarray(x)
                buf = self.pool.acquire(arr.shape, arr.dtype)
                sg.add_array(arr, buf)
                return buf

            sg.ctx = jax.tree.map(one, batch)
            return sg

        def complete(sg: SGList):
            out = self._device_copy(sg.ctx, sharding)
            if self._stage:
                jax.tree.map(self.pool.release, sg.ctx)
            return out

        return Descriptor(build=build, complete=complete, nbytes=nbytes,
                          injection=self.policy.injection_enabled(),
                          tag="stage")

    # -- submission ----------------------------------------------------------
    def submit(self, batch, sharding=None) -> TransferJob:
        nbytes = _nbytes(batch)
        self.stats.submitted += 1
        self.stats.bytes_moved += nbytes
        descr = self._make_descriptor(batch, sharding, nbytes)

        if (self.policy.mode == ExecutionMode.SYNC
                or not self.policy.should_offload(nbytes)):
            # inline path: the caller's thread performs the (counted) SG
            # copies and the device transfer synchronously
            self.stats.inline += 1
            sg = descr.build()
            if len(sg):
                self._copyeng.run_sg(sg, injection=descr.injection,
                                     tag=descr.tag)
            return TransferJob(nbytes, self, value=descr.complete(sg))

        self.stats.offloaded += 1
        cj = self._copyeng.submit(descr, wq=None, policy=self.policy,
                                  latency=self.latency, stats=self.stats)
        job = TransferJob(nbytes, self, job=cj)
        if self.policy.mode == ExecutionMode.PIPELINED:
            with self._lock:
                self._inflight.append(job)
            # backpressure at pipeline depth (bounded queue-pair ring)
            drain_to_depth(self._inflight, self._lock,
                           self.policy.pipeline_depth, lambda j: j.get())
        return job

    # -- batch-level completion (pipelined mode defers checks to here) --------
    def drain(self) -> list:
        with self._lock:
            jobs, self._inflight = list(self._inflight), deque()
        return [j.get() for j in jobs]

    def close(self) -> None:
        """Complete outstanding transfers (the shared copy engine itself
        stays up — it serves every other datapath in the process)."""
        self.drain()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
