"""Adaptive hybrid-coordination governor (the paper's §IV/Table III as a
feedback loop instead of constants).

The static :class:`~repro.core.policy.OffloadPolicy` picks a per-message
strategy from fixed thresholds: ``offload_threshold_bytes`` splits inline
vs offloaded copies, ``heap_threshold_bytes`` splits slot vs bulk-heap,
and coalescing is on or off.  Those constants encode one machine's
break-evens; the paper's point is that the *fixed* costs they trade off
(slot claim, doorbell, poll wakeup, submission round-trip) are exactly
the ones that drift with host load, core count, and queue depth.

:class:`ChannelGovernor` replaces the constants with measurement.  Per
**size class** (log2 bucket of payload bytes) it keeps an EWMA of the
observed per-message cost of every *route* it has tried:

- ``inline``   — the caller copies into the slot and publishes (sync/DTO);
- ``offload``  — the copy engine performs claim+copy+publish async;
- ``coalesce`` — the message joins a microbatch frame, amortizing slot
  claim, meta encode, and doorbell K-ways (``FLAG_COALESCED``);
- ``heap``     — the payload rides bulk-heap extents, the ring only a
  descriptor.

``decide()`` returns the cheapest *eligible* route for the message's
class.  Eligibility is semantic, not learned: sync-mode sends can never
leave the caller before completion (no offload/coalesce), payloads over
the slot capacity must take the heap, and coalescing requires enough
queue **occupancy** (EWMA of the tx backlog the channel reports) that a
frame actually fills — batching a depth-1 request/reply stream would add
latency for nothing, which is the load-awareness half of the paper's
hybrid coordination (cf. Shenango/Shimmy-style load-aware polling).

Exploration is deterministic, bounded, and **bursty**: routes are probed
in runs of ``explore_burst`` consecutive messages — single-message
probes would be both unfair (a lone coalesced message makes a 1-deep
frame, measuring none of the amortization) and disruptive (every route
flip flushes the open frame early).  A route with fewer than
``min_samples`` observations is burst-probed first (cold start, fewest
samples first), after that every ``explore_every``-th decision per class
starts a re-probe burst of the stalest route so a drifted break-even is
re-learned.  Between bursts the class *sticks* to its current route and
only switches when a competitor's EWMA beats it by ``switch_margin``
(hysteresis — measurement jitter alone cannot cause flip-flopping).
Unmeasured routes are seeded with priors from the calibrated
:class:`~repro.core.latency.LatencyModel` and the static policy
thresholds, so a cold adaptive channel behaves like the static one.

No timers run in the data plane: the channel feeds ``observe()`` with
timings it already takes (send duration, completion-record timestamps)
and ``observe_occupancy()`` with shared-counter reads; the governor
itself never calls the clock.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.latency import LatencyModel
from repro.core.policy import OffloadPolicy
from repro.obs import hwcounters as _hw
from repro.obs import trace as _trace

# route names (wire-stable: they appear in stats snapshots and benchmarks)
INLINE, OFFLOAD, COALESCE, HEAP = "inline", "offload", "coalesce", "heap"
ROUTES = (INLINE, OFFLOAD, COALESCE, HEAP)

#: log2 size-class floor: everything below 1 KB shares one class (the
#: control-plane cost dominates; distinguishing 64 B from 512 B is noise)
_MIN_CLASS = 10
_MAX_CLASS = 32


def size_class(nbytes: int) -> int:
    """Log2 bucket of a payload size (classes ``_MIN_CLASS.._MAX_CLASS``)."""
    return min(max((max(nbytes, 1) - 1).bit_length(), _MIN_CLASS),
               _MAX_CLASS)


@dataclass
class RouteEstimate:
    """One (size class, route) cell: EWMA cost + sample accounting."""
    ewma_us: float = 0.0
    samples: int = 0
    picks: int = 0              # decisions routed here (immediate, unlike
                                # samples, which lag behind async completion)
    last_decision: int = 0      # decision index of the last observation

    def observe(self, us: float, alpha: float) -> None:
        if self.samples == 0:
            self.ewma_us = us
        else:
            # winsorize: on coarse-timer kernels a single stray quantum
            # sleep is a ~1 ms outlier on a ~30 µs route — letting it
            # through would inflate the estimate past any hysteresis
            # margin and flip the route on scheduler noise rather than
            # cost.  While cold (< 16 samples) use a running mean (1/n
            # decay washes an unlucky early draw out linearly; an EWMA
            # would anchor on it for dozens of samples).
            us = min(us, 4.0 * self.ewma_us)
            if self.samples < 16:
                self.ewma_us += (us - self.ewma_us) / (self.samples + 1)
            else:
                self.ewma_us += alpha * (us - self.ewma_us)
        self.samples += 1


@dataclass
class GovernorStats:
    """Counted decisions (no timing): route picks, exploration, flips."""
    decisions: int = 0
    explored: int = 0            # decisions spent (re)probing a route
    flips: int = 0               # class best-route changes observed
    picks: dict = field(default_factory=dict)     # route -> count

    def snapshot(self) -> dict:
        out = dict(self.__dict__)
        out["picks"] = dict(self.picks)
        return out


class ChannelGovernor:
    """Measured break-even route selection for one channel.

    Thread-safety: a channel may be driven by several sender threads, and
    its observation callbacks fire under different channel locks (frame
    flush, in-flight pruning) or none at all (inline sampling) — so the
    governor guards its own state with one internal lock.  ``decide``'s
    steady state is a cached dict hit, so the lock is held for well under
    a microsecond per message.
    """

    def __init__(self, policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 alpha: float = 0.1,
                 occupancy_alpha: float = 0.1,
                 min_samples: int = 12,
                 explore_burst: int = 8,
                 explore_every: int = 128,
                 refresh_every: int = 32,
                 switch_margin: float = 0.75,
                 min_coalesce_occupancy: float = 1.5):
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.alpha = alpha
        self.occupancy_alpha = occupancy_alpha
        self.min_samples = min_samples
        self.explore_burst = max(1, explore_burst)
        self.explore_every = explore_every
        self.refresh_every = max(1, refresh_every)
        self.switch_margin = switch_margin
        self.min_coalesce_occupancy = min_coalesce_occupancy
        self.stats = GovernorStats()
        self._lock = threading.Lock()
        self._occ_ewma = 0.0
        # (class) -> {route -> RouteEstimate}; (class) -> decision counter
        self._est: dict[int, dict[str, RouteEstimate]] = {}
        self._decisions: dict[int, int] = {}
        self._best: dict[int, str] = {}
        # decision cache: (class) -> [route, valid-until decision index].
        # The full evaluation (eligibility, due re-probes, argmin) runs
        # every refresh_every decisions — or once per exploration burst —
        # so the steady-state decide() is one dict hit, not eight EWMA
        # comparisons on every message of a 30 µs hot path.
        self._cached: dict[int, list] = {}

    # -- feedback -------------------------------------------------------------
    def observe(self, route: str, nbytes: int, us: float) -> None:
        """Feed one measured per-message cost (µs) for a route."""
        if us < 0.0:
            return
        if _trace.TRACE.enabled:
            _trace.instant(_trace.GOV_OBSERVE,
                           arg=min(nbytes, 0xFFFFFFFF))
        cls = size_class(nbytes)
        with self._lock:
            cell = self._cell(cls, route)
            now = self._decisions.get(cls, 0)
            if (cell.samples and self.explore_every
                    and now - cell.last_decision > 2 * self.explore_every):
                # stale estimate being re-probed: restart the robust mean
                # so the burst re-learns the cost in explore_burst samples
                # — decaying an EWMA from a wrong old anchor would delay a
                # clearly-due route flip by hundreds of messages
                cell.samples = 0
            cell.observe(us, self.alpha)
            cell.last_decision = now

    def wants_sample(self, route: str, nbytes: int) -> bool:
        """True while a route's estimate is still cold — callers that
        subsample their cost measurements (the inline hot path) observe
        every message until the cell has a trustworthy baseline."""
        cell = self._est.get(size_class(nbytes), {}).get(route)
        return cell is None or cell.samples < 4 * self.min_samples

    def observe_occupancy(self, backlog: float) -> None:
        """Feed the sender-side queue depth (tx ring backlog + pending
        frame entries) — the load signal gating coalescing."""
        with self._lock:
            self._occ_ewma += self.occupancy_alpha * (backlog
                                                      - self._occ_ewma)

    @property
    def occupancy(self) -> float:
        """Current EWMA of the observed queue occupancy."""
        return self._occ_ewma

    # -- priors (cold start ≈ the static Table III policy) --------------------
    def _prior_us(self, route: str, nbytes: int) -> float:
        base = self.latency.predict_us(nbytes)
        if route == INLINE:
            return base
        if route == OFFLOAD:
            # static threshold as a prior: offload looks cheaper above it
            return base * (0.6 if self.policy.should_offload(nbytes) else 1.5)
        if route == COALESCE:
            # amortization hope: fixed cost split ~4 ways until measured
            return (self.latency.l_fixed_us / 4.0
                    + self.latency.alpha_us_per_mb * nbytes / (1 << 20))
        # HEAP: descriptor-passing beats slot copy above the static threshold
        return base * (0.8 if nbytes >= self.policy.heap_threshold_bytes
                       else 2.0)

    def _cell(self, cls: int, route: str) -> RouteEstimate:
        per = self._est.get(cls)
        if per is None:
            per = self._est[cls] = {}
        cell = per.get(route)
        if cell is None:
            cell = per[route] = RouteEstimate()
        return cell

    def _cost_us(self, cls: int, route: str, nbytes: int) -> float:
        cell = self._est.get(cls, {}).get(route)
        if cell is None or cell.samples == 0:
            return self._prior_us(route, nbytes)
        return cell.ewma_us

    # -- the decision ---------------------------------------------------------
    def decide(self, nbytes: int, eligible: Sequence[str],
               backlog_fn=None) -> str:
        """Pick a route for one message among the semantically *eligible*
        ones (the channel enforces mode/size/capacity legality; the
        governor layers load-awareness and measured break-evens on top).

        ``backlog_fn`` lazily supplies the sender-side queue depth — it is
        only called on the (every ``refresh_every``-th) full evaluation,
        keeping shared-counter reads off the per-message fast path.
        """
        if _trace.TRACE.enabled or _hw.PROF.enabled:
            t0 = _trace.now() if _trace.TRACE.enabled else 0
            c0 = _hw.begin() if _hw.PROF.enabled else None
            try:
                return self._decide(nbytes, eligible, backlog_fn)
            finally:
                if t0:
                    _trace.emit(_trace.GOV_DECIDE, t0,
                                arg=min(nbytes, 0xFFFFFFFF))
                if c0 is not None:
                    _hw.end(c0, "governor", nbytes=nbytes)
        return self._decide(nbytes, eligible, backlog_fn)

    def _decide(self, nbytes: int, eligible: Sequence[str],
                backlog_fn=None) -> str:
        """Untraced body of :meth:`decide`."""
        cls = size_class(nbytes)
        backlog = None
        with self._lock:
            n = self._decisions.get(cls, 0) + 1
            self._decisions[cls] = n
            self.stats.decisions += 1
            cached = self._cached.get(cls)
            if cached is not None and n < cached[1] and cached[0] in eligible:
                pick = cached[0]
                self.stats.picks[pick] = self.stats.picks.get(pick, 0) + 1
                return pick
        if backlog_fn is not None:       # outside the lock: counter reads
            backlog = backlog_fn()
        with self._lock:
            if backlog is not None:
                self._occ_ewma += self.occupancy_alpha * (backlog
                                                          - self._occ_ewma)
            routes = [r for r in ROUTES if r in eligible]
            if COALESCE in routes and len(routes) > 1 \
                    and self._occ_ewma < self.min_coalesce_occupancy:
                routes.remove(COALESCE)  # not enough backlog to fill a frame
            if len(routes) == 1:
                pick, ttl = routes[0], self.refresh_every
            else:
                pick, ttl = self._pick(cls, routes, nbytes, n)
            self._cell(cls, pick).picks += ttl   # cached decisions included
            self._cached[cls] = [pick, n + ttl]
            self.stats.picks[pick] = self.stats.picks.get(pick, 0) + 1
            return pick

    def _samples(self, cls: int, route: str) -> int:
        cell = self._est.get(cls, {}).get(route)
        return 0 if cell is None else cell.samples

    def _pick(self, cls: int, routes: list[str], nbytes: int,
              n: int) -> tuple[str, int]:
        """Full route evaluation; returns ``(route, decisions-to-cache)``.
        Exploration always runs as a *burst* of ``explore_burst`` cached
        decisions — a lone coalesced probe would measure a 1-deep frame
        (no amortization) and every route flip flushes the open frame."""
        # cold start: burst-probe any route still under min_samples
        # (deterministic: fewest samples first, route declaration order
        # breaking ties) so every eligible route gets a fair measurement —
        # min_samples spans two bursts, so a baseline is never a single
        # contiguous window of one host-load patch.  Bounded by *picks*:
        # async routes report their cost via lagging completion records,
        # and treating "picked a lot, few samples yet" as still-cold would
        # keep burst-probing the slowest route exactly because it is slow
        cold = [r for r in routes
                if self._samples(cls, r) < self.min_samples
                and self._cell(cls, r).picks < 2 * self.explore_burst]
        if cold:
            route = min(cold, key=lambda r: (self._samples(cls, r),
                                             ROUTES.index(r)))
            self.stats.explored += 1
            return route, self.explore_burst
        # periodic re-probe bursts with cost-ratio backoff: a route whose
        # measured cost is r× the best is revisited r× less often (up to
        # 64×), so confirming that offload is terrible for 4 KB messages
        # costs an asymptotically vanishing share of the stream while a
        # drifted break-even is still re-learned
        if self.explore_every:
            best_cost = min(self._cost_us(cls, r, nbytes) for r in routes)
            incumbent = self._best.get(cls)
            due_route, due_at = None, None
            for r in routes:
                if r == incumbent:
                    continue           # continuously observed anyway
                ratio = max(1.0, min(self._cost_us(cls, r, nbytes)
                                     / max(best_cost, 1e-9), 64.0))
                due = (self._est[cls][r].last_decision
                       + self.explore_every * ratio)
                if due <= n and (due_at is None or due < due_at):
                    due_route, due_at = r, due
            if due_route is not None:
                self.stats.explored += 1
                return due_route, self.explore_burst
        # exploit with hysteresis: stick to the incumbent unless a
        # competitor's measured cost beats it by the switch margin
        current = self._best.get(cls)
        challenger = min(routes,
                         key=lambda r: (self._cost_us(cls, r, nbytes),
                                        ROUTES.index(r)))
        if current in routes and challenger != current:
            if (self._cost_us(cls, challenger, nbytes)
                    >= self.switch_margin * self._cost_us(cls, current,
                                                          nbytes)):
                return current, self.refresh_every
            self.stats.flips += 1       # margin cleared: real break-even move
        self._best[cls] = challenger
        return challenger, self.refresh_every

    # -- introspection --------------------------------------------------------
    def snapshot(self) -> dict:
        """Per-class route costs + decision counters (benchmark rows,
        ``ShmTransport.stats()``)."""
        with self._lock:
            classes = {}
            for cls, per in sorted(self._est.items()):
                classes[cls] = {
                    r: {"ewma_us": round(c.ewma_us, 3), "samples": c.samples}
                    for r, c in per.items()}
            return {"occupancy": round(self._occ_ewma, 3),
                    "best": dict(self._best),
                    "classes": classes,
                    **self.stats.snapshot()}
