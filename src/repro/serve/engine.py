"""Serving runtime: batched prefill/decode with persistent device cache slots.

ROCKET integration:
- the :class:`~repro.core.dispatcher.RequestDispatcher` front-end batches
  requests (pipelined mode) before they hit the device — the paper's
  application-level request batching;
- KV caches are *donated* through jit (persistent queue-pair buffers: the
  allocation is reused every decode step, no re-mapping);
- host→device prompt transfer goes through the tier-1 engine policy.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dispatcher import RequestDispatcher
from repro.core.engine import AsyncTransferEngine
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.models.registry import ModelAPI
from repro.obs import trace as _trace


@dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    max_batch: int = 8
    max_new_tokens: int = 32
    greedy: bool = True


class BatchedServer:
    """Batch-synchronous generation server over a fixed slot count."""

    def __init__(self, model: ModelAPI, params, scfg: ServeConfig,
                 policy: OffloadPolicy = OffloadPolicy()):
        self.model = model
        self.params = params
        self.scfg = scfg
        self.policy = policy
        self.engine = AsyncTransferEngine(policy)
        self._prefill = jax.jit(
            functools.partial(model.prefill, max_len=scfg.max_len))
        # cache donated: the persistent decode buffer is reused in place
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self.stats = {"requests": 0, "batches": 0, "tokens_out": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    # -- core batched generation ------------------------------------------------
    def generate_batch(self, batch: dict, new_tokens: Optional[int] = None
                       ) -> np.ndarray:
        n_new = new_tokens or self.scfg.max_new_tokens
        tt0 = _trace.now() if _trace.TRACE.enabled else 0
        t0 = time.perf_counter()
        dev_batch = self.engine.submit(batch).get()
        logits, cache = self._prefill(self.params, dev_batch)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        outs = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            outs.append(tok)
        result = np.asarray(jnp.concatenate(outs, axis=1))
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["tokens_out"] += result.size
        if tt0:
            _trace.emit(_trace.SERVE_BATCH, tt0, arg=result.shape[0])
        return result

    # -- diskless checkpoint/restore ---------------------------------------------
    def state_snapshot(self) -> tuple:
        """``(tree, extra)`` for a :class:`repro.checkpoint.ShardCodec` /
        :class:`repro.checkpoint.ReplicationSource`: the parameter pytree
        pulled to host memory plus the serving counters as picklable side
        state.  Byte-exact — :meth:`restore_state` of the encoded shards
        reproduces the params bit-for-bit."""
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), self.params)
        return host, {"stats": dict(self.stats)}

    def restore_state(self, tree, extra: Optional[dict] = None) -> None:
        """Adopt a replicated/decoded snapshot: install the parameter
        pytree (device placement happens lazily on first jit call) and
        the serving counters, so a promoted replica's numbers continue
        the primary's, not restart from zero."""
        self.params = tree
        if extra and "stats" in extra:
            self.stats.update(extra["stats"])

    # -- request-level API (dispatcher integration) ------------------------------
    def make_dispatcher(self, latency: Optional[LatencyModel] = None,
                        workers: int = 1) -> RequestDispatcher:
        d = RequestDispatcher(self.policy, latency, workers=workers)

        def single(data: np.ndarray) -> np.ndarray:
            self.stats["requests"] += 1
            return self.generate_batch(self._pack([data]))[0]

        def batched(datas: list[np.ndarray]) -> list[np.ndarray]:
            self.stats["requests"] += len(datas)
            out = self.generate_batch(self._pack(datas))
            return [out[i] for i in range(len(datas))]

        def batched_slab(slab: np.ndarray, shapes) -> list[np.ndarray]:
            # single-copy datapath: the dispatcher's batch-formation gather
            # already left-aligned + zero-padded every prompt into ``slab``
            # — exactly what _pack would build — so wrap it without another
            # per-row packing copy
            self.stats["requests"] += len(shapes)
            out = self.generate_batch(self._wrap(slab))
            return [out[i] for i in range(len(shapes))]

        d.register_handler("generate", single, batch_fn=batched,
                           slab_fn=batched_slab)
        return d

    # -- cross-process serving (repro.ipc) ---------------------------------------
    def serve_over_ipc(self, name: Optional[str] = None,
                       latency: Optional[LatencyModel] = None,
                       data_slot_bytes: int = 2 << 20,
                       heap_extent_bytes: int = 1 << 20,
                       heap_extents: int = 32,
                       max_clients: int = 64,
                       reactors: int = 1,
                       default_deadline_ms: Optional[float] = None,
                       replicate: bool = False,
                       shard_bytes: int = 1 << 20):
        """Expose the dispatcher to any number of client *processes* over
        the multi-client shared-memory fabric.

        Returns a started :class:`repro.ipc.ServingFabric` — use it as a
        context manager (one ``with`` tears down listener, reactor,
        per-client transports, and the dispatcher in order).  Clients join
        with ``RemoteDispatcherClient.connect(fabric.name)`` and use the
        paper's request/query API; pipelined requests from different
        clients are batched into single model calls.

        Slots only have to fit *sub-threshold* messages now: prompts or
        replies at/over ``policy.heap_threshold_bytes`` ride each
        connection's bulk heap (``heap_extents × heap_extent_bytes`` per
        direction; ``heap_extents=0`` disables it), so per-client shared
        memory stays small without capping the payload size.

        SLO serving: ``reactors`` shards the drain loop (clients are
        partitioned across shards at accept time; the dispatcher gets a
        matching worker pool so shards execute concurrently), and
        ``default_deadline_ms`` stamps a deadline on every request that
        arrives without one, arming the fabric's SLO monitor.

        ``replicate=True`` attaches a
        :class:`repro.checkpoint.ReplicationSource` over
        :meth:`state_snapshot` (sharded at ``shard_bytes``), so a warm
        standby (:class:`repro.ft.StandbyReplica`) can mirror this
        server's params + dispatcher state through the same fabric; the
        source is exposed as ``fabric.replication``.
        """
        from repro.ipc import ServingFabric
        from repro.ipc.transport import TransportSpec

        dispatcher = self.make_dispatcher(latency, workers=max(1, reactors))
        fabric = ServingFabric(
            dispatcher, name=name,
            spec=TransportSpec(data_slot_bytes=data_slot_bytes,
                               heap_extent_bytes=heap_extent_bytes,
                               heap_extents=heap_extents),
            policy=self.policy, latency=latency, max_clients=max_clients,
            own_dispatcher=True, reactors=reactors,
            default_deadline_ms=default_deadline_ms)
        fabric.metrics.register("server", lambda: self.stats)
        if replicate:
            from repro.checkpoint import ReplicationSource
            fabric.replication = ReplicationSource(
                self.state_snapshot, shard_bytes=shard_bytes
            ).attach(dispatcher)
        return fabric.start()

    def _pack(self, prompts: list[np.ndarray]) -> dict:
        """Left-align prompts into a fixed (B, S) slab (persistent shape)."""
        s = max(int(p.shape[-1]) for p in prompts)
        b = len(prompts)
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : p.shape[-1]] = p
        return self._wrap(toks)

    def _wrap(self, toks: np.ndarray) -> dict:
        """Model-input dict around an already-packed (B, S) token slab."""
        toks = np.ascontiguousarray(toks.astype(np.int32, copy=False))
        b, s = toks.shape
        batch = {"tokens": toks}
        cfg = self.model.cfg
        if cfg.family == "audio":
            batch["frame_embeds"] = np.zeros((b, s, cfg.d_model), np.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = np.zeros(
                (b, cfg.num_patches, cfg.d_model), np.float32)
        return batch

    def close(self):
        self.engine.close()
