from repro.serve.engine import BatchedServer, ServeConfig

__all__ = ["BatchedServer", "ServeConfig"]
