from repro.sharding import api
from repro.sharding.api import constrain, get_mesh, set_mesh, spec, use_mesh

__all__ = ["api", "constrain", "get_mesh", "set_mesh", "spec", "use_mesh"]
