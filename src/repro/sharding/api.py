"""Mesh registry + logical-axis sharding constraints.

Models call :func:`constrain` with *logical* axis names; outside a mesh
context (CPU smoke tests) this is a no-op, inside the dry-run/launcher it
resolves to ``with_sharding_constraint`` against the registered mesh.

Logical axes:
  ``batch``  -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  ``model``  -> "model" (tensor-parallel axis)
  ``fsdp``   -> "data"  (parameter sharding for fsdp archs)
  ``seq``    -> "data"  (sequence parallelism, long-context decode)
  ``expert`` -> "model" (expert parallelism)
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_LAYOUT: str = "tp"      # "tp" | "dp_only" (see sharding.rules / §Perf)
_MANUAL: bool = False    # inside a manual shard_map region (constraints no-op)


@contextlib.contextmanager
def manual_mode():
    global _MANUAL
    prev = _MANUAL
    _MANUAL = True
    try:
        yield
    finally:
        _MANUAL = prev


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def set_layout(layout: str) -> None:
    global _LAYOUT
    assert layout in ("tp", "dp_only"), layout
    _LAYOUT = layout


def layout() -> str:
    return _LAYOUT


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    prev = _MESH
    set_mesh(mesh)
    try:
        if isinstance(mesh, Mesh):        # AbstractMesh has no device context
            with mesh:
                yield mesh
        else:
            yield mesh
    finally:
        set_mesh(prev)


def _axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def resolve(logical) -> object:
    """Map one logical axis name (or None / tuple) to mesh axis name(s)."""
    if _MESH is None:
        return None
    names = _axes(_MESH)
    if logical is None:
        return None
    if isinstance(logical, (tuple, list)):
        out = []
        for l in logical:
            r = resolve(l)
            if r is None:
                continue
            if isinstance(r, tuple):
                out.extend(r)
            else:
                out.append(r)
        return tuple(out) or None
    batch_names = ("pod", "data", "model") if _LAYOUT == "dp_only" \
        else ("pod", "data")
    table = {
        "batch": tuple(a for a in batch_names if a in names) or None,
        "model": None if _LAYOUT == "dp_only" else (
            "model" if "model" in names else None),
        "fsdp": "data" if "data" in names else None,
        "seq": "data" if "data" in names else None,
        "expert": "model" if "model" in names else None,
    }
    if logical not in table:
        raise KeyError(f"unknown logical axis {logical!r}")
    return table[logical]


def spec(*logicals) -> P:
    """Build a PartitionSpec from logical axis names (None = replicated)."""
    return P(*[resolve(l) for l in logicals])


def constrain(x, *logicals):
    """Apply a sharding constraint expressed in logical axes; no-op w/o mesh
    or inside a manual shard_map region."""
    if _MESH is None or _MANUAL:
        return x
    s = spec(*logicals)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, s))


def named(s: P) -> Optional[NamedSharding]:
    if _MESH is None:
        return None
    return NamedSharding(_MESH, s)
