"""Pipeline parallelism: GPipe-style microbatch pipelining over a mesh axis
via shard_map + collective_permute.

The stage axis holds one layer-group per shard; activations flow stage→stage
with `ppermute` while each stage processes a different microbatch — the
ROCKET *pipelined* execution mode applied to the layer dimension (submission
= microbatch injection at stage 0, completion = drain at the last stage,
depth = number of in-flight microbatches).

Schedule: GPipe forward with `n_micro + n_stages - 1` ticks. Stages idle in
the fill/drain bubbles (bubble fraction = (S-1)/(M+S-1), reported by
:func:`bubble_fraction` so the planner can size microbatch counts).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.sharding import api as shard_api


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x, *, axis: str,
                   n_micro: int):
    """Run ``y = stage_fn(params_s, ...) for s in stages`` as a pipeline.

    stage_params: pytree with leading dim = n_stages (sharded over ``axis``);
    x: (batch, ...) microbatched along dim 0 into ``n_micro`` slices.
    Returns y with the same shape as x (activations after the last stage).
    """
    mesh = shard_api.get_mesh()
    assert mesh is not None, "pipeline_apply requires an active mesh"
    n_stages = int(mesh.shape[axis])
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params_local, x_local):
        # params_local: (1, ...) this stage's parameters
        # x_local: full input (replicated); only stage 0 consumes it
        stage = jax.lax.axis_index(axis)
        p_mine = jax.tree.map(lambda t: t[0], params_local)
        xs = x_local.reshape(n_micro, mb, *x_local.shape[1:])
        outs0 = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry              # buf: (mb, ...) in-flight act
            inject = xs[jnp.minimum(t, n_micro - 1)]
            buf = jnp.where((stage == 0) & (t < n_micro), inject, buf)
            y = stage_fn(p_mine, buf)
            # last stage banks microbatch (t - (n_stages-1)) when valid
            out_idx = t - (n_stages - 1)
            bank = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                bank,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm_fwd)
            return (buf, outs), None

        buf0 = jnp.zeros((mb, *x_local.shape[1:]), x_local.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_micro + n_stages - 1))
        # every stage holds outs; only the last stage's is real — psum after
        # masking so the result is replicated
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(b, *x_local.shape[1:])

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    with shard_api.manual_mode():
        out = compat.shard_map(
            per_stage, mesh=mesh,
            in_specs=(param_specs, P()),
            out_specs=P(), check_vma=False,
        )(stage_params, x)
    return out


def sequential_apply(stage_fn: Callable, stage_params, x):
    """Reference: apply the stages sequentially (no pipelining)."""
    def body(h, p):
        return stage_fn(p, h), None
    out, _ = jax.lax.scan(body, x, stage_params)
    return out
