"""Parameter / batch / cache PartitionSpec rules (DP / TP / EP / SP / FSDP).

Rules are keyed by the *trailing* parameter-tree path names so they apply
uniformly to stacked (scanned) parameters: leading stack dimensions are
padded with ``None``.

Policy (baseline):
- attention: Q heads over ``model``; KV heads over ``model`` only when
  divisible (Megatron GQA convention: replicate KV inside the TP group
  otherwise); output projection reduced over ``model``;
- MLP: hidden over ``model``; MoE experts over ``model`` (EP);
- embeddings: vocab over ``model`` (+ d_model over ``data`` for fsdp archs);
- fsdp archs: the non-TP dimension of every large matrix over ``data``;
- KV caches: batch over ``data`` when divisible, otherwise *sequence* over
  ``data`` (SP — long-context decode), heads over ``model`` when divisible.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding import api as shard_api


def _mesh_axis_size(name: str) -> int:
    mesh = shard_api.get_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _model_axis(n: int) -> Optional[str]:
    """'model' if the dimension is shardable over the model axis."""
    if shard_api.layout() == "dp_only":
        return None          # model axis is repurposed as data parallelism
    size = _mesh_axis_size("model")
    return "model" if size > 1 and n % size == 0 else None


def _fsdp_axis(cfg: ModelConfig, n: int) -> Optional[str]:
    if not cfg.fsdp:
        return None
    size = _mesh_axis_size("data")
    return "data" if size > 1 and n % size == 0 else None


def _batch_axes() -> tuple:
    mesh = shard_api.get_mesh()
    if mesh is None:
        return ()
    names = ("pod", "data", "model") if shard_api.layout() == "dp_only" \
        else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def batch_axis_size() -> int:
    return int(np.prod([_mesh_axis_size(a) for a in _batch_axes()])) \
        if _batch_axes() else 1


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# layout policy (hillclimbed; see EXPERIMENTS.md §Perf):
# "tp"      — tensor parallel over the model axis (default)
# "dp_only" — replicate parameters, use the model axis as extra data
#             parallelism (right choice for small archs whose matrices are
#             too small to amortize TP collectives); set via
#             shard_api.set_layout around tracing.


def _param_spec(path_names: tuple, shape: tuple, cfg: ModelConfig) -> P:
    name = path_names[-1]
    nd = len(shape)
    if shard_api.layout() == "dp_only":
        return P(*([None] * nd))

    def pad(*trailing) -> P:
        lead = nd - len(trailing)
        return P(*([None] * lead), *trailing)

    kvh = cfg.num_kv_heads
    if name == "embedding":                       # (V, D)
        return pad(_model_axis(shape[-2]), _fsdp_axis(cfg, shape[-1]))
    if name == "lm_head":                         # (D, V)
        return pad(_fsdp_axis(cfg, shape[-2]), _model_axis(shape[-1]))
    if name == "wq":                              # (D, H, hd)
        return pad(_fsdp_axis(cfg, shape[-3]), _model_axis(shape[-2]), None)
    if name in ("wk", "wv") and nd >= 3:          # (D, K, hd)
        return pad(_fsdp_axis(cfg, shape[-3]), _model_axis(shape[-2]), None)
    if name == "wo" and nd >= 3:                  # (H, hd, D)
        return pad(_model_axis(shape[-3]), None, _fsdp_axis(cfg, shape[-1]))
    if name in ("wg", "wu", "wi", "ffn_wi") and nd >= 2:   # (D, F)
        return pad(_fsdp_axis(cfg, shape[-2]), _model_axis(shape[-1]))
    if name in ("wd", "ffn_wd"):                  # (F, D)
        return pad(_model_axis(shape[-2]), _fsdp_axis(cfg, shape[-1]))
    if name == "router":                          # (D, E)
        return pad(None, None)
    if name in ("we_g", "we_u"):                  # (E, D, F)
        return pad(_model_axis(shape[-3]), _fsdp_axis(cfg, shape[-2]), None)
    if name == "we_d":                            # (E, F, D)
        return pad(_model_axis(shape[-3]), None, _fsdp_axis(cfg, shape[-1]))
    # --- SSM (Mamba2) -------------------------------------------------------
    if name == "in_proj":                         # (D, proj_out)
        return pad(_fsdp_axis(cfg, shape[-2]), _model_axis(shape[-1]))
    if name == "conv_w":                          # (W, C)
        return pad(None, _model_axis(shape[-1]))
    if name in ("conv_b", "norm_scale", "gn_scale"):
        return pad(_model_axis(shape[-1]))
    if name == "out_proj":                        # (d_inner, D)
        return pad(_model_axis(shape[-2]), _fsdp_axis(cfg, shape[-1]))
    # --- xLSTM ----------------------------------------------------------------
    if name == "up_proj":                         # (D, 2*din)
        return pad(_fsdp_axis(cfg, shape[-2]), _model_axis(shape[-1]))
    if name == "down_proj":                       # (din, D)
        return pad(_model_axis(shape[-2]), _fsdp_axis(cfg, shape[-1]))
    if name in ("wz", "wf"):                      # sLSTM gate proj (D, D)
        return pad(None, _model_axis(shape[-1]))
    if name in ("w_i", "w_f"):                    # mLSTM gates (din, H)
        return pad(_model_axis(shape[-2]), None)
    # everything else (norm scales/biases, small gates, recurrent mixers)
    return P(*([None] * nd))


def param_pspecs(cfg: ModelConfig, params_tree):
    """Map a (possibly abstract) param pytree to PartitionSpecs."""
    def fn(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p))) for p in path)
        return _param_spec(names, leaf.shape, cfg)
    return jax.tree_util.tree_map_with_path(fn, params_tree)


# ---------------------------------------------------------------------------
# batch / activation rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch_tree):
    """Shard the leading (global batch) dim of every input over DP axes
    (replicated when the batch doesn't divide, e.g. long-context batch=1)."""
    axes = _batch_axes()
    bsz = batch_axis_size()

    def fn(leaf):
        if axes and leaf.shape and leaf.shape[0] % max(bsz, 1) == 0 \
                and leaf.shape[0] >= bsz:
            return P(axes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree.map(fn, batch_tree)


# ---------------------------------------------------------------------------
# cache rules (shape-aware: SP for long-context decode)
# ---------------------------------------------------------------------------

def _kv_spec(shape: tuple, cfg: ModelConfig, batch: int) -> P:
    """(L, B, T, K, hd) or (B, T, K, hd): batch over data if divisible,
    else sequence over data (SP); KV heads over model when divisible, else
    the *sequence* dim is sharded over model (flash-decode style split-KV),
    so the cache never replicates across the TP group."""
    dsize = _mesh_axis_size("data")
    msize = _mesh_axis_size("model")
    axes = _batch_axes()
    nd = len(shape)
    b_dim, t_dim, k_dim = nd - 4, nd - 3, nd - 2
    spec = [None] * nd
    if dsize > 1 and batch % batch_axis_size() == 0 and batch >= batch_axis_size():
        spec[b_dim] = axes
    elif dsize > 1 and shape[t_dim] % dsize == 0:
        spec[t_dim] = "data"                       # sequence parallelism
    kax = _model_axis(shape[k_dim])
    if kax is not None:
        spec[k_dim] = kax
    elif msize > 1 and spec[t_dim] is None and shape[t_dim] % msize == 0:
        spec[t_dim] = "model"                      # split-KV over TP group
    elif msize > 1 and spec[t_dim] == "data" and shape[t_dim] % (msize * dsize) == 0:
        spec[t_dim] = ("data", "model")            # long-context: both axes
    return P(*spec)


def logits_pspec(cfg: ModelConfig, batch_sharded: bool = True) -> P:
    """(B, S, V): batch over DP axes, vocab over model when divisible."""
    axes = _batch_axes()
    return P(axes if (axes and batch_sharded) else None, None,
             _model_axis(cfg.vocab_size))


def _state_spec(shape: tuple, cfg: ModelConfig, batch: int, head_dims) -> P:
    """Recurrent state: batch over data if divisible, else a head/channel dim
    over model.  ``head_dims`` = candidate trailing dims (negative indices)."""
    nd = len(shape)
    spec = [None] * nd
    if batch % max(batch_axis_size(), 1) == 0 and batch >= batch_axis_size() \
            and batch_axis_size() > 1:
        # find the batch dim: first dim whose size == batch
        for i, s in enumerate(shape):
            if s == batch:
                spec[i] = _batch_axes()
                break
    else:
        for d in head_dims:
            if _model_axis(shape[d]):
                spec[d] = "model"
                break
    return P(*spec)


def cache_pspecs(cfg: ModelConfig, cache_tree, batch: int):
    """PartitionSpecs for a serving cache pytree (family-aware)."""
    def fn(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p))) for p in path)
        name = names[-1] if names else ""
        if name == "index":
            return P()
        if name in ("k", "v", "mk", "mv", "k_scale", "v_scale"):
            return _kv_spec(leaf.shape, cfg, batch)
        if name in ("conv", "ssm", "mlstm", "slstm") or len(names) > 1 and \
                names[0] in ("mlstm", "slstm"):
            return _state_spec(leaf.shape, cfg, batch, head_dims=(-1, -2, -3))
        return _state_spec(leaf.shape, cfg, batch, head_dims=(-1, -2, -3))
    return jax.tree_util.tree_map_with_path(fn, cache_tree)


# ---------------------------------------------------------------------------
# optimizer-state rules
# ---------------------------------------------------------------------------

def opt_pspecs(params_specs, opt_state_tree):
    """Adam moments mirror parameter sharding; scalars replicated.

    ``opt_state_tree`` is {"m": params, "v": params, "step": scalar}-shaped.
    """
    return {
        "m": params_specs,
        "v": params_specs,
        "step": P(),
    }


def zero1_respec(specs_tree, shapes_tree):
    """ZeRO-1 (tier-2 'pipelined' movement mode applied to the optimizer):
    additionally shard the first still-replicated, divisible dim of every
    moment over ``data`` — GSPMD then lowers the gradient sync as
    reduce-scatter (+ all-gather of updates) instead of all-reduce."""
    dsize = _mesh_axis_size("data")

    def fn(spec, leaf):
        if leaf.ndim == 0 or dsize <= 1:
            return spec
        entries = list(tuple(spec)) + [None] * (leaf.ndim - len(tuple(spec)))
        flat = []
        for e in entries:
            flat.extend(e if isinstance(e, (tuple, list)) else [e])
        if "data" in flat:
            return spec
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % dsize == 0:
                entries[i] = "data"
                return P(*entries)
        return spec

    return jax.tree.map(fn, specs_tree, shapes_tree)
