"""AdamW from scratch (no optax), with global-norm clipping, decoupled weight
decay, and fp32 moments over any-parameter-dtype trees.

The state tree is ``{"m": like(params), "v": like(params), "step": i32[]}``
so sharding specs mirror parameter specs directly (see sharding.rules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # gradient compression (tier-2 size-thresholded offload control):
    # cast gradients to this dtype before the (GSPMD-inserted) reduction.
    grad_sync_dtype: Optional[str] = None        # e.g. "bfloat16"


def init(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.grad_sync_dtype:
        grads = jax.tree.map(
            lambda g: g.astype(jnp.dtype(cfg.grad_sync_dtype)), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
