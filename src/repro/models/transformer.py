"""Decoder-only transformer LM (dense and MoE) with scan-over-layers.

Used directly by the dense / moe / vlm families and as the building block of
the encoder-decoder and hybrid families.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    adtype,
    apply_mlp,
    apply_norm,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    softmax_cross_entropy,
    stack_init,
    unembed,
)
from repro.sharding import api as shard_api


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": norm_init(cfg),
        "attn": attn.attn_init(k1, cfg),
        "ln2": norm_init(cfg),
    }
    if cfg.num_experts:
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k3, cfg)
    return p


def block_apply(params, x, cfg: ModelConfig, positions):
    x = shard_api.constrain(x, "batch", None, None)
    h = apply_norm(params["ln1"], x, cfg)
    h = attn.self_attention(params["attn"], h, cfg, positions=positions)
    x = x + h
    h = apply_norm(params["ln2"], x, cfg)
    if cfg.num_experts:
        h, aux = moe_mod.moe_apply(params["moe"], h, cfg)
    else:
        h, aux = apply_mlp(params["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    x = x + h
    x = shard_api.constrain(x, "batch", None, None)
    return x, aux


def block_decode(params, x, cfg: ModelConfig, layer_k, layer_v, index):
    h = apply_norm(params["ln1"], x, cfg)
    h, layer_k, layer_v = attn.self_attention_decode(
        params["attn"], h, cfg, layer_k=layer_k, layer_v=layer_v, index=index)
    x = x + h
    h = apply_norm(params["ln2"], x, cfg)
    if cfg.num_experts:
        h, _ = moe_mod.moe_apply(params["moe"], h, cfg)
    else:
        h = apply_mlp(params["mlp"], h, cfg)
    return x + h, layer_k, layer_v


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "embed": embed_init(k1, cfg),
        "blocks": stack_init(k2, cfg.num_layers, lambda k: block_init(k, cfg)),
        "final_norm": norm_init(cfg),
    }


def apply_blocks(params, h, cfg: ModelConfig, positions):
    """h: (B, S, D) -> (h, aux_sum); scan over the stacked layer params."""
    def body(carry, layer_params):
        carry, aux = block_apply(layer_params, carry, cfg, positions)
        return carry, aux
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, auxs = jax.lax.scan(body, h, params["blocks"])
    return h, jnp.sum(auxs)


def apply_blocks_decode(params, h, cfg: ModelConfig, cache):
    """h: (B,1,D); cache: stacked (L,B,T,K,hd) k/v + index (B,)."""
    index = cache["index"]

    def body(carry, xs):
        layer_params, lk, lv = xs
        carry, lk, lv = block_decode(layer_params, carry, cfg, lk, lv, index)
        return carry, (lk, lv)

    h, (new_k, new_v) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
    new_cache = {"k": new_k, "v": new_v, "index": index + 1}
    return h, new_cache


def hidden_to_logits(params, h, cfg: ModelConfig):
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    return shard_api.constrain(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# training forward / loss
# ---------------------------------------------------------------------------

AUX_LOSS_WEIGHT = 0.01


def lm_loss(params, batch, cfg: ModelConfig):
    """batch: {tokens (B,S), labels (B,S)} -> (loss, metrics)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)
    h = shard_api.constrain(h, "batch", None, None)
    positions = jnp.arange(s)[None, :]
    h, aux = apply_blocks(params, h, cfg, positions)
    logits = hidden_to_logits(params, h, cfg)
    mask = batch.get("loss_mask")
    ce, count = softmax_cross_entropy(logits, batch["labels"], mask)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": count}


# ---------------------------------------------------------------------------
# serving forward
# ---------------------------------------------------------------------------

def lm_prefill(params, batch, cfg: ModelConfig, max_len: int | None = None):
    """Prefill over the prompt; returns (last-token logits, KV cache).

    The cache is sized to ``max_len`` (defaults to prompt length).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    t = max_len or s
    h = embed_tokens(params["embed"], tokens, cfg)
    positions = jnp.arange(s)[None, :]

    def body(carry, layer_params):
        x = carry
        hn = apply_norm(layer_params["ln1"], x, cfg)
        q, k, v = attn.project_qkv(layer_params["attn"], hn, cfg, positions)
        if attn._use_blockwise(s, s):
            o = attn.attend_blockwise(q, k, v, cfg, causal=True)
        else:
            o = attn.attend(q, k, v, cfg, attn.causal_mask(s))
        x = x + attn.project_out(layer_params["attn"], o, x.dtype)
        hn = apply_norm(layer_params["ln2"], x, cfg)
        if cfg.num_experts:
            hn, _ = moe_mod.moe_apply(layer_params["moe"], hn, cfg)
        else:
            hn = apply_mlp(layer_params["mlp"], hn, cfg)
        x = x + hn
        if t > s:
            pad = ((0, 0), (0, t - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"])
    logits = hidden_to_logits(params, h[:, -1:, :], cfg)
    # cache layout is imposed by the caller via out_shardings (shape-aware:
    # sequence-sharded for long-context, batch-sharded otherwise)
    cache = {"k": ks, "v": vs, "index": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def lm_decode_step(params, cache, tokens, cfg: ModelConfig):
    """tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    h = embed_tokens(params["embed"], tokens, cfg)
    h, cache = apply_blocks_decode(params, h, cfg, cache)
    logits = hidden_to_logits(params, h, cfg)
    return logits, cache


def lm_decode_step_inplace(params, cache, tokens, cfg: ModelConfig,
                           sp_axis: str | None = None, sp_batch_axes=None):
    """Optimized decode (§Perf): the cache is a scan *carry* updated with
    O(1)-token writes (no per-layer cache rewrite), and attention runs over
    the stale cache merged with the current token's k/v.  With ``sp_axis``
    the sequence-sharded cache is attended via shard_map split-KV partials
    (only (B,H) statistics cross the interconnect).  Supports int8-quantized
    caches (``k_scale``/``v_scale`` present): values are dequantized at use,
    new tokens quantized at write — halves cache traffic vs bf16."""
    index = cache["index"]
    h = embed_tokens(params["embed"], tokens, cfg)
    n_layers = cache["k"].shape[0]
    quant = "k_scale" in cache

    def body(carry, xs):
        if quant:
            h, ck, cv, cks, cvs = carry
        else:
            h, ck, cv = carry
        layer_params, li = xs
        x = shard_api.constrain(h, "batch", None, None)
        hn = apply_norm(layer_params["ln1"], x, cfg)
        positions = index[:, None]
        q, k_new, v_new = attn.project_qkv(layer_params["attn"], hn, cfg,
                                           positions)
        # Megatron-style decode: activations cross the TP group (MBs), the
        # weights stay put — see EXPERIMENTS.md §Perf (decode cell)
        q = shard_api.constrain(q, "batch", None, None, None)
        k_new = shard_api.constrain(k_new, "batch", None, None, None)
        v_new = shard_api.constrain(v_new, "batch", None, None, None)
        lk = jax.lax.dynamic_index_in_dim(ck, li, 0, keepdims=False)
        lv = jax.lax.dynamic_index_in_dim(cv, li, 0, keepdims=False)
        if quant:
            lks = jax.lax.dynamic_index_in_dim(cks, li, 0, keepdims=False)
            lvs = jax.lax.dynamic_index_in_dim(cvs, li, 0, keepdims=False)
            lk = attn.dequantize_kv(lk, lks, q.dtype)
            lv = attn.dequantize_kv(lv, lvs, q.dtype)
        if sp_axis:
            o = attn.sp_decode_attention(q, lk, lv, k_new, v_new, cfg, index,
                                         axis=sp_axis,
                                         batch_axes=sp_batch_axes)
        else:
            o = attn.decode_attention_merged(q, lk, lv, k_new, v_new, cfg,
                                             index)
        x = x + attn.project_out(layer_params["attn"], o, x.dtype)
        x = shard_api.constrain(x, "batch", None, None)
        hn = apply_norm(layer_params["ln2"], x, cfg)
        if cfg.num_experts:
            hn, _ = moe_mod.moe_apply(layer_params["moe"], hn, cfg)
        else:
            hn = apply_mlp(layer_params["mlp"], hn, cfg)
        x = shard_api.constrain(x + hn, "batch", None, None)

        # O(1)-token in-place cache write at (layer li, batch b, index_b)
        def write(c, new):
            def one(cb, nb, idx):     # cb (L,T,K,hd); nb (1,K,hd)
                return jax.lax.dynamic_update_slice(
                    cb, nb[None].astype(cb.dtype), (li, idx, 0, 0))
            return jax.vmap(one, in_axes=(1, 0, 0), out_axes=1)(c, new, index)
        if quant:
            kq, ks = attn.quantize_kv(k_new)
            vq, vs = attn.quantize_kv(v_new)
            ck, cv = write(ck, kq), write(cv, vq)
            cks, cvs = write(cks, ks), write(cvs, vs)
            return (x, ck, cv, cks, cvs), None
        ck = write(ck, k_new)
        cv = write(cv, v_new)
        return (x, ck, cv), None

    if quant:
        carry0 = (h, cache["k"], cache["v"], cache["k_scale"],
                  cache["v_scale"])
    else:
        carry0 = (h, cache["k"], cache["v"])
    out_carry, _ = jax.lax.scan(
        body, carry0, (params["blocks"], jnp.arange(n_layers)))
    h = out_carry[0]
    logits = hidden_to_logits(params, h, cfg)
    new_cache = {"k": out_carry[1], "v": out_carry[2], "index": index + 1}
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = out_carry[3], out_carry[4]
    return logits, new_cache


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return attn.init_kv_cache(cfg, batch, max_len, cfg.num_layers)
