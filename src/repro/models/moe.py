"""Mixture-of-Experts layer: top-k token-choice routing with capacity.

GShard-style grouped one-hot dispatch (einsum form) so GSPMD can shard the
expert dimension over the ``model`` axis (expert parallelism) and insert the
dispatch collectives.  Tokens are processed in groups of ``GROUP_SIZE`` to
bound the dispatch-tensor working set (the same size-threshold discipline
the paper applies to offloaded transfers).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, pdtype
from repro.sharding import api as shard_api

GROUP_SIZE = 512


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = pdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), dt),
        "we_g": dense_init(k2, (e, d, f), dt),
        "we_u": dense_init(k3, (e, d, f), dt),
        "we_d": dense_init(k4, (e, f, d), dt),
    }


def moe_param_count(cfg: ModelConfig) -> int:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return d * e + 3 * e * d * f


def expert_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens_per_group * cfg.num_experts_per_token
                  / cfg.num_experts * cfg.moe_capacity_factor)
    return max(c, 1)


def moe_apply(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss). Top-k routing with capacity dropping."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_token
    tokens = x.reshape(b * s, d)
    tg = min(GROUP_SIZE, b * s)
    ng = (b * s) // tg
    xt = tokens[: ng * tg].reshape(ng, tg, d)
    cap = expert_capacity(tg, cfg)

    # --- routing (fp32) -----------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (g, t, e)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # (g, t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize

    # --- position-in-expert with capacity, k priority order ------------------
    dispatch = jnp.zeros((ng, tg, e, cap), x.dtype)
    combine = jnp.zeros((ng, tg, e, cap), jnp.float32)
    counts = jnp.zeros((ng, e), jnp.int32)
    for kk in range(k):
        m = jax.nn.one_hot(idx[..., kk], e, dtype=jnp.int32)          # (g,t,e)
        pos = jnp.cumsum(m, axis=1) - m + counts[:, None, :]          # (g,t,e)
        keep = (pos < cap) & (m > 0)
        poh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
        dispatch = dispatch + poh
        combine = combine + poh.astype(jnp.float32) * gate_vals[..., kk][..., None, None]
        counts = counts + jnp.sum(m, axis=1)

    # --- expert computation (sharded over the expert axis) -------------------
    ein = jnp.einsum("gtec,gtd->egcd", dispatch, xt)
    ein = shard_api.constrain(ein, "expert", "batch", None, None)
    wg = params["we_g"].astype(ein.dtype)
    wu = params["we_u"].astype(ein.dtype)
    wd = params["we_d"].astype(ein.dtype)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", ein, wg)) \
        * jnp.einsum("egcd,edf->egcf", ein, wu)
    eout = jnp.einsum("egcf,efd->egcd", h, wd)
    eout = shard_api.constrain(eout, "expert", "batch", None, None)
    y = jnp.einsum("egcd,gtec->gtd", eout, combine.astype(eout.dtype))

    # --- load-balancing aux loss (switch-style) -------------------------------
    # fraction of tokens whose top-1 choice is expert e  ×  mean router prob
    top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_p)

    y = y.reshape(ng * tg, d)
    if ng * tg < b * s:                                      # ragged tail
        tail = tokens[ng * tg:]
        y = jnp.concatenate([y, jnp.zeros_like(tail)], axis=0)
    return y.reshape(b, s, d), aux
