"""xLSTM blocks: mLSTM (matrix-memory, parallelizable) and sLSTM (scalar
memory, sequential scan) [arXiv:2405.04517].

The mLSTM parallel (training) form and the recurrent (decode) form are kept
numerically consistent — a property test asserts their equivalence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    dense_init,
    norm_init,
    apply_norm,
    ones_init,
    pdtype,
    zeros_init,
)
from repro.models.ssm import causal_conv, conv_step

NEG_INF = -1e30


def mlstm_dims(cfg: ModelConfig):
    d_inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    hd = d_inner // cfg.num_heads
    return d_inner, hd


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, hd = mlstm_dims(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    return {
        "ln": norm_init(cfg),
        "up_proj": dense_init(ks[0], (d, 2 * d_inner), dt),
        "conv_w": dense_init(ks[1], (4, d_inner), dt, scale=0.5),
        "conv_b": zeros_init((d_inner,), dt),
        "wq": dense_init(ks[2], (d_inner, d_inner), dt),
        "wk": dense_init(ks[3], (d_inner, d_inner), dt),
        "wv": dense_init(ks[4], (d_inner, d_inner), dt),
        "w_i": dense_init(ks[5], (d_inner, cfg.num_heads), dt),
        "b_i": zeros_init((cfg.num_heads,), jnp.float32),
        "w_f": dense_init(ks[6], (d_inner, cfg.num_heads), dt),
        "b_f": jnp.full((cfg.num_heads,), 3.0, jnp.float32),   # open forget gates
        "gn_scale": ones_init((d_inner,), dt),
        "down_proj": dense_init(ks[7], (d_inner, d), dt),
    }


def mlstm_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner, _ = mlstm_dims(cfg)
    h = cfg.num_heads
    return (d * 2 * d_inner + 4 * d_inner + d_inner
            + 3 * d_inner * d_inner + 2 * d_inner * h + 2 * h
            + d_inner + d_inner * d + 2 * d)   # + block layernorm


def _mlstm_qkv_gates(params, x_in, cfg: ModelConfig):
    """x_in: (B,S,d_inner) pre-conv path. Returns q,k,v (B,S,H,hd), i,f (B,S,H)."""
    b, s, d_inner = x_in.shape
    h = cfg.num_heads
    hd = d_inner // h
    x_conv = jax.nn.silu(causal_conv(x_in, params["conv_w"], params["conv_b"]))
    q = jnp.einsum("bsd,de->bse", x_conv, params["wq"].astype(x_in.dtype))
    k = jnp.einsum("bsd,de->bse", x_conv, params["wk"].astype(x_in.dtype))
    v = jnp.einsum("bsd,de->bse", x_in, params["wv"].astype(x_in.dtype))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, h, hd)
    v = v.reshape(b, s, h, hd)
    i_raw = jnp.einsum("bsd,dh->bsh", x_conv, params["w_i"].astype(x_in.dtype))
    f_raw = jnp.einsum("bsd,dh->bsh", x_conv, params["w_f"].astype(x_in.dtype))
    i_raw = i_raw.astype(jnp.float32) + params["b_i"]
    f_raw = f_raw.astype(jnp.float32) + params["b_f"]
    return q, k, v, i_raw, f_raw, x_conv


def _headwise_groupnorm(y, scale, eps=1e-6):
    """y: (B,S,H,hd) — layernorm per head, then flatten and scale."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, hd = y.shape
    return yn.reshape(b, s, h * hd) * scale.astype(jnp.float32)


def mlstm_parallel(q, k, v, i_raw, f_raw):
    """Stabilized parallel mLSTM (xLSTM paper eq. 29-33).

    q,k,v: (B,S,H,hd); i_raw,f_raw: (B,S,H) fp32. Returns (B,S,H,hd).
    """
    b, s, h, hd = q.shape
    logf = jax.nn.log_sigmoid(f_raw)                       # (B,S,H)
    fcum = jnp.cumsum(logf, axis=1)                        # F_j inclusive
    # D[j,i] = F_j - F_i + i_i   for i <= j   (decay from i+1..j, gate i_i)
    dmat = (fcum[:, :, None, :] - fcum[:, None, :, :]
            + i_raw[:, None, :, :])                        # (B,j,i,H)
    mask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, NEG_INF)
    mstab = jnp.max(dmat, axis=2)                          # (B,j,H)
    dexp = jnp.exp(dmat - mstab[:, :, None, :])
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bjhe,bihe->bjih", q, k).astype(jnp.float32) * scale
    w = scores * dexp                                      # (B,j,i,H)
    denom = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-mstab))  # (B,j,H)
    y = jnp.einsum("bjih,bihe->bjhe", w, v.astype(jnp.float32))
    return (y / denom[..., None]).astype(q.dtype)


def mlstm_chunked(q, k, v, i_raw, f_raw, chunk: int = 1024, state=None):
    """Chunk-scanned stabilized mLSTM, numerically equal to the recurrent
    form: carried (C, n, m) state across chunks; quadratic tensors exist one
    chunk at a time.

    q,k,v: (B,S,H,hd); i_raw,f_raw: (B,S,H) fp32. Returns (y, (C, n, m)).
    """
    b, s, h, hd = q.shape
    cq = min(chunk, s)
    s_orig = s
    if s % cq:    # pad with identity steps: f=+inf (decay 1), i=-inf, qkv=0
        pad = cq - s % cq
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        q, k, v = map(zpad, (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=NEG_INF)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)), constant_values=40.0)
        s = s + pad
    nc = s // cq
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    mask = jnp.tril(jnp.ones((cq, cq), bool))
    if state is None:
        C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, fc = xs                    # (b,cq,...) one chunk
        logf = jax.nn.log_sigmoid(fc)              # (b,cq,h)
        fcum = jnp.cumsum(logf, axis=1)            # F_j inclusive
        # D[j,i] = F_j - F_i + i_i (i <= j); carry term: F_j + m_prev
        dmat = (fcum[:, :, None, :] - fcum[:, None, :, :] + ic[:, None, :, :])
        dmat = jnp.where(mask[None, :, :, None], dmat, NEG_INF)
        carry_log = fcum + m[:, None, :]           # (b,j,h)
        m_new = jnp.maximum(jnp.max(dmat, axis=2), carry_log)  # rowwise (b,j,h)
        dexp = jnp.exp(dmat - m_new[:, :, None, :])
        cscale = jnp.exp(carry_log - m_new)        # (b,j,h)
        scores = jnp.einsum("bjhe,bihe->bjih", qc, kc).astype(jnp.float32) * scale
        w = scores * dexp
        qf = qc.astype(jnp.float32)
        num = jnp.einsum("bjih,bihe->bjhe", w, vc.astype(jnp.float32)) \
            + jnp.einsum("bjhe,bhef->bjhf", qf, C) * cscale[..., None]
        # denominator: sum_i exp(D-m)(q_j.k_i)/sqrt(d) + cscale*(q_j.n_prev)
        den = jnp.sum(w, axis=2) + jnp.einsum("bjhe,bhe->bjh", qf, n) * cscale
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        y = num / den[..., None]
        # end-of-chunk state (recurrent semantics at position cq)
        m_end = m_new[:, -1, :]                    # (b,h)
        dlast = fcum[:, -1:, :] - fcum + ic        # D_{Q,i}: (b,i,h)
        wts = jnp.exp(dlast - m_end[:, None, :])   # (b,i,h)
        kf = kc.astype(jnp.float32) * scale
        C_new = jnp.exp(carry_log[:, -1] - m_end)[..., None, None] * C \
            + jnp.einsum("bih,bihe,bihf->bhef", wts, kf, vc.astype(jnp.float32))
        n_new = jnp.exp(carry_log[:, -1] - m_end)[..., None] * n \
            + jnp.einsum("bih,bihe->bhe", wts, kf)
        return (C_new, n_new, m_end), y.astype(qc.dtype)

    xs = tuple(jnp.moveaxis(t.reshape(b, nc, cq, *t.shape[2:]), 1, 0)
               for t in (q, k, v, i_raw, f_raw))
    (C, n, m), ys = jax.lax.scan(jax.checkpoint(body), (C0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    return y[:, :s_orig], (C, n, m)


def mlstm_recurrent_step(state, q, k, v, i_raw, f_raw):
    """One-token mLSTM. state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)).

    q,k,v: (B,H,hd); i_raw,f_raw: (B,H) fp32.
    """
    C, n, m = state
    hd = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    fsc = jnp.exp(logf + m - m_new)[..., None]
    isc = jnp.exp(i_raw - m_new)[..., None]
    kf = k.astype(jnp.float32) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    vf = v.astype(jnp.float32)
    C_new = fsc[..., None] * C + isc[..., None] * kf[..., :, None] * vf[..., None, :]
    n_new = fsc * n + isc * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhe,bhef->bhf", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", qf, n_new)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(q.dtype)
    return (C_new, n_new, m_new), y


MLSTM_CHUNK_THRESHOLD = 2048


def mlstm_block_apply(params, x, cfg: ModelConfig):
    """x: (B,S,D) -> (B,S,D). Parallel form for short sequences; chunk-scanned
    (bounded working set) above ``MLSTM_CHUNK_THRESHOLD``."""
    h = apply_norm(params["ln"], x, cfg)
    up = jnp.einsum("bsd,de->bse", h, params["up_proj"].astype(x.dtype))
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_raw, f_raw, _ = _mlstm_qkv_gates(params, x_in, cfg)
    if x.shape[1] > MLSTM_CHUNK_THRESHOLD:
        y, _ = mlstm_chunked(q, k, v, i_raw, f_raw)
    else:
        y = mlstm_parallel(q, k, v, i_raw, f_raw)
    y = _headwise_groupnorm(y, params["gn_scale"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(x.dtype))


def mlstm_block_decode(params, x, cfg: ModelConfig, state):
    """x: (B,1,D) one-token decode; state = (C, n, m, conv_state)."""
    C, n, m, conv_state = state
    h = apply_norm(params["ln"], x, cfg)
    up = jnp.einsum("bsd,de->bse", h, params["up_proj"].astype(x.dtype))
    x_in, z = jnp.split(up[:, 0], 2, axis=-1)              # (B, d_inner)
    y_conv, conv_state = conv_step(x_in, conv_state, params["conv_w"], params["conv_b"])
    x_conv = jax.nn.silu(y_conv)
    b = x.shape[0]
    nh = cfg.num_heads
    hd = x_in.shape[-1] // nh
    q = (x_conv @ params["wq"].astype(x.dtype)).reshape(b, nh, hd)
    k = (x_conv @ params["wk"].astype(x.dtype)).reshape(b, nh, hd)
    v = (x_in @ params["wv"].astype(x.dtype)).reshape(b, nh, hd)
    i_raw = (x_conv @ params["w_i"].astype(x.dtype)).astype(jnp.float32) + params["b_i"]
    f_raw = (x_conv @ params["w_f"].astype(x.dtype)).astype(jnp.float32) + params["b_f"]
    (C, n, m), y = mlstm_recurrent_step((C, n, m), q, k, v, i_raw, f_raw)
    y = _headwise_groupnorm(y[:, None, :, :], params["gn_scale"])[:, 0]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = x + (y @ params["down_proj"].astype(x.dtype))[:, None, :]
    return out, (C, n, m, conv_state)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_inner, hd = mlstm_dims(cfg)
    h = cfg.num_heads
    return (
        jnp.zeros((batch, h, hd, hd), jnp.float32),
        jnp.zeros((batch, h, hd), jnp.float32),
        jnp.full((batch, h), -1e30, jnp.float32),
        jnp.zeros((batch, 3, d_inner), jnp.dtype(cfg.dtype)),   # conv width 4
    )


# ---------------------------------------------------------------------------
# sLSTM block (sequential scan; scalar-memory cells with recurrent mixing)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    dt = pdtype(cfg)
    ks = jax.random.split(key, 10)
    d_ff = int(d * 4 / 3)
    return {
        "ln": norm_init(cfg),
        # input projections for gates z, i, f, o
        "wz": dense_init(ks[0], (d, d), dt),
        "wi": dense_init(ks[1], (d, d), dt),
        "wf": dense_init(ks[2], (d, d), dt),
        "wo": dense_init(ks[3], (d, d), dt),
        # per-head recurrent (block-diagonal) mixing
        "rz": dense_init(ks[4], (h, hd, hd), dt),
        "ri": dense_init(ks[5], (h, hd, hd), dt),
        "rf": dense_init(ks[6], (h, hd, hd), dt),
        "ro": dense_init(ks[7], (h, hd, hd), dt),
        "b_z": zeros_init((d,), jnp.float32),
        "b_i": zeros_init((d,), jnp.float32),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
        "b_o": zeros_init((d,), jnp.float32),
        "gn_scale": ones_init((d,), dt),
        # post-FFN (proj factor 4/3, gelu)
        "ln2": norm_init(cfg),
        "ffn_wi": dense_init(ks[8], (d, d_ff), dt),
        "ffn_wd": dense_init(ks[9], (d_ff, d), dt),
    }


def slstm_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    d_ff = int(d * 4 / 3)
    return (4 * d * d + 4 * h * hd * hd + 4 * d + d
            + 2 * d * d_ff + 4 * d)   # + 2 norms


def slstm_scan(params, x_gates, cfg: ModelConfig, state):
    """x_gates: dict of per-step gate preactivations (B,S,D). Sequential scan."""
    b, s, d = x_gates["z"].shape
    h = cfg.num_heads
    hd = d // h

    def step(carry, xs):
        c, n, m, hprev = carry                     # all (B,H,hd) / m (B,H,hd)
        zx, ix, fx, ox = xs                        # (B,D) fp32
        def mix(r, hp):
            return jnp.einsum("bhe,hef->bhf", hp, r.astype(jnp.float32))
        hp = hprev
        z = jnp.tanh(zx.reshape(b, h, hd) + mix(params["rz"], hp))
        it = ix.reshape(b, h, hd) + mix(params["ri"], hp)
        ft = fx.reshape(b, h, hd) + mix(params["rf"], hp)
        ot = jax.nn.sigmoid(ox.reshape(b, h, hd) + mix(params["ro"], hp))
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = jnp.maximum(f_p * n + i_p, 1e-6)
        h_new = ot * c_new / n_new
        return (c_new, n_new, m_new, h_new), h_new

    xs = tuple(jnp.moveaxis(x_gates[g].astype(jnp.float32), 1, 0)
               for g in ("z", "i", "f", "o"))
    (c, n, m, hlast), hs = jax.lax.scan(step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)   # (B,S,D)
    return hs, (c, n, m, hlast)


def slstm_block_apply(params, x, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    hn = apply_norm(params["ln"], x, cfg)
    gates = {
        "z": hn @ params["wz"].astype(x.dtype) + params["b_z"].astype(x.dtype),
        "i": hn @ params["wi"].astype(x.dtype) + params["b_i"].astype(x.dtype),
        "f": hn @ params["wf"].astype(x.dtype) + params["b_f"].astype(x.dtype),
        "o": hn @ params["wo"].astype(x.dtype) + params["b_o"].astype(x.dtype),
    }
    if state is None:
        state = init_slstm_state(cfg, b)
    hs, state = slstm_scan(params, gates, cfg, state)
    hs = hs.astype(jnp.float32) * params["gn_scale"].astype(jnp.float32)
    x = x + hs.astype(x.dtype)
    hn = apply_norm(params["ln2"], x, cfg)
    ff = jax.nn.gelu(hn @ params["ffn_wi"].astype(x.dtype)) \
        @ params["ffn_wd"].astype(x.dtype)
    return x + ff, state


def slstm_block_decode(params, x, cfg: ModelConfig, state):
    out, state = slstm_block_apply(params, x, cfg, state)
    return out, state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return (z, z + 1e-6, z - 1e30, z)


def mlstm_block_prefill(params, x, cfg: ModelConfig):
    """Full-sequence forward that also returns the end-of-sequence state."""
    h = apply_norm(params["ln"], x, cfg)
    up = jnp.einsum("bsd,de->bse", h, params["up_proj"].astype(x.dtype))
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_raw, f_raw, _ = _mlstm_qkv_gates(params, x_in, cfg)
    y, (C, n, m) = mlstm_chunked(q, k, v, i_raw, f_raw)
    s = x.shape[1]
    conv_state = x_in[:, -3:, :] if s >= 3 else jnp.pad(
        x_in, ((0, 0), (3 - s, 0), (0, 0)))
    y = _headwise_groupnorm(y, params["gn_scale"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = x + jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(x.dtype))
    return out, (C, n, m, conv_state)


# ---------------------------------------------------------------------------
# xLSTM language model assembly
#
# Blocks are organized in groups of ``slstm_every``: (slstm_every - 1) mLSTM
# blocks followed by one sLSTM block, scanned over groups so the HLO stays
# compact for deep stacks.
# ---------------------------------------------------------------------------

from repro.models.layers import embed_init, embed_tokens, softmax_cross_entropy, stack_init, unembed  # noqa: E402
from repro.sharding import api as shard_api  # noqa: E402


def _xlstm_group_counts(cfg: ModelConfig):
    per = cfg.slstm_every
    assert cfg.num_layers % per == 0, "num_layers must divide by slstm_every"
    return cfg.num_layers // per, per - 1


def xlstm_lm_init(key, cfg: ModelConfig):
    groups, m_per = _xlstm_group_counts(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": embed_init(k1, cfg),
        "mblocks": stack_init(
            k2, groups,
            lambda kk: stack_init(kk, m_per, lambda k3_: mlstm_init(k3_, cfg))),
        "sblocks": stack_init(k3, groups, lambda kk: slstm_init(kk, cfg)),
        "final_norm": norm_init(cfg),
    }


def _xlstm_group_apply(mparams, sparams, h, cfg: ModelConfig):
    def mbody(hh, mp):
        return mlstm_block_apply(mp, hh, cfg), None
    h, _ = jax.lax.scan(mbody, h, mparams)
    h, _ = slstm_block_apply(sparams, h, cfg)
    return h


def xlstm_lm_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens, cfg)
    h = shard_api.constrain(h, "batch", None, None)

    def gbody(hh, xs):
        mp, sp = xs
        return _xlstm_group_apply(mp, sp, hh, cfg), None
    body = jax.checkpoint(gbody, prevent_cse=False) if cfg.remat else gbody
    h, _ = jax.lax.scan(body, h, (params["mblocks"], params["sblocks"]))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    logits = shard_api.constrain(logits, "batch", None, "model")
    ce, count = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32), "tokens": count}


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Recurrent state per block; no KV growth with sequence length."""
    groups, m_per = _xlstm_group_counts(cfg)

    def rep(x, *lead):
        return jnp.broadcast_to(x, (*lead, *x.shape))
    C, n, m, conv = init_mlstm_state(cfg, batch)
    ms = tuple(rep(t, groups, m_per) for t in (C, n, m, conv))
    ss = tuple(rep(t, groups) for t in init_slstm_state(cfg, batch))
    return {"mlstm": ms, "slstm": ss,
            "index": jnp.zeros((batch,), jnp.int32)}


def xlstm_lm_prefill(params, batch, cfg: ModelConfig, max_len=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)

    def gbody(hh, xs):
        mp, sp = xs
        def mbody(hhh, mpp):
            out, st = mlstm_block_prefill(mpp, hhh, cfg)
            return out, st
        hh, mstates = jax.lax.scan(mbody, hh, mp)
        hh, sstate = slstm_block_apply(sp, hh, cfg)
        return hh, (mstates, sstate)
    body = jax.checkpoint(gbody, prevent_cse=False) if cfg.remat else gbody
    h, (mstates, sstates) = jax.lax.scan(body, h, (params["mblocks"], params["sblocks"]))
    h = apply_norm(params["final_norm"], h[:, -1:, :], cfg)
    logits = unembed(params["embed"], h, cfg)
    cache = {"mlstm": mstates, "slstm": sstates,
             "index": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def xlstm_lm_decode_step(params, cache, tokens, cfg: ModelConfig):
    h = embed_tokens(params["embed"], tokens, cfg)

    def gbody(hh, xs):
        mp, sp, mstate, sstate = xs
        def mbody(hhh, xs2):
            mpp, st = xs2
            out, st = mlstm_block_decode(mpp, hhh, cfg, st)
            return out, st
        hh, mstate = jax.lax.scan(mbody, hh, (mp, mstate))
        hh, sstate = slstm_block_decode(sp, hh, cfg, sstate)
        return hh, (mstate, sstate)

    h, (ms, ss) = jax.lax.scan(
        gbody, h,
        (params["mblocks"], params["sblocks"], cache["mlstm"], cache["slstm"]))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    new_cache = {"mlstm": ms, "slstm": ss, "index": cache["index"] + 1}
    return logits, new_cache
