from repro.models.registry import ModelAPI, build_model, count_params_analytic

__all__ = ["ModelAPI", "build_model", "count_params_analytic"]
