"""Model registry: a uniform API over the 10 assigned architecture families."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[Any], Any]                  # rng -> params
    loss: Callable[[Any, Any], Any]             # (params, batch) -> (loss, metrics)
    prefill: Callable[..., Any]                 # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable[[Any, Any, Any], Any] # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable[..., Any]              # (batch, max_len) -> cache


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe"):
        from repro.models import transformer as t
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(t.lm_init, cfg=cfg),
            loss=functools.partial(t.lm_loss, cfg=cfg),
            prefill=functools.partial(t.lm_prefill, cfg=cfg),
            decode_step=functools.partial(t.lm_decode_step, cfg=cfg),
            init_cache=functools.partial(t.lm_init_cache, cfg),
        )
    if fam == "vlm":
        from repro.models import vlm as v
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(v.vlm_init, cfg=cfg),
            loss=functools.partial(v.vlm_loss, cfg=cfg),
            prefill=functools.partial(v.vlm_prefill, cfg=cfg),
            decode_step=functools.partial(v.vlm_decode_step, cfg=cfg),
            init_cache=functools.partial(v.vlm_init_cache, cfg),
        )
    if fam == "audio":
        from repro.models import encdec as e
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(e.encdec_init, cfg=cfg),
            loss=functools.partial(e.encdec_loss, cfg=cfg),
            prefill=functools.partial(e.encdec_prefill, cfg=cfg),
            decode_step=functools.partial(e.encdec_decode_step, cfg=cfg),
            init_cache=functools.partial(e.encdec_init_cache, cfg),
        )
    if fam == "ssm":
        from repro.models import xlstm as x
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(x.xlstm_lm_init, cfg=cfg),
            loss=functools.partial(x.xlstm_lm_loss, cfg=cfg),
            prefill=functools.partial(x.xlstm_lm_prefill, cfg=cfg),
            decode_step=functools.partial(x.xlstm_lm_decode_step, cfg=cfg),
            init_cache=functools.partial(x.xlstm_init_cache, cfg),
        )
    if fam == "hybrid":
        from repro.models import hybrid as hb
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(hb.hybrid_lm_init, cfg=cfg),
            loss=functools.partial(hb.hybrid_lm_loss, cfg=cfg),
            prefill=functools.partial(hb.hybrid_lm_prefill, cfg=cfg),
            decode_step=functools.partial(hb.hybrid_lm_decode_step, cfg=cfg),
            init_cache=functools.partial(hb.hybrid_init_cache, cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# analytic parameter counts (for MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _param_count_cached(cfg: ModelConfig, active_only: bool) -> int:
    import math
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    total = sum(math.prod(x.shape) if x.shape else 1
                for x in jax.tree.leaves(shapes))
    if active_only and cfg.num_experts:
        e, k = cfg.num_experts, cfg.num_experts_per_token
        inactive = cfg.num_layers * 3 * (e - k) * cfg.d_model * cfg.d_ff
        total -= inactive
    return total


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    return _param_count_cached(cfg, active_only)


def count_flops_params(cfg: ModelConfig, kind: str) -> int:
    """Parameter count entering MODEL_FLOPS = {6,2}·N·D.

    Embedding-table *gathers* are not matmul FLOPs, and prefill computes
    logits for the final position only, so:
      train/decode: N = core + V·D (the unembedding matmul)
      prefill:      N = core
    where core excludes both embedding tables.
    """
    total = _param_count_cached(cfg, bool(cfg.num_experts))
    embed_vd = cfg.vocab_size * cfg.d_model
    untied_extra = 0 if cfg.tie_embeddings else embed_vd
    core = total - embed_vd - untied_extra
    if kind == "prefill":
        return core
    return core + embed_vd
