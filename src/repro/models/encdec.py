"""Encoder-decoder backbone (Seamless-M4T medium geometry).

The speech/text modality frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (batch, src_len, d_model).  The decoder
is a standard causal transformer with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    softmax_cross_entropy,
    stack_init,
    unembed,
)
from repro.sharding import api as shard_api


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg),
        "attn": attn.attn_init(k1, cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(k2, cfg),
    }


def dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg),
        "self_attn": attn.attn_init(k1, cfg),
        "lnx": norm_init(cfg),
        "cross_attn": attn.attn_init(k2, cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(k3, cfg),
    }


def enc_block_apply(params, x, cfg: ModelConfig, positions):
    h = apply_norm(params["ln1"], x, cfg)
    x = x + attn.self_attention(params["attn"], h, cfg, positions=positions,
                                causal=False)
    h = apply_norm(params["ln2"], x, cfg)
    return x + apply_mlp(params["mlp"], h, cfg)


def dec_block_apply(params, x, enc_out, cfg: ModelConfig, positions):
    h = apply_norm(params["ln1"], x, cfg)
    x = x + attn.self_attention(params["self_attn"], h, cfg, positions=positions)
    h = apply_norm(params["lnx"], x, cfg)
    mk, mv = attn.cross_attention_memory(params["cross_attn"], enc_out, cfg)
    x = x + attn.cross_attention(params["cross_attn"], h, mk, mv, cfg)
    h = apply_norm(params["ln2"], x, cfg)
    return x + apply_mlp(params["mlp"], h, cfg)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def encdec_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": embed_init(k1, cfg),
        "enc_blocks": stack_init(k2, cfg.enc_layers,
                                 lambda k: enc_block_init(k, cfg)),
        "dec_blocks": stack_init(k3, cfg.dec_layers,
                                 lambda k: dec_block_init(k, cfg)),
        "enc_final_norm": norm_init(cfg),
        "final_norm": norm_init(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, T, D) precomputed frame embeddings (frontend stub)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    h = shard_api.constrain(h, "batch", None, None)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(hh, bp):
        return enc_block_apply(bp, hh, cfg, positions), None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return apply_norm(params["enc_final_norm"], h, cfg)


def encdec_loss(params, batch, cfg: ModelConfig):
    """batch: {frame_embeds (B,T,D), tokens (B,S), labels (B,S)}."""
    enc_out = encode(params, batch["frame_embeds"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)
    positions = jnp.arange(s)[None, :]

    def body(hh, bp):
        return dec_block_apply(bp, hh, enc_out, cfg, positions), None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    logits = shard_api.constrain(logits, "batch", None, "model")
    ce, count = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32), "tokens": count}


# ---------------------------------------------------------------------------
# serving: prefill computes encoder output + decoder self-cache + per-layer
# cross-attention memory; decode is a one-token decoder step.
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      src_len: int | None = None, kv_dtype=None):
    hd = cfg.resolved_head_dim()
    kh = cfg.num_kv_heads
    dt = kv_dtype or jnp.dtype(cfg.dtype)
    src = src_len or max_len
    l = cfg.dec_layers
    return {
        "k": jnp.zeros((l, batch, max_len, kh, hd), dt),
        "v": jnp.zeros((l, batch, max_len, kh, hd), dt),
        "mk": jnp.zeros((l, batch, src, kh, hd), dt),
        "mv": jnp.zeros((l, batch, src, kh, hd), dt),
        "index": jnp.zeros((batch,), jnp.int32),
    }


def encdec_prefill(params, batch, cfg: ModelConfig, max_len=None):
    enc_out = encode(params, batch["frame_embeds"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    t = max_len or s
    h = embed_tokens(params["embed"], tokens, cfg)
    positions = jnp.arange(s)[None, :]

    def body(x, bp):
        hn = apply_norm(bp["ln1"], x, cfg)
        q, k, v = attn.project_qkv(bp["self_attn"], hn, cfg, positions)
        if attn._use_blockwise(s, s):
            o = attn.attend_blockwise(q, k, v, cfg, causal=True)
        else:
            o = attn.attend(q, k, v, cfg, attn.causal_mask(s))
        x = x + attn.project_out(bp["self_attn"], o, x.dtype)
        hn = apply_norm(bp["lnx"], x, cfg)
        mk, mv = attn.cross_attention_memory(bp["cross_attn"], enc_out, cfg)
        x = x + attn.cross_attention(bp["cross_attn"], hn, mk, mv, cfg)
        hn = apply_norm(bp["ln2"], x, cfg)
        x = x + apply_mlp(bp["mlp"], hn, cfg)
        if t > s:
            pad = ((0, 0), (0, t - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v, mk, mv)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (ks, vs, mks, mvs) = jax.lax.scan(body, h, params["dec_blocks"])
    h = apply_norm(params["final_norm"], h[:, -1:, :], cfg)
    logits = unembed(params["embed"], h, cfg)
    cache = {"k": ks, "v": vs, "mk": mks, "mv": mvs,
             "index": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def encdec_decode_step(params, cache, tokens, cfg: ModelConfig):
    h = embed_tokens(params["embed"], tokens, cfg)
    index = cache["index"]

    def body(x, xs):
        bp, lk, lv, mk, mv = xs
        hn = apply_norm(bp["ln1"], x, cfg)
        o, lk, lv = attn.self_attention_decode(
            bp["self_attn"], hn, cfg, layer_k=lk, layer_v=lv, index=index)
        x = x + o
        hn = apply_norm(bp["lnx"], x, cfg)
        x = x + attn.cross_attention(bp["cross_attn"], hn,
                                     mk.astype(x.dtype), mv.astype(x.dtype), cfg)
        hn = apply_norm(bp["ln2"], x, cfg)
        x = x + apply_mlp(bp["mlp"], hn, cfg)
        return x, (lk, lv)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["mk"], cache["mv"]))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    new_cache = {"k": ks, "v": vs, "mk": cache["mk"], "mv": cache["mv"],
                 "index": index + 1}
    return logits, new_cache
