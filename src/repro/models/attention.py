"""Grouped-query attention with RoPE, optional qk-norm, KV caching.

Execution paths (the paper's *offload control* knob, §IV-B, applied to the
attention hot-spot):

- ``direct``    — reference einsum path; scores materialize (small shapes);
- ``blockwise`` — memory-efficient streaming attention (double scan over
  query/key blocks with running log-sum-exp), the pure-XLA analogue of the
  pipelined DMA kernel: bounded working set, automatically selected above a
  size threshold (``BLOCKWISE_THRESHOLD`` score elements);
- ``flash``     — Pallas kernel (``repro.kernels.flash_attention``) with
  explicit VMEM DMA tiling, validated against ``direct`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_rope,
    dense_init,
    ones_init,
    pdtype,
    rms_normalize,
)

NEG_INF = -1e30
# size-threshold (paper Table III "Data Size"): switch to the streaming path
# once the score tensor would exceed this many elements per device.
BLOCKWISE_THRESHOLD = 2 ** 22
Q_BLOCK = 1024
K_BLOCK = 1024


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim()
    h, k = cfg.num_heads, cfg.num_kv_heads
    dt = pdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h, hd), dt),
        "wk": dense_init(k2, (d, k, hd), dt),
        "wv": dense_init(k3, (d, k, hd), dt),
        "wo": dense_init(k4, (h, hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), dt)
        p["k_norm"] = ones_init((hd,), dt)
    return p


def attn_param_count(cfg: ModelConfig, d_model: int | None = None) -> int:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim()
    n = 2 * d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
    if cfg.qk_norm:
        n += 2 * hd
    return n


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def project_qkv(params, x, cfg: ModelConfig, positions=None, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,hd), k (B,S,K,hd), v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_normalize(q, params["q_norm"])
        k = rms_normalize(k, params["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_out(params, o, x_dtype):
    """o: (B, S, H, hd) -> (B, S, D)."""
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(x_dtype))


# ---------------------------------------------------------------------------
# direct (reference) GQA attention
# ---------------------------------------------------------------------------

def _scores(qg, k, cfg: ModelConfig):
    hd = qg.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bskge,btke->bkgst", qg, k).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        s = c * jnp.tanh(s / c)
    return s


def attend(q, k, v, cfg: ModelConfig, mask):
    """q: (B,S,H,hd); k/v: (B,T,K,hd); mask broadcastable to (B,K,G,S,T)."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scores = jnp.where(mask, _scores(qg, k, cfg), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btke->bskge", w, v)
    return o.reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# blockwise streaming attention (memory-efficient; the inline analogue of
# the pipelined-DMA execution mode: bounded VMEM/registers working set)
# ---------------------------------------------------------------------------

def attend_blockwise(q, k, v, cfg: ModelConfig, *, causal: bool,
                     q_block: int = Q_BLOCK, k_block: int = K_BLOCK):
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qb = min(q_block, s)
    kb = min(k_block, t)
    nq, nk = s // qb, t // kb
    qg = q.reshape(b, nq, qb, kh, g, hd)
    kc = k.reshape(b, nk, kb, kh, hd)
    vc = v.reshape(b, nk, kb, kh, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block          # qblk: (b, qb, kh, g, hd)

        def kv_step(carry, kj_and_chunk):
            m, l, acc = carry
            kj, kchunk, vchunk = kj_and_chunk
            sc = jnp.einsum("bskge,btke->bkgst", qblk, kchunk)
            sc = sc.astype(jnp.float32) * scale
            if cfg.attn_logit_softcap:
                c = cfg.attn_logit_softcap
                sc = c * jnp.tanh(sc / c)
            if causal:
                qpos = qi * qb + jnp.arange(qb)
                kpos = kj * kb + jnp.arange(kb)
                msk = (kpos[None, :] <= qpos[:, None])[None, None, None]
                sc = jnp.where(msk, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgst,btke->bkgse", p.astype(qblk.dtype), vchunk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qb, hd), qblk.dtype)
        kv_body = jax.checkpoint(kv_step)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None].astype(acc.dtype))        # (b,kh,g,qb,hd)
        out = jnp.moveaxis(out, 3, 1).reshape(b, qb, kh * g, hd)
        return None, out

    _, blocks = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # blocks: (nq, b, qb, h, hd) -> (b, s, h, hd)
    return jnp.moveaxis(blocks, 0, 1).reshape(b, s, h, hd)


def _use_blockwise(s: int, t: int) -> bool:
    return s > 1 and s * t > BLOCKWISE_THRESHOLD


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def causal_mask(s: int, t: int | None = None, offset: int = 0):
    t = t or s
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    return (kj <= qi)[None, None, None]


def full_mask(s: int, t: int):
    return jnp.ones((1, 1, 1, s, t), bool)


def decode_mask(index, t: int):
    """index: (B,) current position; keys j <= index valid. -> (B,1,1,1,T)."""
    kj = jnp.arange(t)[None, :]
    return (kj <= index[:, None])[:, None, None, None]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  kv_dtype=None):
    hd = cfg.resolved_head_dim()
    kh = cfg.num_kv_heads
    dt = kv_dtype or jnp.dtype(cfg.dtype)
    shape = (n_layers, batch, max_len, kh, hd)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "index": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# int8 KV quantization (beyond-paper: halves decode cache traffic vs bf16)
# ---------------------------------------------------------------------------

def quantize_kv(x):
    """x (..., hd) -> (int8 values, fp scale per head-vector)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache_q8(cfg: ModelConfig, batch: int, max_len: int,
                     n_layers: int):
    hd = cfg.resolved_head_dim()
    kh = cfg.num_kv_heads
    shape = (n_layers, batch, max_len, kh, hd)
    sshape = (n_layers, batch, max_len, kh, 1)
    return {
        "k": jnp.zeros(shape, jnp.int8),
        "v": jnp.zeros(shape, jnp.int8),
        "k_scale": jnp.zeros(sshape, jnp.float32),
        "v_scale": jnp.zeros(sshape, jnp.float32),
        "index": jnp.zeros((batch,), jnp.int32),
    }


def cache_insert_prefill(layer_k, layer_v, k, v):
    lk = jax.lax.dynamic_update_slice(layer_k, k.astype(layer_k.dtype), (0, 0, 0, 0))
    lv = jax.lax.dynamic_update_slice(layer_v, v.astype(layer_v.dtype), (0, 0, 0, 0))
    return lk, lv


def cache_insert_token(layer_k, layer_v, k, v, index):
    """Insert one token's k/v (B,1,K,hd) at per-batch position ``index`` (B,)."""
    def upd(buf, new):
        def one(row_buf, row_new, idx):
            return jax.lax.dynamic_update_slice(
                row_buf, row_new.astype(row_buf.dtype), (idx, 0, 0))
        return jax.vmap(one)(buf, new, index)
    return upd(layer_k, k), upd(layer_v, v)


# ---------------------------------------------------------------------------
# block-level application
# ---------------------------------------------------------------------------

def self_attention(params, x, cfg: ModelConfig, *, positions, causal=True,
                   rope=True):
    s = x.shape[1]
    q, k, v = project_qkv(params, x, cfg, positions, rope=rope)
    if _use_blockwise(s, s):
        o = attend_blockwise(q, k, v, cfg, causal=causal)
    else:
        mask = causal_mask(s) if causal else full_mask(s, s)
        o = attend(q, k, v, cfg, mask)
    return project_out(params, o, x.dtype)


def self_attention_decode(params, x, cfg: ModelConfig, *, layer_k, layer_v,
                          index, rope=True):
    """One-token decode: x (B,1,D); cache layer (B,T,K,hd); index (B,)."""
    positions = index[:, None]                       # (B,1)
    q, k, v = project_qkv(params, x, cfg, positions, rope=rope)
    layer_k, layer_v = cache_insert_token(layer_k, layer_v, k, v, index)
    mask = decode_mask(index, layer_k.shape[1])
    o = attend(q, layer_k.astype(q.dtype), layer_v.astype(q.dtype), cfg, mask)
    return project_out(params, o, x.dtype), layer_k, layer_v


def _merge_new_token(o, l, m, q, k_new, v_new, cfg: ModelConfig):
    """Fold the current token's self-attention term into partial stats.

    o (B,K,G,1,hd) unnormalized; l,m (B,K,G,1); q (B,1,H,hd);
    k_new/v_new (B,1,K,hd).
    """
    b, _, h, hd = q.shape
    kh = k_new.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s_new = jnp.einsum("bskge,btke->bkgs", qg, k_new).astype(jnp.float32) * scale
    m2 = jnp.maximum(m, s_new)
    corr = jnp.exp(m - m2)
    w_new = jnp.exp(s_new - m2)
    o2 = o * corr[..., None] + jnp.einsum(
        "bkgs,btke->bkgse", w_new, v_new.astype(jnp.float32))
    l2 = l * corr + w_new
    return o2 / jnp.maximum(l2, 1e-30)[..., None]


def decode_attention_partial(q, layer_k, layer_v, cfg: ModelConfig, index,
                             pos_offset=0):
    """Unnormalized partial attention over a cache segment.

    Returns (o (B,K,G,1,hd) fp32 unnormalized, l (B,K,G,1), m (B,K,G,1)).
    ``pos_offset`` is the global position of the segment's first key.
    """
    b, _, h, hd = q.shape
    t, kh = layer_k.shape[1], layer_k.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bskge,btke->bkgst", qg, layer_k.astype(q.dtype))
    s = s.astype(jnp.float32) * scale                       # (B,K,G,1,T)
    kpos = pos_offset + jnp.arange(t)
    valid = (kpos[None, :] < index[:, None])                # cached keys only
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgst,btke->bkgse", p,
                   layer_v.astype(jnp.float32))
    return o, l, m


def sp_decode_attention(q, layer_k, layer_v, k_new, v_new, cfg: ModelConfig,
                        index, axis: str = "model", batch_axes=None):
    """Split-KV flash-decode: the cache's sequence dim is sharded over
    ``axis``; each shard computes local partial stats and only the (B,K,G)
    statistics cross the interconnect (psum log-sum-exp merge) — instead of
    all-gathering the cache (the paper's 'move the computation, not the
    bytes' applied to decode)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import api as shard_api

    mesh = shard_api.get_mesh()
    bx = batch_axes if batch_axes else None

    def local(q, lk, lv, index):
        tl = lk.shape[1]
        shard = jax.lax.axis_index(axis)
        o, l, m = decode_attention_partial(q, lk, lv, cfg, index,
                                           pos_offset=shard * tl)
        m_all = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_all)
        l_all = jax.lax.psum(l * corr, axis)
        o_all = jax.lax.psum(o * corr[..., None], axis)
        return o_all, l_all, m_all

    with shard_api.manual_mode():
        o, l, m = compat.shard_map(
            local, mesh=mesh,
            in_specs=(P(bx), P(bx, axis, None, None),
                      P(bx, axis, None, None), P(bx)),
            out_specs=(P(bx), P(bx), P(bx)), check_vma=False,
        )(q, layer_k, layer_v, index)
    o = _merge_new_token(o, l, m, q, k_new, v_new, cfg)
    b, _, h, hd = q.shape
    return o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention_merged(q, layer_k, layer_v, k_new, v_new,
                            cfg: ModelConfig, index):
    """Single-device equivalent of sp_decode_attention (no cache rewrite:
    attends the stale cache + the new token's k/v)."""
    o, l, m = decode_attention_partial(q, layer_k, layer_v, cfg, index)
    o = _merge_new_token(o, l, m, q, k_new, v_new, cfg)
    b, _, h, hd = q.shape
    return o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def cross_attention(params, x, memory_k, memory_v, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_normalize(q, params["q_norm"])
    s, t = x.shape[1], memory_k.shape[1]
    if _use_blockwise(s, t):
        o = attend_blockwise(q, memory_k, memory_v, cfg, causal=False)
    else:
        o = attend(q, memory_k, memory_v, cfg, full_mask(s, t))
    return project_out(params, o, x.dtype)


def cross_attention_memory(params, enc_out, cfg: ModelConfig):
    k = jnp.einsum("btd,dke->btke", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dke->btke", enc_out, params["wv"].astype(enc_out.dtype))
    if cfg.qk_norm:
        k = rms_normalize(k, params["k_norm"])
    return k, v
