"""VLM backbone (Phi-3-vision geometry): phi3-mini decoder + CLIP frontend
STUB — ``input_specs`` provides precomputed patch embeddings at d_model,
fused at the head of the token sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import embed_tokens, softmax_cross_entropy
from repro.models.transformer import (
    apply_blocks,
    hidden_to_logits,
    lm_decode_step,
    lm_init,
    lm_init_cache,
)
from repro.sharding import api as shard_api

vlm_init = lm_init
vlm_init_cache = lm_init_cache
vlm_decode_step = lm_decode_step


def _fuse(params, batch, cfg: ModelConfig):
    """Prepend patch embeddings to token embeddings."""
    tok = embed_tokens(params["embed"], batch["tokens"], cfg)       # (B,S_t,D)
    patches = batch["patch_embeds"].astype(tok.dtype)               # (B,P,D)
    return jnp.concatenate([patches, tok], axis=1)


def vlm_loss(params, batch, cfg: ModelConfig):
    """batch: {tokens (B,S_t), patch_embeds (B,P,D), labels (B,S_t)}."""
    h = _fuse(params, batch, cfg)
    h = shard_api.constrain(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    h, aux = apply_blocks(params, h, cfg, positions)
    p = batch["patch_embeds"].shape[1]
    logits = hidden_to_logits(params, h[:, p:, :], cfg)             # text region
    ce, count = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": aux, "tokens": count}


def vlm_prefill(params, batch, cfg: ModelConfig, max_len=None):
    """Prefill over [patches; prompt tokens]; logits for the last position."""
    from repro.models import attention as attn
    from repro.models.layers import apply_mlp, apply_norm
    from repro.models import moe as moe_mod

    h = _fuse(params, batch, cfg)
    b, s, _ = h.shape
    t = max_len or s
    positions = jnp.arange(s)[None, :]

    def body(x, layer_params):
        hn = apply_norm(layer_params["ln1"], x, cfg)
        q, k, v = attn.project_qkv(layer_params["attn"], hn, cfg, positions)
        if attn._use_blockwise(s, s):
            o = attn.attend_blockwise(q, k, v, cfg, causal=True)
        else:
            o = attn.attend(q, k, v, cfg, attn.causal_mask(s))
        x = x + attn.project_out(layer_params["attn"], o, x.dtype)
        hn = apply_norm(layer_params["ln2"], x, cfg)
        x = x + apply_mlp(layer_params["mlp"], hn, cfg)
        if t > s:
            pad = ((0, 0), (0, t - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"])
    from repro.models.transformer import hidden_to_logits as h2l
    logits = h2l(params, h[:, -1:, :], cfg)
    cache = {"k": ks, "v": vs, "index": jnp.full((b,), s, jnp.int32)}
    return logits, cache
