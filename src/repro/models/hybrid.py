"""Zamba2-style hybrid LM: Mamba2 backbone with a weight-shared attention+MLP
block applied every ``shared_attn_every`` layers [arXiv:2411.15242].

Layers are scanned in groups of ``shared_attn_every`` Mamba2 blocks; the
shared transformer block (single parameter set, reused at every application)
closes over the scan body, so its gradient accumulates across applications.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    embed_tokens,
    mlp_init,
    norm_init,
    softmax_cross_entropy,
    stack_init,
    unembed,
)
from repro.models.transformer import block_apply as tblock_apply
from repro.models.transformer import block_decode as tblock_decode
from repro.models.transformer import block_init as tblock_init
from repro.sharding import api as shard_api


def _group_counts(cfg: ModelConfig):
    per = cfg.shared_attn_every
    assert cfg.num_layers % per == 0, "num_layers must divide by shared_attn_every"
    return cfg.num_layers // per, per


def _ssm_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln": norm_init(cfg), "ssm": ssm.ssm_init(k2, cfg)}


def hybrid_lm_init(key, cfg: ModelConfig):
    groups, per = _group_counts(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": embed_init(k1, cfg),
        "ssm_blocks": stack_init(
            k2, groups,
            lambda kk: stack_init(kk, per, lambda k3_: _ssm_layer_init(k3_, cfg))),
        "shared_block": tblock_init(k3, cfg),
        "final_norm": norm_init(cfg),
    }


def _ssm_layer_apply(lp, h, cfg: ModelConfig):
    return h + ssm.ssm_block_apply(lp["ssm"], apply_norm(lp["ln"], h, cfg), cfg)


def hybrid_lm_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params["embed"], tokens, cfg)
    h = shard_api.constrain(h, "batch", None, None)
    positions = jnp.arange(s)[None, :]
    shared = params["shared_block"]

    def gbody(hh, gp):
        def sbody(hhh, lp):
            return _ssm_layer_apply(lp, hhh, cfg), None
        hh, _ = jax.lax.scan(sbody, hh, gp)
        hh, _ = tblock_apply(shared, hh, cfg, positions)
        return hh, None

    body = jax.checkpoint(gbody, prevent_cse=False) if cfg.remat else gbody
    h, _ = jax.lax.scan(body, h, params["ssm_blocks"])
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    logits = shard_api.constrain(logits, "batch", None, "model")
    ce, count = softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32), "tokens": count}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype=None):
    groups, per = _group_counts(cfg)
    conv, state = ssm.init_ssm_state(cfg, batch)

    def rep(x, *lead):
        return jnp.broadcast_to(x, (*lead, *x.shape))
    kvc = attn.init_kv_cache(cfg, batch, max_len, groups, kv_dtype)
    return {
        "conv": rep(conv, groups, per),
        "ssm": rep(state, groups, per),
        "k": kvc["k"], "v": kvc["v"],
        "index": jnp.zeros((batch,), jnp.int32),
    }


def hybrid_lm_prefill(params, batch, cfg: ModelConfig, max_len=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    t = max_len or s
    h = embed_tokens(params["embed"], tokens, cfg)
    positions = jnp.arange(s)[None, :]
    shared = params["shared_block"]

    def gbody(hh, gp):
        def sbody(hhh, lp):
            out, st = ssm.ssm_block_prefill(lp["ssm"], apply_norm(lp["ln"], hhh, cfg), cfg)
            return hhh + out, st
        hh, (convs, states) = jax.lax.scan(sbody, hh, gp)
        # shared attention block with KV capture
        x = hh
        hn = apply_norm(shared["ln1"], x, cfg)
        q, k, v = attn.project_qkv(shared["attn"], hn, cfg, positions)
        if attn._use_blockwise(s, s):
            o = attn.attend_blockwise(q, k, v, cfg, causal=True)
        else:
            o = attn.attend(q, k, v, cfg, attn.causal_mask(s))
        x = x + attn.project_out(shared["attn"], o, x.dtype)
        hn = apply_norm(shared["ln2"], x, cfg)
        x = x + apply_mlp(shared["mlp"], hn, cfg)
        if t > s:
            pad = ((0, 0), (0, t - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (convs, states, k, v)

    body = jax.checkpoint(gbody, prevent_cse=False) if cfg.remat else gbody
    h, (convs, states, ks, vs) = jax.lax.scan(body, h, params["ssm_blocks"])
    h = apply_norm(params["final_norm"], h[:, -1:, :], cfg)
    logits = unembed(params["embed"], h, cfg)
    cache = {"conv": convs, "ssm": states, "k": ks, "v": vs,
             "index": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def hybrid_lm_decode_step(params, cache, tokens, cfg: ModelConfig):
    h = embed_tokens(params["embed"], tokens, cfg)
    index = cache["index"]
    shared = params["shared_block"]

    def gbody(hh, xs):
        gp, convs, states, lk, lv = xs
        def sbody(hhh, xs2):
            lp, cv, st = xs2
            out, cv, st = ssm.ssm_block_decode(
                lp["ssm"], apply_norm(lp["ln"], hhh, cfg), cfg, cv, st)
            return hhh + out, (cv, st)
        hh, (convs, states) = jax.lax.scan(sbody, hh, (gp, convs, states))
        hh, lk, lv = tblock_decode(shared, hh, cfg, lk, lv, index)
        return hh, (convs, states, lk, lv)

    h, (convs, states, ks, vs) = jax.lax.scan(
        gbody, h,
        (params["ssm_blocks"], cache["conv"], cache["ssm"], cache["k"], cache["v"]))
    h = apply_norm(params["final_norm"], h, cfg)
    logits = unembed(params["embed"], h, cfg)
    new_cache = {"conv": convs, "ssm": states, "k": ks, "v": vs,
                 "index": index + 1}
    return logits, new_cache
