"""Mamba2 (SSD — state-space duality) block in pure JAX.

Chunked-parallel SSD for train/prefill, recurrent state update for decode.
The chunked form here is also the reference oracle for the Pallas
``ssd_scan`` kernel.

Recurrence (per head h, state N×P):
    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D_skip · x_t
with A = -exp(A_log) < 0, dt = softplus(dt_raw + dt_bias).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, norm_init, ones_init, pdtype, zeros_init
from repro.sharding import api as shard_api


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def ssm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
    dt = pdtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    proj_out = 2 * d_inner + 2 * g * n + n_heads
    return {
        "in_proj": dense_init(k1, (d, proj_out), dt),
        "conv_w": dense_init(k2, (w, conv_dim), dt, scale=0.5),
        "conv_b": zeros_init((conv_dim,), dt),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": ones_init((d_inner,), dt),
        "out_proj": dense_init(k3, (d_inner, d), dt),
    }


def ssm_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    g, n, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
    proj_out = 2 * d_inner + 2 * g * n + n_heads
    return (d * proj_out + w * conv_dim + conv_dim + 3 * n_heads
            + d_inner + d_inner * d)


# ---------------------------------------------------------------------------
# projections / conv
# ---------------------------------------------------------------------------

def _split_proj(proj, cfg: ModelConfig):
    d_inner, n_heads, _ = ssm_dims(cfg)
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: 2 * d_inner + 2 * gn]
    dt_raw = proj[..., 2 * d_inner + 2 * gn:]
    return z, xbc, dt_raw


def causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv: xbc (B,S,C), conv_w (W,C) -> (B,S,C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    s = xbc.shape[1]
    for i in range(w):
        out = out + pad[:, i: i + s, :] * conv_w[i][None, None, :].astype(xbc.dtype)
    return out + conv_b[None, None, :].astype(xbc.dtype)


def conv_step(x_t, conv_state, conv_w, conv_b):
    """One-token conv: x_t (B,C); conv_state (B,W-1,C) -> (y_t, new_state)."""
    w = conv_w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, conv_w.astype(x_t.dtype))
    y = y + conv_b[None, :].astype(x_t.dtype)
    return y, window[:, 1:, :]


def _gates(xbc_conv, dt_raw, params, cfg: ModelConfig):
    d_inner, n_heads, _ = ssm_dims(cfg)
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    p = cfg.ssm_head_dim
    x = xbc_conv[..., :d_inner]
    bmat = xbc_conv[..., d_inner: d_inner + g * n]
    cmat = xbc_conv[..., d_inner + g * n:]
    lead = x.shape[:-1]
    xh = x.reshape(*lead, n_heads, p)
    bm = bmat.reshape(*lead, g, n).astype(jnp.float32)
    cm = cmat.reshape(*lead, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                      # (H,) negative
    da = dt * a                                        # (..., H) log-decay
    return xh, bm, cm, dt, da


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)  — reference for kernels/ssd_scan
# ---------------------------------------------------------------------------

def ssd_chunked(xh, bm, cm, dt, da, d_skip, cfg: ModelConfig, h0=None):
    """xh (B,S,H,P); bm/cm (B,S,G,N) fp32; dt/da (B,S,H) fp32.

    Sequential ``lax.scan`` over chunks with carried state, so the quadratic
    intra-chunk tensors exist for one chunk at a time (bounded working set —
    the same pipelined-streaming discipline as the paper's batched mode).
    Returns (y (B,S,H,P), h_final (B,H,N,P)).
    """
    b, s, nh, p = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    hg = nh // g                                   # heads per B/C group
    q = min(cfg.ssm_chunk, s)
    s_orig = s
    if s % q:                                      # pad with identity steps:
        pad = q - s % q                            # da=0 (decay 1), dt/B/C/x = 0
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xh, bm, cm, dt, da = map(zpad, (xh, bm, cm, dt, da))
        s = s + pad
    nc = s // q
    mask = jnp.tril(jnp.ones((q, q), bool))
    if h0 is None:
        h0 = jnp.zeros((b, nh, n, p), jnp.float32)

    def chunk_body(h_state, xs):
        xq, bq, cq, dtq, daq = xs                  # (b,q,...) one chunk
        sgm = jnp.cumsum(daq, axis=1)              # (b,q,h) inclusive
        s_last = sgm[:, -1, :]                     # (b,h)
        # intra-chunk: M[j,i] = exp(s_j - s_i) * (C_j . B_i), i <= j
        cb = jnp.einsum("bjgN,bigN->bgji", cq, bq)           # (b,g,q,q)
        cb = jnp.repeat(cb, hg, axis=1)                      # (b,h,q,q)
        ldiff = sgm[:, :, None, :] - sgm[:, None, :, :]      # (b,j,i,h)
        ldiff = jnp.transpose(ldiff, (0, 3, 1, 2))           # (b,h,j,i)
        m = jnp.where(mask[None, None], cb * jnp.exp(ldiff), 0.0)
        dtx = dtq[..., None] * xq.astype(jnp.float32)        # (b,q,h,p)
        y_intra = jnp.einsum("bhji,bihp->bjhp", m, dtx)
        # inter-chunk: y_j += exp(s_j) * C_j . h_prev
        cq_h = jnp.repeat(cq, hg, axis=2)                    # (b,q,h,N)
        y_inter = jnp.einsum("bqhN,bhNp->bqhp", cq_h, h_state) \
            * jnp.exp(sgm)[..., None]
        # state update: h_new = exp(s_last) h_prev + sum_i exp(s_last-s_i) B_i (x) dtx_i
        decay_to_end = jnp.exp(s_last[:, None, :] - sgm)     # (b,q,h)
        bq_h = jnp.repeat(bq, hg, axis=2)                    # (b,q,h,N)
        chunk_state = jnp.einsum("bqhN,bqhp,bqh->bhNp", bq_h, dtx, decay_to_end)
        h_new = h_state * jnp.exp(s_last)[..., None, None] + chunk_state
        return h_new, y_intra + y_inter

    xs = (
        jnp.moveaxis(xh.reshape(b, nc, q, nh, p), 1, 0),
        jnp.moveaxis(bm.reshape(b, nc, q, g, n), 1, 0),
        jnp.moveaxis(cm.reshape(b, nc, q, g, n), 1, 0),
        jnp.moveaxis(dt.reshape(b, nc, q, nh), 1, 0),
        jnp.moveaxis(da.reshape(b, nc, q, nh), 1, 0),
    )
    hfin, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, p)
    y = y + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    return y[:, :s_orig], hfin


def ssd_recurrent_step(state, xh, bm, cm, dt, da, d_skip):
    """One decode step. state (B,H,N,P); xh (B,H,P); bm/cm (B,G,N); dt/da (B,H)."""
    b, nh, n, p = state.shape
    g = bm.shape[1]
    hg = nh // g
    bm_h = jnp.repeat(bm, hg, axis=1)            # (B,H,N)
    cm_h = jnp.repeat(cm, hg, axis=1)
    dtx = dt[..., None] * xh.astype(jnp.float32)  # (B,H,P)
    new_state = state * jnp.exp(da)[..., None, None] \
        + bm_h[..., :, None] * dtx[..., None, :]  # (B,H,N,P)
    y = jnp.einsum("bhN,bhNp->bhp", cm_h, new_state)
    y = y + d_skip[None, :, None] * xh.astype(jnp.float32)
    return y, new_state


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _gated_norm(y, z, scale, eps=1e-6):
    """Mamba2 gated RMSNorm: rmsnorm(y * silu(z)) * scale."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps)) * scale.astype(jnp.float32)


def ssm_block_apply(params, x, cfg: ModelConfig, use_kernel: bool = False):
    """x: (B, S, D) -> (B, S, D). Full-sequence (train / prefill)."""
    b, s, d = x.shape
    d_inner, n_heads, _ = ssm_dims(cfg)
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = jax.nn.silu(causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xh, bm, cm, dt, da = _gates(xbc, dt_raw, params, cfg)
    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xh, bm, cm, dt, da, params["D"], chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xh, bm, cm, dt, da, params["D"], cfg)
    y = y.reshape(b, s, d_inner)
    y = _gated_norm(y, z, params["norm_scale"]).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(x.dtype))


def ssm_block_prefill(params, x, cfg: ModelConfig):
    """Like ssm_block_apply but also returns (conv_state, ssm_state)."""
    b, s, d = x.shape
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    w = cfg.ssm_conv_width
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    conv_state = xbc[:, -(w - 1):, :] if s >= w - 1 else jnp.pad(
        xbc, ((0, 0), (w - 1 - s, 0), (0, 0)))
    xbc = jax.nn.silu(causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xh, bm, cm, dt, da = _gates(xbc, dt_raw, params, cfg)
    y, hfin = ssd_chunked(xh, bm, cm, dt, da, params["D"], cfg)
    y = y.reshape(b, s, d_inner)
    y = _gated_norm(y, z, params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(x.dtype))
    return out, (conv_state, hfin)


def ssm_block_decode(params, x, cfg: ModelConfig, conv_state, ssm_state):
    """x: (B, 1, D) one-token decode with carried states."""
    b, _, d = x.shape
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    proj = jnp.einsum("bsd,dp->bsp", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj[:, 0], cfg)   # squeeze S=1
    y_conv, conv_state = conv_step(xbc, conv_state, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(y_conv)
    xh, bm, cm, dt, da = _gates(xbc, dt_raw, params, cfg)
    y, ssm_state = ssd_recurrent_step(ssm_state, xh, bm, cm, dt, da, params["D"])
    y = y.reshape(b, d_inner)
    y = _gated_norm(y, z, params["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"].astype(x.dtype))
    return out[:, None, :], conv_state, ssm_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    return (
        jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
        jnp.zeros((batch, n_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    )
