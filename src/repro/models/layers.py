"""Shared building blocks: norms, RoPE, MLP variants, init helpers.

All modules are pure functions over nested-dict parameter pytrees.  Compute
dtype (``cfg.dtype``) and parameter storage dtype (``cfg.param_dtype``) are
taken from the :class:`~repro.configs.base.ModelConfig`; numerically
sensitive reductions (norms, softmax, loss) run in float32.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def adtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def cast_act(x, cfg: ModelConfig):
    return x.astype(adtype(cfg))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype):
    return jnp.ones(shape, dtype)


def stack_init(key, n: int, init_fn: Callable):
    """Initialize ``n`` copies of a layer, stacked on a leading axis (for scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    p = {"scale": ones_init((d,), pdtype(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = zeros_init((d,), pdtype(cfg))
    return p


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32)
        y = y + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_normalize(x, scale=None, eps: float = 1e-6):
    """Headwise RMS norm used for qk_norm; operates on the last dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_in: int | None = None, d_ff: int | None = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wg": dense_init(k1, (d, f), dt),
            "wu": dense_init(k2, (d, f), dt),
            "wd": dense_init(k3, (f, d), dt),
        }
    # squared_relu / gelu: single up projection
    return {
        "wi": dense_init(k1, (d, f), dt),
        "wd": dense_init(k2, (f, d), dt),
    }


def apply_mlp(params, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, params["wu"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
        if cfg.mlp_type == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["wd"].astype(x.dtype))


def mlp_param_count(cfg: ModelConfig, d_in: int | None = None, d_ff: int | None = None) -> int:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    return (3 if cfg.mlp_type == "swiglu" else 2) * d * f


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    emb = params["embedding"]
    return jnp.take(emb, tokens, axis=0).astype(adtype(cfg))


def unembed(params, x, cfg: ModelConfig):
    """Returns logits (..., V) in the activation dtype (cast up at the loss)."""
    if cfg.tie_embeddings:
        w = params["embedding"].astype(x.dtype)   # (V, D)
        return jnp.einsum("...d,vd->...v", x, w)
    w = params["lm_head"].astype(x.dtype)         # (D, V)
    return jnp.einsum("...d,dv->...v", x, w)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None):
    """CE over the last dim; one-hot einsum form (TPU/GSPMD friendly).

    logits: (..., V) any float dtype; labels: (...) int32; mask: (...) or None.
    Returns (mean_loss_f32, token_count).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    picked = jnp.einsum("...v,...v->...", lf, onehot)
    nll = lse - picked
    if mask is None:
        mask = jnp.ones(nll.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    total = jnp.sum(nll * mask)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    return total / count, count
