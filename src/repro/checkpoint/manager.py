"""Mesh-agnostic checkpointing with async snapshots and elastic restore.

- Arrays are gathered to host and written one file per leaf (npy) plus a
  JSON manifest (tree structure, shapes, dtypes, step, data-pipeline state).
- ``save_async`` reuses the tier-1 engine discipline: device→host copies and
  file writes happen on a background thread, off the training critical path
  (the paper's async mode applied to the checkpoint write).
- Restore is *elastic*: arrays are re-placed under whatever mesh/sharding the
  restoring job provides (device count may differ from the saving job).

**Diskless replication** (the fabric analogue of the file path above):
:class:`ShardCodec` serializes a state pytree into size-classed shards —
fixed power-of-two uint8 buffers filled by scatter-gather descriptors on
the process-wide :class:`~repro.core.copyengine.CopyEngine` (tag
``ckpt``, one counted logical copy per shard per direction) — and
:class:`ReplicationSource` serves those shards *through the serving
fabric itself* as reserved ``__ckpt.*`` operations, so a warm-standby
process (:mod:`repro.ft.standby`) can pull a complete snapshot plus a
small delta log over the bulk heap without any disk in the path.  A
shard is the ultimate "hundreds of MB per request" payload: at or over
``policy.heap_threshold_bytes`` it rides the puller connection's extent
arenas exactly like any other large message.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil
import threading
import time
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name or "leaf", leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="ckpt")
        self._last: Optional[Future] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: dict, extra: Optional[dict] = None) -> str:
        """Synchronous save. ``state`` is any pytree dict of arrays."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        return self._write(step, host_state, extra or {})

    def save_async(self, step: int, state: dict,
                   extra: Optional[dict] = None) -> Future:
        """Async-mode save: device→host gather happens now (cheap, engine
        absorbs it), serialization happens on the snapshot thread."""
        self.wait()   # one outstanding snapshot (bounded queue-pair ring)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._last = self._executor.submit(self._write, step, host_state,
                                           extra or {})
        return self._last

    def wait(self) -> None:
        if self._last is not None:
            self._last.result()
            self._last = None

    def _write(self, step: int, host_state: dict, extra: dict) -> str:
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_names(host_state)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for name, leaf in leaves:
            fname = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"][name] = {
                "file": fname, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: dict, shardings=None) -> tuple[dict, dict]:
        """Restore into the structure of ``like``; re-place under
        ``shardings`` (pytree of NamedSharding / None) — elastic across
        device counts since files are full host arrays."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        names, treedef = _flatten_with_names(like)
        shard_leaves = (jax.tree.leaves(shardings,
                                        is_leaf=lambda x: x is None)
                        if shardings is not None else [None] * len(names))
        leaves = []
        for (name, ref), sh in zip(names, shard_leaves):
            meta = manifest["leaves"][name]
            arr = np.load(os.path.join(path, meta["file"]))
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return treedef.unflatten(leaves), manifest["extra"]


# ---------------------------------------------------------------------------
# diskless replication: size-classed shard codec + fabric-served source
# ---------------------------------------------------------------------------

class ShardCorrupt(RuntimeError):
    """A shard failed its CRC on decode.

    Carries ``indices`` — the 0-based shard numbers that failed — so a
    replication puller can re-pull exactly the damaged shards instead of
    restarting the whole snapshot transfer."""

    def __init__(self, indices):
        self.indices = sorted(indices)
        super().__init__(f"shard CRC mismatch at {self.indices}")


def _pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (and >= 4 KB): the shard size class."""
    n = max(int(n), 1 << 12)
    return 1 << (n - 1).bit_length()


class ShardCodec:
    """Serialize a host pytree into size-classed shards and back.

    The encode side lays every leaf's bytes (plus one trailing pickled
    ``extra`` blob) into a logical contiguous payload, then fills
    power-of-two ``shard_bytes`` uint8 buffers with chunked scatter-gather
    descriptors on the process-wide engine (tag ``ckpt``) — leaves that
    straddle a shard boundary are split across two SG entries, so the
    payload is copied exactly once end to end.  Each shard carries a
    CRC32 in the manifest; a blake2s digest over the whole payload is the
    byte-identity witness a restored replica is checked against.

    The decode side verifies every CRC first (raising
    :class:`ShardCorrupt` with the damaged indices), then SG-gathers the
    shard segments back into freshly owned leaf buffers — again one copy
    per byte, counted under the same tag.  ``stats["shard_copies"]``
    counts shard-granularity fills in both directions (the benchmark's
    ``ckpt_shard_copies``).
    """

    def __init__(self, shard_bytes: int = 1 << 20):
        self.shard_bytes = _pow2_at_least(shard_bytes)
        self.stats = {"shard_copies": 0, "bytes_sharded": 0}

    # -- encode ----------------------------------------------------------------
    def encode(self, tree, extra: Optional[dict] = None,
               seq: int = 0) -> tuple[dict, list[np.ndarray]]:
        """``(manifest, shards)`` for a host pytree.  ``extra`` is any
        picklable side state (e.g. server counters) riding the payload
        tail; ``seq`` stamps the snapshot's sequence number."""
        from repro.core.copyengine import SGList, get_engine

        named, _ = _flatten_with_names(tree)
        leaves, metas, offset = [], [], 0
        for name, leaf in named:
            arr = np.asarray(jax.device_get(leaf))
            shape = arr.shape            # before ascontiguousarray: it
            arr = np.ascontiguousarray(arr)  # promotes 0-d to 1-d
            view = arr.view(np.uint8).reshape(-1)
            leaves.append(view)
            metas.append({"name": name, "shape": list(shape),
                          "dtype": str(arr.dtype), "nbytes": int(view.nbytes),
                          "offset": offset})
            offset += view.nbytes
        blob = np.frombuffer(
            pickle.dumps(extra if extra is not None else {},
                         protocol=pickle.HIGHEST_PROTOCOL), dtype=np.uint8)
        extra_off, payload_bytes = offset, offset + blob.nbytes
        segments = leaves + [blob]

        n_shards = max(1, -(-payload_bytes // self.shard_bytes))
        shards, sizes, crcs = [], [], []
        digest = hashlib.blake2s()
        engine = get_engine()
        seg_iter = iter(enumerate(segments))
        seg_idx, seg = next(seg_iter)
        seg_pos = 0
        seg_off = 0          # payload offset of the current segment's start
        for s in range(n_shards):
            lo = s * self.shard_bytes
            hi = min(lo + self.shard_bytes, payload_bytes)
            buf = np.empty(self.shard_bytes, np.uint8)
            sg = SGList()
            filled = 0
            while filled < hi - lo:
                take = min(seg.nbytes - seg_pos, (hi - lo) - filled)
                if take > 0:
                    sg.add(seg[seg_pos:seg_pos + take],
                           buf[filled:filled + take])
                    seg_pos += take
                    filled += take
                if seg_pos >= seg.nbytes:
                    try:
                        seg_idx, seg = next(seg_iter)
                    except StopIteration:
                        break
                    seg_off += seg_pos
                    seg_pos = 0
            if sg.entries:
                # one *logical* copy per shard fill, however many straddle
                # entries the boundary produced — the counted metric
                engine.run_sg(sg, tag="ckpt", count_copies=1)
            self.stats["shard_copies"] += 1
            self.stats["bytes_sharded"] += filled
            sizes.append(filled)
            crcs.append(zlib.crc32(buf[:filled]) & 0xFFFFFFFF)
            digest.update(buf[:filled].tobytes())
            shards.append(buf)
        manifest = {
            "seq": int(seq),
            "shard_bytes": self.shard_bytes,
            "payload_bytes": payload_bytes,
            "extra_offset": extra_off,
            "sizes": sizes,
            "crcs": crcs,
            "digest": digest.hexdigest(),
            "leaves": metas,
            # CLOCK_MONOTONIC stamp: cross-process comparable on Linux, so
            # the puller can compute replication lag without clock skew
            "stamp_ns": time.perf_counter_ns(),
        }
        return manifest, shards

    # -- verification ----------------------------------------------------------
    def verify(self, manifest: dict, idx: int, shard: np.ndarray) -> bool:
        """CRC-check one shard against the manifest (puller-side guard:
        lets a replica re-pull exactly the damaged shard)."""
        size = manifest["sizes"][idx]
        if shard.nbytes < size:
            return False
        view = np.asarray(shard, np.uint8).reshape(-1)[:size]
        return (zlib.crc32(view) & 0xFFFFFFFF) == manifest["crcs"][idx]

    # -- decode ----------------------------------------------------------------
    def decode(self, manifest: dict, shards: list,
               like=None) -> tuple[Any, Any]:
        """Rebuild ``(tree, extra)`` from a manifest + shard list.

        With ``like`` the restored leaves are unflattened into its exact
        treedef (arbitrary pytrees — lists, tuples, namedtuple-ish
        nodes); without it a nested dict is reconstructed from the
        ``/``-joined leaf names.  Raises :class:`ShardCorrupt` (listing
        every damaged shard) before any byte is trusted."""
        from repro.core.copyengine import SGList, get_engine

        shards = [np.asarray(s, np.uint8).reshape(-1) for s in shards]
        if len(shards) != len(manifest["sizes"]):
            raise ShardCorrupt(range(len(manifest["sizes"])))
        bad = [i for i in range(len(shards))
               if not self.verify(manifest, i, shards[i])]
        if bad:
            raise ShardCorrupt(bad)
        engine = get_engine()
        sb = manifest["shard_bytes"]

        def gather(offset: int, nbytes: int) -> np.ndarray:
            out = np.empty(nbytes, np.uint8)
            sg = SGList()
            pos = 0
            while pos < nbytes:
                s, off = divmod(offset + pos, sb)
                take = min(sb - off, nbytes - pos)
                sg.add(shards[s][off:off + take], out[pos:pos + take])
                pos += take
            if sg.entries:
                engine.run_sg(sg, tag="ckpt", count_copies=1)
            self.stats["shard_copies"] += 1
            self.stats["bytes_sharded"] += nbytes
            return out

        arrays = {}
        for meta in manifest["leaves"]:
            raw = gather(meta["offset"], meta["nbytes"])
            arrays[meta["name"]] = raw.view(
                np.dtype(meta["dtype"])).reshape(tuple(meta["shape"]))
        tail = manifest["payload_bytes"] - manifest["extra_offset"]
        extra = pickle.loads(
            gather(manifest["extra_offset"], tail).tobytes()) if tail else {}

        if like is not None:
            named, treedef = _flatten_with_names(like)
            tree = treedef.unflatten([arrays[name] for name, _ in named])
            return tree, extra
        if list(arrays) == ["leaf"]:     # a bare-array "tree"
            return arrays["leaf"], extra
        nested: dict = {}
        for name, arr in arrays.items():
            node = nested
            parts = name.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return nested, extra


class ReplicationSource:
    """Serve snapshots + a delta log through the fabric's own dispatcher.

    Attached to a serving :class:`~repro.core.dispatcher.RequestDispatcher`,
    this registers the reserved replication operations a warm standby
    (:class:`repro.ft.standby.StandbyReplica`) pulls:

    - ``__ckpt.manifest__`` — (re-)snapshot the server state if the
      cached one is older than ``interval_s``, reply with the JSON
      manifest (seq, shard sizes/CRCs, payload digest, leaf layout);
    - ``__ckpt.shard__`` — payload ``[seq, idx]`` int64; reply with one
      shard's bytes (a zero-length reply means the seq was superseded —
      re-pull the manifest).  The ``ckpt.shard.corrupt`` fault site XORs
      one byte of a *copy* here, so CRC containment is drillable without
      damaging the cached snapshot;
    - ``__ckpt.delta__`` — the small fast-moving state re-exported on
      every pull (dedup window, breaker states, service EWMAs — see
      :meth:`RequestDispatcher.export_state`), pickled.  This is the
      delta log that keeps exactly-once intact across a promotion
      without re-streaming the params.

    ``state_fn()`` returns ``(tree, extra)`` — the array pytree plus any
    picklable side state.  Snapshots are cut at most every ``interval_s``
    (pullers arriving faster share the cached one) and the whole surface
    rides the normal request path, so shards at/over the heap threshold
    stream through the puller connection's bulk-heap extents.
    """

    OP_MANIFEST = "__ckpt.manifest__"
    OP_SHARD = "__ckpt.shard__"
    OP_DELTA = "__ckpt.delta__"
    RESERVED_OPS = (OP_MANIFEST, OP_SHARD, OP_DELTA)

    def __init__(self, state_fn: Callable[[], tuple],
                 shard_bytes: int = 1 << 20,
                 interval_s: float = 0.05):
        self.state_fn = state_fn
        self.codec = ShardCodec(shard_bytes)
        self.interval_s = interval_s
        self._lock = threading.Lock()
        self._manifest: Optional[dict] = None
        self._shards: list = []
        self._cut_t = 0.0
        self._seq = 0
        self._dispatcher = None
        self.stats = {"snapshots": 0, "manifest_pulls": 0, "shard_pulls": 0,
                      "delta_pulls": 0, "bytes_replicated": 0}

    # -- snapshot lifecycle ----------------------------------------------------
    def _fresh_snapshot(self) -> dict:
        """Cut (or reuse) a snapshot; returns the manifest."""
        with self._lock:
            now = time.perf_counter()
            if (self._manifest is None
                    or now - self._cut_t >= self.interval_s):
                tree, extra = self.state_fn()
                self._seq += 1
                self._manifest, self._shards = self.codec.encode(
                    tree, extra=extra, seq=self._seq)
                self._cut_t = now
                self.stats["snapshots"] += 1
            return self._manifest

    def snapshot_now(self) -> dict:
        """Force a fresh snapshot immediately (tests/benchmarks)."""
        with self._lock:
            self._cut_t = 0.0
        return self._fresh_snapshot()

    @property
    def seq(self) -> int:
        """Sequence number of the latest cut snapshot (0 = none yet)."""
        return self._seq

    # -- fabric-facing handlers ------------------------------------------------
    def _h_manifest(self, _data) -> np.ndarray:
        manifest = self._fresh_snapshot()
        self.stats["manifest_pulls"] += 1
        return np.frombuffer(json.dumps(manifest).encode(), np.uint8)

    def _h_shard(self, data) -> np.ndarray:
        from repro.ft import inject as _inject

        req = np.asarray(data).reshape(-1)
        seq, idx = int(req[0]), int(req[1])
        with self._lock:
            if self._manifest is None or seq != self._manifest["seq"] \
                    or not 0 <= idx < len(self._shards):
                return np.empty(0, np.uint8)     # superseded: re-pull manifest
            size = self._manifest["sizes"][idx]
            shard = self._shards[idx][:size]
        self.stats["shard_pulls"] += 1
        self.stats["bytes_replicated"] += int(size)
        spec = (_inject.fire("ckpt.shard.corrupt")
                if _inject._PLANE is not None else None)
        if spec is not None and size:
            shard = shard.copy()                 # never damage the cache
            shard[0] ^= np.uint8((spec.arg or 0xFF) & 0xFF)
        return shard

    def _h_delta(self, _data) -> np.ndarray:
        state = (self._dispatcher.export_state()
                 if self._dispatcher is not None else {})
        self.stats["delta_pulls"] += 1
        blob = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
        self.stats["bytes_replicated"] += len(blob)
        return np.frombuffer(blob, np.uint8)

    def attach(self, dispatcher) -> "ReplicationSource":
        """Register the replication ops on a serving dispatcher."""
        self._dispatcher = dispatcher
        dispatcher.register_handler(self.OP_MANIFEST, self._h_manifest)
        dispatcher.register_handler(self.OP_SHARD, self._h_shard)
        dispatcher.register_handler(self.OP_DELTA, self._h_delta)
        return self
