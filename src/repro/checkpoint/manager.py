"""Mesh-agnostic checkpointing with async snapshots and elastic restore.

- Arrays are gathered to host and written one file per leaf (npy) plus a
  JSON manifest (tree structure, shapes, dtypes, step, data-pipeline state).
- ``save_async`` reuses the tier-1 engine discipline: device→host copies and
  file writes happen on a background thread, off the training critical path
  (the paper's async mode applied to the checkpoint write).
- Restore is *elastic*: arrays are re-placed under whatever mesh/sharding the
  restoring job provides (device count may differ from the saving job).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name or "leaf", leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="ckpt")
        self._last: Optional[Future] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: dict, extra: Optional[dict] = None) -> str:
        """Synchronous save. ``state`` is any pytree dict of arrays."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        return self._write(step, host_state, extra or {})

    def save_async(self, step: int, state: dict,
                   extra: Optional[dict] = None) -> Future:
        """Async-mode save: device→host gather happens now (cheap, engine
        absorbs it), serialization happens on the snapshot thread."""
        self.wait()   # one outstanding snapshot (bounded queue-pair ring)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._last = self._executor.submit(self._write, step, host_state,
                                           extra or {})
        return self._last

    def wait(self) -> None:
        if self._last is not None:
            self._last.result()
            self._last = None

    def _write(self, step: int, host_state: dict, extra: dict) -> str:
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_names(host_state)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for name, leaf in leaves:
            fname = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"][name] = {
                "file": fname, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: dict, shardings=None) -> tuple[dict, dict]:
        """Restore into the structure of ``like``; re-place under
        ``shardings`` (pytree of NamedSharding / None) — elastic across
        device counts since files are full host arrays."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        names, treedef = _flatten_with_names(like)
        shard_leaves = (jax.tree.leaves(shardings,
                                        is_leaf=lambda x: x is None)
                        if shardings is not None else [None] * len(names))
        leaves = []
        for (name, ref), sh in zip(names, shard_leaves):
            meta = manifest["leaves"][name]
            arr = np.load(os.path.join(path, meta["file"]))
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return treedef.unflatten(leaves), manifest["extra"]
