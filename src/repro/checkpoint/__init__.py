from repro.checkpoint.manager import (CheckpointManager, ReplicationSource,
                                      ShardCodec, ShardCorrupt)

__all__ = ["CheckpointManager", "ReplicationSource", "ShardCodec",
           "ShardCorrupt"]
