"""Version-portability shims for the jax APIs this repo straddles.

The repo targets current jax, but CI/dev containers may carry an older
release.  Two surfaces moved:

- ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map``
  (old), whose replication-check kwarg was renamed
  ``check_rep`` → ``check_vma``;
- ``pltpu.CompilerParams`` (new) vs ``pltpu.TPUCompilerParams`` (old).

Import from here instead of feature-testing at each call site.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the kwarg spelling of whichever jax is
    installed (``check_vma`` newer / ``check_rep`` older)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new name) / ``TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
