"""Typed channels over shm rings: numpy pytrees, ROCKET send modes.

A :class:`DataChannel` sends pytrees (nested dict/list/tuple) of numpy
arrays through one :class:`~repro.ipc.ring.Ring`.  The wire format is

- **meta**: ``[u32 descriptor length | descriptor pickle | header pickle]``
  where the descriptor mirrors the tree structure with each array leaf
  replaced by ``(offset, shape, dtype)``.  Descriptors are **cached by
  structural signature** (tree shape + leaf shapes/dtypes) on the sender
  and by descriptor bytes on the receiver, so steady-state sends of a
  stable structure skip ``pickle.dumps``/``loads`` of the descriptor
  entirely — only the small per-message header is pickled;
- **payload**: the arrays' bytes packed back-to-back at 64-byte-aligned
  offsets inside the slot — one scatter-gather descriptor per tree,
  executed by the process-wide :class:`~repro.core.copyengine.CopyEngine`
  (a single counted memcpy per leaf into pre-mapped shared memory), and
  *zero* copies on the receive side when the caller asks for views
  (``copy=False``).

Send modes follow :class:`~repro.core.policy.OffloadPolicy` exactly like
the tier-1 engine (the paper's Table III):

- ``sync``       — the caller performs the copy inline and the handle is
  complete on return (cpu/DTO);
- ``async``      — the shared copy engine (one work queue per channel, so
  FIFO order holds without a per-channel thread) performs slot acquire +
  copy + publish; ``send`` returns a handle immediately and
  ``handle.wait()`` applies hybrid polling;
- ``pipelined``  — async plus bounded in-flight depth: when more than
  ``pipeline_depth`` sends are outstanding the oldest is completed first
  (backpressure), with the blocking wait held *outside* the channel lock.

Small below-threshold messages stay inline in every mode (size-based
offload control).

The **reserve-then-fill** path (:meth:`DataChannel.reserve`) exposes the
ring's :class:`~repro.ipc.ring.SlotWriter` as a typed :class:`TxSlot`:
the caller claims the destination slot first and packs the message
directly into it (e.g. a serving reply written straight into the
client's tx slot), eliminating the staging copy a ``send`` of an
already-materialized tree would add.
"""
from __future__ import annotations

import pickle
import struct
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Optional

import numpy as np

from repro.core.copyengine import (
    CopyEngine,
    CopyJob,
    Descriptor,
    HybridPollStats,
    SGList,
    WouldBlock,
    get_engine,
)
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.core.queuepair import drain_to_depth
from repro.ipc.ring import ChannelClosed, Ring, SlotReader, SlotWriter, _align

from dataclasses import dataclass

_U32 = struct.Struct("<I")
_DESCR_CACHE_MAX = 64


# ---------------------------------------------------------------------------
# pytree packing (stdlib-only: no jax dependency inside the IPC layer)
# ---------------------------------------------------------------------------

class _Leaf:
    __slots__ = ("offset", "shape", "dtype")

    def __init__(self, offset: int, shape, dtype: str):
        self.offset = offset
        self.shape = tuple(shape)
        self.dtype = dtype


def _pack_descr(tree, cursor: list[int]):
    """Replace array leaves with placement descriptors; returns mirror tree."""
    if isinstance(tree, dict):
        return {k: _pack_descr(v, cursor) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        packed = [_pack_descr(v, cursor) for v in tree]
        return packed if isinstance(tree, list) else tuple(packed)
    arr = np.asarray(tree)
    leaf = _Leaf(cursor[0], arr.shape, arr.dtype.str)
    cursor[0] += _align(arr.nbytes)
    return leaf


# structure-signature markers (distinct from any dict key / dtype string)
_SIG_DICT, _SIG_LIST, _SIG_TUPLE = 0, 1, 2


def _signature(tree, out: list) -> None:
    """Flatten the tree's *structure* (container shape, keys, leaf
    shapes/dtypes) into a hashable token list — the descriptor-cache key.
    Any structural change (new key, reordered keys, different shape or
    dtype) yields a different signature, which is the cache invalidation."""
    if isinstance(tree, dict):
        out.append(_SIG_DICT)
        out.append(len(tree))
        for k, v in tree.items():
            out.append(k)
            _signature(v, out)
        return
    if isinstance(tree, (list, tuple)):
        out.append(_SIG_LIST if isinstance(tree, list) else _SIG_TUPLE)
        out.append(len(tree))
        for v in tree:
            _signature(v, out)
        return
    arr = np.asarray(tree)
    out.append(arr.dtype.str)
    out.append(arr.shape)


def _gather_sg(tree, descr, payload: memoryview, sg: SGList) -> None:
    """Append one SG entry per leaf: leaf bytes → its slot placement."""
    if isinstance(descr, dict):
        for k, d in descr.items():
            _gather_sg(tree[k], d, payload, sg)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _gather_sg(v, d, payload, sg)
        return
    arr = np.asarray(tree)
    dst = np.frombuffer(payload, np.uint8, count=arr.nbytes,
                        offset=descr.offset)
    sg.add(arr, dst)


def _unpack(descr, payload: memoryview, copy: bool):
    if isinstance(descr, dict):
        return {k: _unpack(d, payload, copy) for k, d in descr.items()}
    if isinstance(descr, (list, tuple)):
        out = [_unpack(d, payload, copy) for d in descr]
        return out if isinstance(descr, list) else tuple(out)
    dtype = np.dtype(descr.dtype)
    count = int(np.prod(descr.shape)) if descr.shape else 1
    arr = np.frombuffer(payload, dtype, count=count,
                        offset=descr.offset).reshape(descr.shape)
    return arr.copy() if copy else arr


def _count_leaves(descr) -> int:
    if isinstance(descr, dict):
        return sum(_count_leaves(d) for d in descr.values())
    if isinstance(descr, (list, tuple)):
        return sum(_count_leaves(d) for d in descr)
    return 1


def tree_nbytes(tree) -> int:
    """Total payload bytes of every array leaf in a pytree."""
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    return np.asarray(tree).nbytes


# ---------------------------------------------------------------------------
# completion handles / leases
# ---------------------------------------------------------------------------

class SendHandle:
    """Completion flag for one send (the job-id side of the paper's API);
    offloaded sends are backed by a copy-engine completion record."""

    def __init__(self, channel: "DataChannel", nbytes: int,
                 job: Optional[CopyJob] = None):
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self._job = job

    def done(self) -> bool:
        """True once the copy has been published (never blocks)."""
        return self._job is None or self._job.done()

    def failed(self) -> bool:
        """True when the offloaded send completed with an exception."""
        return self._job is not None and self._job.failed()

    def wait(self, timeout_s: float = 30.0) -> None:
        """Hybrid-polling completion: size-aware deferral + short waits;
        re-raises engine-side exceptions (e.g. a timed-out slot acquire)."""
        if self._job is not None:
            self._job.wait(timeout_s)
            self._job = None


class RecvLease:
    """Zero-copy receive: tree views stay valid until ``release``."""

    def __init__(self, tree, header: dict, reader: Optional[SlotReader]):
        self.tree = tree
        self.header = header
        self._reader = reader

    @property
    def held(self) -> bool:
        """True while the lease still occupies its ring slot (a lease made
        from an already-copied message reports False)."""
        return self._reader is not None

    def release(self) -> None:
        """Recycle the slot; the leased views become invalid."""
        if self._reader is not None:
            self._reader.release()
            self._reader = None
            # the views are invalid once the slot is recycled; drop them so
            # they can't pin the arena mapping open (BufferError on close)
            self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


class TxSlot:
    """A reserved tx slot with typed writable views (reserve-then-fill).

    ``tree`` mirrors the template pytree with numpy views *into the slot
    payload*; write results straight into them, then :meth:`publish`.
    :meth:`abort` gives an unfillable slot back as a skip sentinel the
    receive path ignores.  As a context manager it publishes on clean
    exit and aborts if the block raised.
    """

    def __init__(self, tree, writer: SlotWriter, meta: bytes, nbytes: int,
                 channel: "DataChannel"):
        self.tree = tree
        self._writer = writer
        self._meta = meta
        self._nbytes = nbytes
        self._channel = channel

    def publish(self) -> None:
        """Write the (cached) descriptor meta and ring the doorbell."""
        if self._writer is None:
            return
        w, ch = self._writer, self._channel
        self._writer = None
        w.meta[:len(self._meta)] = self._meta
        w.publish(self._nbytes, len(self._meta))
        ch.stats.sends += 1
        ch.stats.inline += 1
        ch.stats.bytes_sent += self._nbytes
        self.tree = None

    def abort(self) -> None:
        """Give the slot back unfilled (publishes the skip sentinel)."""
        if self._writer is None:
            return
        self._writer.abort()
        self._writer = None
        self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            self.abort()
        else:
            self.publish()


@dataclass
class ChannelStats(HybridPollStats):
    """Per-channel counters: the shared hybrid-polling fields plus
    send/recv/byte totals and descriptor-cache effectiveness."""
    sends: int = 0
    recvs: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    descr_cache_hits: int = 0
    descr_cache_misses: int = 0


# ---------------------------------------------------------------------------
# the channel
# ---------------------------------------------------------------------------

class DataChannel:
    """Bidirectional typed channel over one tx ring + one rx ring."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring],
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 copy_engine: Optional[CopyEngine] = None,
                 descr_cache: bool = True):
        self.tx = tx
        self.rx = rx
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = ChannelStats()
        self._engine = copy_engine or get_engine()
        self._send_lock = threading.Lock()      # slot-order serialization
        self._inflight: deque[SendHandle] = deque()
        self._inflight_lock = threading.Lock()
        self._cache_enabled = descr_cache
        self._tx_descr_cache: OrderedDict = OrderedDict()
        self._rx_descr_cache: OrderedDict = OrderedDict()

    # -- wire encoding (descriptor cache) -------------------------------------
    def _encode(self, tree, header: Optional[dict]):
        """Build (meta bytes, descriptor, payload nbytes); the descriptor
        and its pickle are cached by structural signature, so steady-state
        sends pickle only the small header."""
        sig: Optional[tuple] = None
        hit = None
        if self._cache_enabled:
            toks: list = []
            _signature(tree, toks)
            sig = tuple(toks)
            hit = self._tx_descr_cache.get(sig)
        if hit is not None:
            descr, descr_bytes, nbytes = hit
            self._tx_descr_cache.move_to_end(sig)
            self.stats.descr_cache_hits += 1
        else:
            cursor = [0]
            descr = _pack_descr(tree, cursor)
            nbytes = cursor[0]
            descr_bytes = pickle.dumps(descr,
                                       protocol=pickle.HIGHEST_PROTOCOL)
            self.stats.descr_cache_misses += 1
            if self._cache_enabled:
                self._tx_descr_cache[sig] = (descr, descr_bytes, nbytes)
                while len(self._tx_descr_cache) > _DESCR_CACHE_MAX:
                    self._tx_descr_cache.popitem(last=False)
        header_bytes = pickle.dumps(header or {},
                                    protocol=pickle.HIGHEST_PROTOCOL)
        meta = _U32.pack(len(descr_bytes)) + descr_bytes + header_bytes
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B — create the transport with a "
                f"larger data_slot_bytes")
        if len(meta) > self.tx.spec.meta_bytes:
            raise ValueError(
                f"meta of {len(meta)} B exceeds meta capacity "
                f"{self.tx.spec.meta_bytes} B")
        return meta, descr, nbytes

    def _decode_meta(self, raw: bytes):
        """(header, descriptor) from wire meta; descriptors are cached by
        their pickled bytes so a stable stream skips ``pickle.loads``."""
        (dlen,) = _U32.unpack_from(raw, 0)
        descr_bytes = raw[4:4 + dlen]
        descr = self._rx_descr_cache.get(descr_bytes)
        if descr is None:
            descr = pickle.loads(descr_bytes)
            if self._cache_enabled:
                self._rx_descr_cache[descr_bytes] = descr
                while len(self._rx_descr_cache) > _DESCR_CACHE_MAX:
                    self._rx_descr_cache.popitem(last=False)
        else:
            self._rx_descr_cache.move_to_end(descr_bytes)
        header = pickle.loads(raw[4 + dlen:])
        return header, descr

    # -- send -----------------------------------------------------------------
    def _fill_and_publish(self, sg: SGList, meta: bytes, nbytes: int) -> None:
        w: SlotWriter = sg.ctx
        w.meta[:len(meta)] = meta
        w.publish(nbytes, len(meta))

    def _acquire_sg(self, tree, descr, timeout_s: float) -> SGList:
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    def _acquire_sg_nonblocking(self, tree, descr, timeout_s: float,
                                state: dict) -> SGList:
        """Engine-thread slot acquire: never blocks a shared copy-engine
        worker.  A full ring raises :class:`WouldBlock` so the engine parks
        this channel's work queue and retries at quantum cadence — other
        channels keep copying meanwhile; the blocking-path semantics
        (ChannelClosed on peer shutdown, TimeoutError after ``timeout_s``)
        are preserved."""
        if state.get("deadline") is None:
            state["deadline"] = time.perf_counter() + timeout_s
        with self._send_lock:
            writer = self.tx.try_acquire()
        if writer is None:
            if self.tx.peer_closed:
                raise ChannelClosed("peer endpoint closed the transport")
            if time.perf_counter() > state["deadline"]:
                raise TimeoutError(
                    f"ring full for {timeout_s}s (consumer stalled?)")
            raise WouldBlock(self.policy.poll_interval_us * 1e-6)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    def send(self, tree, header: Optional[dict] = None,
             mode: ExecutionMode | str | None = None,
             timeout_s: float = 30.0) -> SendHandle:
        """Send one pytree under the given (or policy) mode; see module
        docstring for the sync/async/pipelined semantics."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        meta, descr, nbytes = self._encode(tree, header)   # raises on oversize
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes

        if mode == ExecutionMode.SYNC or not self.policy.should_offload(nbytes):
            self.stats.inline += 1
            self.flush(timeout_s)      # FIFO: inline never overtakes offloads
            sg = self._acquire_sg(tree, descr, timeout_s)
            self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                                tag="send")
            self._fill_and_publish(sg, meta, nbytes)
            return SendHandle(self, nbytes)

        self.stats.offloaded += 1
        acquire_state: dict = {}       # deadline anchored at first attempt
        job = self._engine.submit(
            Descriptor(build=lambda: self._acquire_sg_nonblocking(
                           tree, descr, timeout_s, acquire_state),
                       complete=lambda sg: self._fill_and_publish(
                           sg, meta, nbytes),
                       nbytes=nbytes,
                       injection=self.policy.injection_enabled(),
                       tag="send"),
            wq=self, policy=self.policy, latency=self.latency,
            stats=self.stats)
        handle = SendHandle(self, nbytes, job=job)
        with self._inflight_lock:
            # track every offloaded send so flush() orders later sync sends
            # after it; prune cleanly-completed ones so async stays bounded
            # (a failed handle is kept: flush must surface its exception)
            while (self._inflight and self._inflight[0].done()
                   and not self._inflight[0].failed()):
                self._inflight.popleft()
            self._inflight.append(handle)
        if mode == ExecutionMode.PIPELINED:
            # bounded in-flight depth (the engine's backpressure, same shape)
            drain_to_depth(self._inflight, self._inflight_lock,
                           self.policy.pipeline_depth,
                           lambda h: h.wait(timeout_s))
        return handle

    def reserve(self, template, header: Optional[dict] = None,
                timeout_s: float = 30.0) -> TxSlot:
        """Reserve-then-fill: claim the next tx slot, lay it out for
        ``template`` (a pytree of arrays — shapes/dtypes only, nothing is
        copied), and return a :class:`TxSlot` of writable views.  The
        caller packs the message directly into the destination slot and
        calls ``publish()`` — no staging copy, and the descriptor meta
        comes from the same structure-keyed cache as ``send``."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        meta, descr, nbytes = self._encode(template, header)
        self.flush(timeout_s)          # FIFO wrt earlier offloaded sends
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        tree = _unpack(descr, writer.payload, copy=False)
        return TxSlot(tree, writer, meta, nbytes, self)

    def flush(self, timeout_s: float = 30.0) -> None:
        """Complete all outstanding pipelined sends (batch-level check)."""
        with self._inflight_lock:
            pending, self._inflight = self._inflight, deque()
        for h in pending:
            h.wait(timeout_s)

    # -- recv -----------------------------------------------------------------
    def _lease_from_reader(self, reader: SlotReader, copy: bool):
        header, descr = self._decode_meta(reader.meta)
        self.stats.recvs += 1
        self.stats.bytes_recv += reader.payload_nbytes
        payload = reader.slot.payload_view
        if copy:
            tree = _unpack(descr, payload, copy=True)
            # counted staging copy: the receive-side memcpy the zero-copy
            # serving path exists to eliminate
            self._engine.count("recv_copy", _count_leaves(descr),
                               reader.payload_nbytes)
            reader.release()
            return tree, header
        return RecvLease(_unpack(descr, payload, copy=False), header, reader)

    def recv(self, timeout_s: float = 30.0, copy: bool = True,
             hint_nbytes: int = 0):
        """Receive one pytree; ``copy=False`` returns a :class:`RecvLease`
        whose arrays are zero-copy views into the slot."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        deadline = time.perf_counter() + timeout_s
        while True:
            reader = self.rx.wait_recv(
                max(1e-3, deadline - time.perf_counter()), hint_nbytes)
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                hint_nbytes = 0
                continue
            return self._lease_from_reader(reader, copy)

    def try_recv(self, copy: bool = True):
        """Non-blocking receive; None when no message is ready."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        while True:
            reader = self.rx.try_poll()
            if reader is None:
                return None
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                continue
            return self._lease_from_reader(reader, copy)

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout_s: float = 5.0) -> None:
        """Flush outstanding sends (the shared copy engine stays up — it
        serves every other channel in the process)."""
        try:
            self.flush(timeout_s)
        except (TimeoutError, ChannelClosed):
            pass


class ControlChannel:
    """Small pickled-object messages (commands, acks) over tiny slots.

    Both receive paths surface :class:`~repro.ipc.ring.ChannelClosed`
    consistently once the peer endpoint announced shutdown (after the
    ring is drained), so callers never have to poke ring internals to
    distinguish "no message yet" from "peer is gone"."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring]):
        self.tx = tx
        self.rx = rx
        self._lock = threading.Lock()

    def send_msg(self, obj: Any, timeout_s: float = 30.0) -> None:
        """Send one small pickled message (blocks while the ring is full)."""
        blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > self.tx.spec.slot_bytes:
            raise ValueError(f"control message of {len(blob)} B too large")
        with self._lock:
            w = self.tx.acquire(timeout_s)
            w.payload[:len(blob)] = blob
            w.publish(len(blob))

    def recv_msg(self, timeout_s: float = 30.0) -> Any:
        """Blocking receive of one message; raises
        :class:`~repro.ipc.ring.ChannelClosed` when the peer shut down
        while we were waiting (in-flight messages are delivered first)."""
        with self.rx.wait_recv(timeout_s) as r:
            return pickle.loads(r.payload)

    def try_recv_msg(self) -> Any:
        """Non-blocking receive; None when no message is waiting, and
        :class:`~repro.ipc.ring.ChannelClosed` once the peer announced
        shutdown and the ring is fully drained."""
        r = self.rx.try_poll()
        if r is None:
            if self.rx.peer_closed:
                raise ChannelClosed(
                    "control peer closed and the ring is drained")
            return None
        with r:
            return pickle.loads(r.payload)
