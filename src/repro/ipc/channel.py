"""Typed channels over shm rings: numpy pytrees, ROCKET send modes.

A :class:`DataChannel` sends pytrees (nested dict/list/tuple) of numpy
arrays through one :class:`~repro.ipc.ring.Ring`.  The wire format is

- **meta**: ``[u32 descriptor length | descriptor pickle | header pickle]``
  where the descriptor mirrors the tree structure with each array leaf
  replaced by ``(offset, shape, dtype)``.  Descriptors are **cached by
  structural signature** (tree shape + leaf shapes/dtypes) on the sender
  and by descriptor bytes on the receiver, so steady-state sends of a
  stable structure skip ``pickle.dumps``/``loads`` of the descriptor
  entirely — only the small per-message header is pickled;
- **payload**: the arrays' bytes packed back-to-back at 64-byte-aligned
  offsets inside the slot — one scatter-gather descriptor per tree,
  executed by the process-wide :class:`~repro.core.copyengine.CopyEngine`
  (a single counted memcpy per leaf into pre-mapped shared memory), and
  *zero* copies on the receive side when the caller asks for views
  (``copy=False``).

Send modes follow :class:`~repro.core.policy.OffloadPolicy` exactly like
the tier-1 engine (the paper's Table III):

- ``sync``       — the caller performs the copy inline and the handle is
  complete on return (cpu/DTO);
- ``async``      — the shared copy engine (one work queue per channel, so
  FIFO order holds without a per-channel thread) performs slot acquire +
  copy + publish; ``send`` returns a handle immediately and
  ``handle.wait()`` applies hybrid polling;
- ``pipelined``  — async plus bounded in-flight depth: when more than
  ``pipeline_depth`` sends are outstanding the oldest is completed first
  (backpressure), with the blocking wait held *outside* the channel lock.

Small below-threshold messages stay inline in every mode (size-based
offload control).

The **reserve-then-fill** path (:meth:`DataChannel.reserve`) exposes the
ring's :class:`~repro.ipc.ring.SlotWriter` as a typed :class:`TxSlot`:
the caller claims the destination slot first and packs the message
directly into it (e.g. a serving reply written straight into the
client's tx slot), eliminating the staging copy a ``send`` of an
already-materialized tree would add.

The **large-message datapath**: when the transport attached a
:class:`~repro.ipc.heap.BulkHeap`, payloads at/over
``policy.heap_threshold_bytes`` (and anything that would not fit a slot)
are written into heap *extents* instead and the ring slot carries only
the compact extent descriptor (``FLAG_HEAP``).  Sync mode fills the
extents with one blocking gather; async/pipelined split the fill into
``policy.heap_chunk_bytes`` SG submissions on the channel's work queue,
so the copy of message k+1 overlaps the peer's drain of message k.
Receivers get zero-copy views into the extents (scatter allocations
reassemble only boundary-straddling leaves, counted), and the *lease
release frees the extents* — receiver-driven reclamation, with a held
lease acting as byte-granular backpressure on the sender's allocator.
"""
from __future__ import annotations

import pickle
import struct
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Optional

import numpy as np

from repro.core.copyengine import (
    CopyEngine,
    CopyJob,
    Descriptor,
    HybridPollStats,
    SGList,
    WouldBlock,
    get_engine,
    split_sg,
)
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.core.queuepair import drain_to_depth
from repro.ipc.heap import MAX_SEGMENTS, BulkHeap, HeapExhausted
from repro.ipc.ring import (
    FLAG_HEAP,
    ChannelClosed,
    Ring,
    SlotReader,
    SlotWriter,
    _align,
)

from dataclasses import dataclass

_U32 = struct.Struct("<I")
_DESCR_CACHE_MAX = 64
# header key carrying the heap scatter list on the wire (stripped before
# the header dict reaches the application)
_HX_KEY = "__rocket_hx__"


# ---------------------------------------------------------------------------
# pytree packing (stdlib-only: no jax dependency inside the IPC layer)
# ---------------------------------------------------------------------------

class _Leaf:
    __slots__ = ("offset", "shape", "dtype")

    def __init__(self, offset: int, shape, dtype: str):
        self.offset = offset
        self.shape = tuple(shape)
        self.dtype = dtype


def _pack_descr(tree, cursor: list[int]):
    """Replace array leaves with placement descriptors; returns mirror tree."""
    if isinstance(tree, dict):
        return {k: _pack_descr(v, cursor) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        packed = [_pack_descr(v, cursor) for v in tree]
        return packed if isinstance(tree, list) else tuple(packed)
    arr = np.asarray(tree)
    leaf = _Leaf(cursor[0], arr.shape, arr.dtype.str)
    cursor[0] += _align(arr.nbytes)
    return leaf


# structure-signature markers (distinct from any dict key / dtype string)
_SIG_DICT, _SIG_LIST, _SIG_TUPLE = 0, 1, 2


def _signature(tree, out: list) -> None:
    """Flatten the tree's *structure* (container shape, keys, leaf
    shapes/dtypes) into a hashable token list — the descriptor-cache key.
    Any structural change (new key, reordered keys, different shape or
    dtype) yields a different signature, which is the cache invalidation."""
    if isinstance(tree, dict):
        out.append(_SIG_DICT)
        out.append(len(tree))
        for k, v in tree.items():
            out.append(k)
            _signature(v, out)
        return
    if isinstance(tree, (list, tuple)):
        out.append(_SIG_LIST if isinstance(tree, list) else _SIG_TUPLE)
        out.append(len(tree))
        for v in tree:
            _signature(v, out)
        return
    arr = np.asarray(tree)
    out.append(arr.dtype.str)
    out.append(arr.shape)


def _gather_sg(tree, descr, payload: memoryview, sg: SGList) -> None:
    """Append one SG entry per leaf: leaf bytes → its slot placement."""
    if isinstance(descr, dict):
        for k, d in descr.items():
            _gather_sg(tree[k], d, payload, sg)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _gather_sg(v, d, payload, sg)
        return
    arr = np.asarray(tree)
    dst = np.frombuffer(payload, np.uint8, count=arr.nbytes,
                        offset=descr.offset)
    sg.add(arr, dst)


def _unpack(descr, payload: memoryview, copy: bool):
    if isinstance(descr, dict):
        return {k: _unpack(d, payload, copy) for k, d in descr.items()}
    if isinstance(descr, (list, tuple)):
        out = [_unpack(d, payload, copy) for d in descr]
        return out if isinstance(descr, list) else tuple(out)
    dtype = np.dtype(descr.dtype)
    count = int(np.prod(descr.shape)) if descr.shape else 1
    arr = np.frombuffer(payload, dtype, count=count,
                        offset=descr.offset).reshape(descr.shape)
    return arr.copy() if copy else arr


def _heap_fill_sg(tree, descr, heap: BulkHeap, direction: int, segments,
                  total_nbytes: int, sg: SGList) -> None:
    """One flat-u8 SG entry per (leaf, heap piece): leaf bytes → the heap
    range(s) its virtual placement resolves to.  Contiguous allocations
    yield exactly one entry per leaf; scatter allocations split leaves
    that straddle a segment boundary (still one *logical* copy — the
    submitter accounts with ``count_copies``)."""
    if isinstance(descr, dict):
        for k, d in descr.items():
            _heap_fill_sg(tree[k], d, heap, direction, segments,
                          total_nbytes, sg)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _heap_fill_sg(v, d, heap, direction, segments, total_nbytes, sg)
        return
    arr = np.asarray(tree)
    if not arr.flags["C_CONTIGUOUS"]:
        arr = np.ascontiguousarray(arr)
    src = arr.reshape(-1).view(np.uint8)
    off = 0
    for piece in heap.resolve(direction, segments, descr.offset, arr.nbytes,
                              total_nbytes):
        sg.add_array(src[off:off + piece.nbytes], piece)
        off += piece.nbytes


def _unpack_heap(descr, heap: BulkHeap, direction: int, segments,
                 total_nbytes: int, copy: bool):
    """Rebuild a pytree from heap extents.  ``copy=False`` returns
    zero-copy views for every leaf that lies inside one segment and
    reassembles (one counted copy) only boundary-straddling leaves;
    returns ``(tree, reassembled_copies, reassembled_bytes)``."""
    counters = [0, 0]

    def walk(d):
        if isinstance(d, dict):
            return {k: walk(v) for k, v in d.items()}
        if isinstance(d, (list, tuple)):
            out = [walk(v) for v in d]
            return out if isinstance(d, list) else tuple(out)
        dtype = np.dtype(d.dtype)
        count = int(np.prod(d.shape)) if d.shape else 1
        nbytes = count * dtype.itemsize
        pieces = heap.resolve(direction, segments, d.offset, nbytes,
                              total_nbytes)
        if len(pieces) == 1 and not copy:
            return np.frombuffer(pieces[0], dtype,
                                 count=count).reshape(d.shape)
        buf = np.empty(count, dtype)
        u8, off = buf.view(np.uint8), 0
        for p in pieces:
            u8[off:off + p.nbytes] = p
            off += p.nbytes
        if not copy:                   # straddler reassembled under a lease
            counters[0] += 1
            counters[1] += nbytes
        return buf.reshape(d.shape)

    return walk(descr), counters[0], counters[1]


def _writable_heap_tree(descr, heap: BulkHeap, direction: int, segments,
                        total_nbytes: int):
    """Reserve-then-fill layout over heap extents: leaves contiguous in
    one segment become writable views straight into the heap; straddlers
    get a staging array copied in at publish.  Returns ``(tree, staged)``
    with ``staged`` a list of ``(array, leaf_descr)`` pairs."""
    staged: list = []

    def walk(d):
        if isinstance(d, dict):
            return {k: walk(v) for k, v in d.items()}
        if isinstance(d, (list, tuple)):
            out = [walk(v) for v in d]
            return out if isinstance(d, list) else tuple(out)
        dtype = np.dtype(d.dtype)
        count = int(np.prod(d.shape)) if d.shape else 1
        pieces = heap.resolve(direction, segments, d.offset,
                              count * dtype.itemsize, total_nbytes)
        if len(pieces) == 1:
            return np.frombuffer(pieces[0], dtype,
                                 count=count).reshape(d.shape)
        buf = np.empty(d.shape, dtype)
        staged.append((buf, d))
        return buf

    return walk(descr), staged


def _count_leaves(descr) -> int:
    if isinstance(descr, dict):
        return sum(_count_leaves(d) for d in descr.values())
    if isinstance(descr, (list, tuple)):
        return sum(_count_leaves(d) for d in descr)
    return 1


def tree_nbytes(tree) -> int:
    """Total payload bytes of every array leaf in a pytree."""
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    return np.asarray(tree).nbytes


# ---------------------------------------------------------------------------
# completion handles / leases
# ---------------------------------------------------------------------------

class SendHandle:
    """Completion flag for one send (the job-id side of the paper's API);
    offloaded sends are backed by a copy-engine completion record."""

    def __init__(self, channel: "DataChannel", nbytes: int,
                 job: Optional[CopyJob] = None):
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self._job = job

    def done(self) -> bool:
        """True once the copy has been published (never blocks)."""
        return self._job is None or self._job.done()

    def failed(self) -> bool:
        """True when the offloaded send completed with an exception."""
        return self._job is not None and self._job.failed()

    def wait(self, timeout_s: float = 30.0) -> None:
        """Hybrid-polling completion: size-aware deferral + short waits;
        re-raises engine-side exceptions (e.g. a timed-out slot acquire)."""
        if self._job is not None:
            self._job.wait(timeout_s)
            self._job = None


class RecvLease:
    """Zero-copy receive: tree views stay valid until ``release``.

    A lease over a heap-routed message additionally owns its extents:
    ``release`` frees them back to the sender's allocator (``on_release``)
    — the *receiver-driven* reclamation that makes heap lifetime equal
    lease lifetime, and a held lease the sender's backpressure."""

    def __init__(self, tree, header: dict, reader: Optional[SlotReader],
                 on_release=None):
        self.tree = tree
        self.header = header
        self._reader = reader
        self._on_release = on_release

    @property
    def held(self) -> bool:
        """True while the lease still occupies its ring slot or heap
        extents (a lease made from an already-copied message reports
        False)."""
        return self._reader is not None or self._on_release is not None

    def release(self) -> None:
        """Recycle the slot and free any heap extents; the leased views
        become invalid."""
        released = False
        if self._reader is not None:
            self._reader.release()
            self._reader = None
            released = True
        if self._on_release is not None:
            cb, self._on_release = self._on_release, None
            cb()
            released = True
        if released:
            # the views are invalid once the slot/extents are recycled;
            # drop them so they can't pin the arena mapping open
            # (BufferError on close)
            self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


class TxSlot:
    """A reserved tx destination with typed writable views
    (reserve-then-fill).

    ``tree`` mirrors the template pytree with numpy views *into the
    destination* — a ring slot's payload region, or (for large templates)
    bulk-heap extents; write results straight into them, then
    :meth:`publish`.  :meth:`abort` gives an unfillable reservation back
    (slot path: a skip sentinel the receive path ignores; heap path: the
    extents return to FREE — no ring slot was claimed yet, so there is
    nothing to sentinel).  As a context manager it publishes on clean
    exit and aborts if the block raised.
    """

    def __init__(self, tree, writer: Optional[SlotWriter], meta: bytes,
                 nbytes: int, channel: "DataChannel",
                 heap_state: Optional[dict] = None):
        self.tree = tree
        self._writer = writer
        self._meta = meta
        self._nbytes = nbytes
        self._channel = channel
        self._heap = heap_state
        self._done = False

    def _publish_heap(self) -> None:
        """Stage straddling leaves into their extents, then claim a ring
        slot for the compact extent descriptor and ring the doorbell.  Any
        failure (meta overflow, ring acquire timeout) frees the extents —
        ownership only transfers on a successful publish."""
        hs, ch = self._heap, self._channel
        heap = ch._heap
        try:
            if hs["staged"]:
                sg = SGList()
                for buf, d in hs["staged"]:
                    src = np.ascontiguousarray(buf).reshape(-1).view(np.uint8)
                    off = 0
                    for piece in heap.resolve(heap.tx_dir, hs["segments"],
                                              d.offset, src.nbytes,
                                              self._nbytes):
                        sg.add_array(src[off:off + piece.nbytes], piece)
                        off += piece.nbytes
                ch._engine.run_sg(sg, injection=ch.policy.injection_enabled(),
                                  tag="heap_stage",
                                  count_copies=len(hs["staged"]))
            meta = ch._meta_bytes(hs["descr_bytes"], hs["header"],
                                  hs["segments"])
            with ch._send_lock:
                w = ch.tx.acquire(hs["timeout_s"])
        except BaseException:
            heap.free(hs["segments"], heap.tx_dir)
            raise
        w.meta[:len(meta)] = meta
        w.publish(self._nbytes, len(meta), flags=FLAG_HEAP)
        ch.stats.sends += 1
        ch.stats.inline += 1
        ch.stats.heap_sends += 1
        ch.stats.bytes_sent += self._nbytes

    def publish(self) -> None:
        """Write the (cached) descriptor meta and ring the doorbell."""
        if self._done:
            return
        self._done = True
        ch = self._channel
        if self._heap is not None:
            self._publish_heap()
            self.tree = None
            return
        w = self._writer
        self._writer = None
        w.meta[:len(self._meta)] = self._meta
        w.publish(self._nbytes, len(self._meta))
        ch.stats.sends += 1
        ch.stats.inline += 1
        ch.stats.bytes_sent += self._nbytes
        self.tree = None

    def abort(self) -> None:
        """Give the reservation back unfilled (slot: skip sentinel; heap:
        extents freed)."""
        if self._done:
            return
        self._done = True
        if self._heap is not None:
            ch = self._channel
            ch._heap.free(self._heap["segments"], ch._heap.tx_dir)
        else:
            self._writer.abort()
            self._writer = None
        self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            self.abort()
        else:
            self.publish()


@dataclass
class ChannelStats(HybridPollStats):
    """Per-channel counters: the shared hybrid-polling fields plus
    send/recv/byte totals and descriptor-cache effectiveness."""
    sends: int = 0
    recvs: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    descr_cache_hits: int = 0
    descr_cache_misses: int = 0
    heap_sends: int = 0          # messages routed through bulk-heap extents
    heap_recvs: int = 0
    heap_reassembles: int = 0    # straddling leaves rebuilt with a copy


# ---------------------------------------------------------------------------
# the channel
# ---------------------------------------------------------------------------

class DataChannel:
    """Bidirectional typed channel over one tx ring + one rx ring."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring],
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 copy_engine: Optional[CopyEngine] = None,
                 descr_cache: bool = True,
                 heap: Optional[BulkHeap] = None):
        self.tx = tx
        self.rx = rx
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = ChannelStats()
        self._engine = copy_engine or get_engine()
        self._heap = heap
        self._send_lock = threading.Lock()      # slot-order serialization
        self._inflight: deque[SendHandle] = deque()
        self._inflight_lock = threading.Lock()
        self._cache_enabled = descr_cache
        self._tx_descr_cache: OrderedDict = OrderedDict()
        self._rx_descr_cache: OrderedDict = OrderedDict()

    def bind_heap(self, heap: Optional[BulkHeap]) -> None:
        """Attach the connection's bulk heap: payloads at/over
        ``policy.heap_threshold_bytes`` (and anything over the slot
        capacity) are routed through heap extents from now on."""
        self._heap = heap

    def _use_heap(self, nbytes: int) -> bool:
        """Inline-slot vs heap path selection (OffloadPolicy threshold)."""
        if self._heap is None or not self._heap.spec.enabled:
            return False
        return (nbytes > self.tx.spec.slot_bytes
                or nbytes >= self.policy.heap_threshold_bytes)

    # -- wire encoding (descriptor cache) -------------------------------------
    def _encode_descr(self, tree):
        """Build (descriptor, descriptor bytes, payload nbytes); the
        descriptor and its pickle are cached by structural signature, so
        steady-state sends pickle only the small header."""
        sig: Optional[tuple] = None
        hit = None
        if self._cache_enabled:
            toks: list = []
            _signature(tree, toks)
            sig = tuple(toks)
            hit = self._tx_descr_cache.get(sig)
        if hit is not None:
            descr, descr_bytes, nbytes = hit
            self._tx_descr_cache.move_to_end(sig)
            self.stats.descr_cache_hits += 1
        else:
            cursor = [0]
            descr = _pack_descr(tree, cursor)
            nbytes = cursor[0]
            descr_bytes = pickle.dumps(descr,
                                       protocol=pickle.HIGHEST_PROTOCOL)
            self.stats.descr_cache_misses += 1
            if self._cache_enabled:
                self._tx_descr_cache[sig] = (descr, descr_bytes, nbytes)
                while len(self._tx_descr_cache) > _DESCR_CACHE_MAX:
                    self._tx_descr_cache.popitem(last=False)
        return descr, descr_bytes, nbytes

    def _meta_bytes(self, descr_bytes: bytes, header: Optional[dict],
                    segments=None) -> bytes:
        """Assemble wire meta ``[u32 len | descr pickle | header pickle]``;
        a heap message rides its scatter list inside the header under a
        reserved key (stripped again on receive)."""
        if segments is not None:
            header = dict(header or {})
            header[_HX_KEY] = tuple(segments)
        header_bytes = pickle.dumps(header or {},
                                    protocol=pickle.HIGHEST_PROTOCOL)
        meta = _U32.pack(len(descr_bytes)) + descr_bytes + header_bytes
        if len(meta) > self.tx.spec.meta_bytes:
            raise ValueError(
                f"meta of {len(meta)} B exceeds meta capacity "
                f"{self.tx.spec.meta_bytes} B")
        return meta

    def _decode_meta(self, raw: bytes):
        """(header, descriptor) from wire meta; descriptors are cached by
        their pickled bytes so a stable stream skips ``pickle.loads``."""
        (dlen,) = _U32.unpack_from(raw, 0)
        descr_bytes = raw[4:4 + dlen]
        descr = self._rx_descr_cache.get(descr_bytes)
        if descr is None:
            descr = pickle.loads(descr_bytes)
            if self._cache_enabled:
                self._rx_descr_cache[descr_bytes] = descr
                while len(self._rx_descr_cache) > _DESCR_CACHE_MAX:
                    self._rx_descr_cache.popitem(last=False)
        else:
            self._rx_descr_cache.move_to_end(descr_bytes)
        header = pickle.loads(raw[4 + dlen:])
        return header, descr

    # -- send -----------------------------------------------------------------
    def _fill_and_publish(self, sg: SGList, meta: bytes, nbytes: int) -> None:
        w: SlotWriter = sg.ctx
        w.meta[:len(meta)] = meta
        w.publish(nbytes, len(meta))

    def _acquire_sg(self, tree, descr, timeout_s: float) -> SGList:
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    def _acquire_sg_nonblocking(self, tree, descr, timeout_s: float,
                                state: dict) -> SGList:
        """Engine-thread slot acquire: never blocks a shared copy-engine
        worker.  A full ring raises :class:`WouldBlock` so the engine parks
        this channel's work queue and retries at quantum cadence — other
        channels keep copying meanwhile; the blocking-path semantics
        (ChannelClosed on peer shutdown, TimeoutError after ``timeout_s``)
        are preserved."""
        if state.get("deadline") is None:
            state["deadline"] = time.perf_counter() + timeout_s
        with self._send_lock:
            writer = self.tx.try_acquire()
        if writer is None:
            if self.tx.peer_closed:
                raise ChannelClosed("peer endpoint closed the transport")
            if time.perf_counter() > state["deadline"]:
                raise TimeoutError(
                    f"ring full for {timeout_s}s (consumer stalled?)")
            raise WouldBlock(self.policy.poll_interval_us * 1e-6)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    # -- heap (large-message) send path ---------------------------------------
    def _heap_alloc_blocking(self, nbytes: int, timeout_s: float):
        """Blocking extent allocation that converts "peer died while we
        waited" into the channel's usual :class:`ChannelClosed`."""
        try:
            return self._heap.alloc(
                nbytes, timeout_s=timeout_s,
                poll_interval_s=self.policy.poll_interval_us * 1e-6,
                abort_check=lambda: self.tx.peer_closed)
        except HeapExhausted as e:
            raise ChannelClosed(str(e)) from None

    def _validate_heap_meta(self, descr_bytes: bytes,
                            header: Optional[dict]) -> None:
        """Fail a heap send *before* any copy/alloc when even a
        worst-case scatter list cannot fit the ring's meta region."""
        cap = self._heap.spec.dir_bytes
        self._meta_bytes(descr_bytes, header, ((cap, cap),) * MAX_SEGMENTS)

    def _send_heap_inline(self, tree, descr, descr_bytes, header,
                          nbytes: int, timeout_s: float) -> SendHandle:
        """Sync/below-offload heap send: one blocking gather into the
        extents on the caller's thread, then publish the descriptor."""
        self.stats.inline += 1
        self.flush(timeout_s)      # FIFO: inline never overtakes offloads
        segs = self._heap_alloc_blocking(nbytes, timeout_s)
        heap = self._heap
        try:
            sg = SGList()
            _heap_fill_sg(tree, descr, heap, heap.tx_dir, segs, nbytes, sg)
            self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                                tag="heap_fill",
                                count_copies=_count_leaves(descr))
            meta = self._meta_bytes(descr_bytes, header, segs)
            with self._send_lock:
                w = self.tx.acquire(timeout_s)
        except BaseException:
            heap.free(segs, heap.tx_dir)   # ownership transfers at publish
            raise
        w.meta[:len(meta)] = meta
        w.publish(nbytes, len(meta), flags=FLAG_HEAP)
        return SendHandle(self, nbytes)

    def _send_heap_offloaded(self, tree, descr, descr_bytes, header,
                             nbytes: int, timeout_s: float) -> SendHandle:
        """Async/pipelined heap send: the fill is split into chunk-sized
        SG submissions on this channel's work queue (copy of message k+1
        overlaps the peer's drain of message k), the last submission
        claims a ring slot and publishes the extent descriptor."""
        self.stats.offloaded += 1
        heap = self._heap
        n_leaves = _count_leaves(descr)
        chunk_bytes = max(1, self.policy.heap_chunk_bytes)
        n_chunks = max(1, -(-nbytes // chunk_bytes))
        chunk_jobs: list[CopyJob] = []
        state: dict = {"segs": None, "chunks": None, "err": None,
                       "alloc_deadline": None, "ring_deadline": None}

        def fail(e: BaseException):
            state["err"] = e
            raise e

        def build_chunk(i: int) -> SGList:
            if state["err"] is not None:
                raise state["err"]
            if i == 0 and state["chunks"] is None:
                if state["alloc_deadline"] is None:
                    state["alloc_deadline"] = time.perf_counter() + timeout_s
                segs = heap.try_alloc(nbytes)
                if segs is None:
                    if self.tx.peer_closed:
                        fail(ChannelClosed(
                            "peer endpoint closed the transport"))
                    if time.perf_counter() > state["alloc_deadline"]:
                        fail(TimeoutError(
                            f"bulk heap exhausted for {timeout_s}s "
                            f"(receiver holding leases?)"))
                    raise WouldBlock(self.policy.poll_interval_us * 1e-6)
                try:
                    sg = SGList()
                    _heap_fill_sg(tree, descr, heap, heap.tx_dir, segs,
                                  nbytes, sg)
                    state["chunks"] = split_sg(sg, chunk_bytes)
                    state["segs"] = segs
                except BaseException as e:
                    heap.free(segs, heap.tx_dir)
                    fail(e)
            chunks = state["chunks"]
            if chunks is None:
                raise RuntimeError("heap fill aborted (earlier chunk failed)")
            return chunks[i] if i < len(chunks) else SGList()

        def build_final() -> SGList:
            if state["err"] is not None:
                raise state["err"]
            if state["segs"] is None:
                raise RuntimeError("heap fill aborted (earlier chunk failed)")
            # chunk jobs are fire-and-forget, so a copy failure on the
            # engine thread (not routed through fail()) must be surfaced
            # HERE: publishing after a failed chunk would hand the
            # receiver a payload with an uncopied hole as a success
            for j in chunk_jobs:
                if j.failed():
                    heap.free(state["segs"], heap.tx_dir)
                    state["segs"] = None
                    j.wait(0)              # re-raises the chunk's exception
            if state["ring_deadline"] is None:
                state["ring_deadline"] = time.perf_counter() + timeout_s
            with self._send_lock:
                writer = self.tx.try_acquire()
            if writer is None:
                if self.tx.peer_closed:
                    heap.free(state["segs"], heap.tx_dir)
                    fail(ChannelClosed(
                        "peer endpoint closed the transport"))
                if time.perf_counter() > state["ring_deadline"]:
                    heap.free(state["segs"], heap.tx_dir)
                    fail(TimeoutError(
                        f"ring full for {timeout_s}s (consumer stalled?)"))
                raise WouldBlock(self.policy.poll_interval_us * 1e-6)
            sg = SGList()
            sg.ctx = writer
            return sg

        def complete_final(sg: SGList):
            writer: SlotWriter = sg.ctx
            try:
                meta = self._meta_bytes(descr_bytes, header, state["segs"])
            except BaseException:
                heap.free(state["segs"], heap.tx_dir)
                writer.abort()
                raise
            writer.meta[:len(meta)] = meta
            writer.publish(nbytes, len(meta), flags=FLAG_HEAP)

        inject = self.policy.injection_enabled()
        for i in range(n_chunks):
            chunk_jobs.append(self._engine.submit(
                Descriptor(build=lambda i=i: build_chunk(i),
                           nbytes=min(chunk_bytes, nbytes - i * chunk_bytes),
                           injection=inject, tag="heap_fill",
                           count_copies=n_leaves if i == 0 else 0),
                wq=self, policy=self.policy, latency=self.latency,
                stats=self.stats))
        job = self._engine.submit(
            Descriptor(build=build_final, complete=complete_final,
                       nbytes=nbytes, injection=inject, tag="heap_publish",
                       count_copies=0),
            wq=self, policy=self.policy, latency=self.latency,
            stats=self.stats)
        return SendHandle(self, nbytes, job=job)

    def _send_heap(self, tree, descr, descr_bytes, header,
                   nbytes: int, mode: ExecutionMode,
                   timeout_s: float) -> SendHandle:
        """Route one large pytree through the bulk heap; the ring carries
        only the compact extent descriptor."""
        self._validate_heap_meta(descr_bytes, header)   # before any counting
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes
        self.stats.heap_sends += 1
        if mode == ExecutionMode.SYNC or not self.policy.should_offload(nbytes):
            return self._send_heap_inline(tree, descr, descr_bytes, header,
                                          nbytes, timeout_s)
        handle = self._send_heap_offloaded(tree, descr, descr_bytes, header,
                                           nbytes, timeout_s)
        with self._inflight_lock:
            while (self._inflight and self._inflight[0].done()
                   and not self._inflight[0].failed()):
                self._inflight.popleft()
            self._inflight.append(handle)
        if mode == ExecutionMode.PIPELINED:
            drain_to_depth(self._inflight, self._inflight_lock,
                           self.policy.pipeline_depth,
                           lambda h: h.wait(timeout_s))
        return handle

    def send(self, tree, header: Optional[dict] = None,
             mode: ExecutionMode | str | None = None,
             timeout_s: float = 30.0) -> SendHandle:
        """Send one pytree under the given (or policy) mode; see module
        docstring for the sync/async/pipelined semantics.  Payloads at or
        above ``policy.heap_threshold_bytes`` (or over the slot capacity)
        take the bulk-heap path when the transport has one."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        descr, descr_bytes, nbytes = self._encode_descr(tree)
        if self._use_heap(nbytes):
            return self._send_heap(tree, descr, descr_bytes, header, nbytes,
                                   mode, timeout_s)
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B and no bulk heap is attached "
                f"— raise data_slot_bytes or enable heap_extents")
        meta = self._meta_bytes(descr_bytes, header)
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes

        if mode == ExecutionMode.SYNC or not self.policy.should_offload(nbytes):
            self.stats.inline += 1
            self.flush(timeout_s)      # FIFO: inline never overtakes offloads
            sg = self._acquire_sg(tree, descr, timeout_s)
            self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                                tag="send")
            self._fill_and_publish(sg, meta, nbytes)
            return SendHandle(self, nbytes)

        self.stats.offloaded += 1
        acquire_state: dict = {}       # deadline anchored at first attempt
        job = self._engine.submit(
            Descriptor(build=lambda: self._acquire_sg_nonblocking(
                           tree, descr, timeout_s, acquire_state),
                       complete=lambda sg: self._fill_and_publish(
                           sg, meta, nbytes),
                       nbytes=nbytes,
                       injection=self.policy.injection_enabled(),
                       tag="send"),
            wq=self, policy=self.policy, latency=self.latency,
            stats=self.stats)
        handle = SendHandle(self, nbytes, job=job)
        with self._inflight_lock:
            # track every offloaded send so flush() orders later sync sends
            # after it; prune cleanly-completed ones so async stays bounded
            # (a failed handle is kept: flush must surface its exception)
            while (self._inflight and self._inflight[0].done()
                   and not self._inflight[0].failed()):
                self._inflight.popleft()
            self._inflight.append(handle)
        if mode == ExecutionMode.PIPELINED:
            # bounded in-flight depth (the engine's backpressure, same shape)
            drain_to_depth(self._inflight, self._inflight_lock,
                           self.policy.pipeline_depth,
                           lambda h: h.wait(timeout_s))
        return handle

    def reserve(self, template, header: Optional[dict] = None,
                timeout_s: float = 30.0) -> TxSlot:
        """Reserve-then-fill: claim the next tx slot, lay it out for
        ``template`` (a pytree of arrays — shapes/dtypes only, nothing is
        copied), and return a :class:`TxSlot` of writable views.  The
        caller packs the message directly into the destination slot and
        calls ``publish()`` — no staging copy, and the descriptor meta
        comes from the same structure-keyed cache as ``send``.

        A template at/over ``policy.heap_threshold_bytes`` (or over the
        slot capacity) reserves bulk-heap extents instead: the returned
        views point into the heap, and ``publish()`` claims a ring slot
        only for the compact extent descriptor."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        descr, descr_bytes, nbytes = self._encode_descr(template)
        if self._use_heap(nbytes):
            self._validate_heap_meta(descr_bytes, header)
            self.flush(timeout_s)      # FIFO wrt earlier offloaded sends
            segs = self._heap_alloc_blocking(nbytes, timeout_s)
            tree, staged = _writable_heap_tree(descr, self._heap,
                                               self._heap.tx_dir, segs,
                                               nbytes)
            return TxSlot(tree, None, b"", nbytes, self,
                          heap_state={"segments": segs, "staged": staged,
                                      "descr_bytes": descr_bytes,
                                      "header": header,
                                      "timeout_s": timeout_s})
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B and no bulk heap is attached "
                f"— raise data_slot_bytes or enable heap_extents")
        meta = self._meta_bytes(descr_bytes, header)
        self.flush(timeout_s)          # FIFO wrt earlier offloaded sends
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        tree = _unpack(descr, writer.payload, copy=False)
        return TxSlot(tree, writer, meta, nbytes, self)

    def flush(self, timeout_s: float = 30.0) -> None:
        """Complete all outstanding pipelined sends (batch-level check)."""
        with self._inflight_lock:
            pending, self._inflight = self._inflight, deque()
        for h in pending:
            h.wait(timeout_s)

    # -- recv -----------------------------------------------------------------
    def _lease_from_heap(self, reader: SlotReader, header: dict, descr,
                         copy: bool):
        """Resolve a heap-routed message: the ring slot held only the
        extent descriptor, so it is released immediately — the lease (and
        its backpressure) is the *extents*, freed on release/unpack."""
        heap = self._heap
        segs = header.pop(_HX_KEY, None)
        if heap is None or not heap.spec.enabled or segs is None:
            reader.release()
            raise RuntimeError(
                "received a heap-routed message on a transport without a "
                "bulk heap (mismatched TransportSpec?)")
        nbytes = reader.payload_nbytes         # heap bytes (FLAG_HEAP)
        self.stats.recvs += 1
        self.stats.heap_recvs += 1
        self.stats.bytes_recv += nbytes
        tree, reasm, reasm_bytes = _unpack_heap(descr, heap, heap.rx_dir,
                                                segs, nbytes, copy)
        if reasm:
            # straddling leaves rebuilt with a counted copy (scatter allocs)
            self.stats.heap_reassembles += reasm
            self._engine.count("heap_reassemble", reasm, reasm_bytes)
        reader.release()                       # descriptor slot recycles now
        if copy:
            # counted staging copy, same tag as the slot path's copy-out
            self._engine.count("recv_copy", _count_leaves(descr), nbytes)
            heap.free(segs)
            return tree, header
        return RecvLease(tree, header, None,
                         on_release=lambda: heap.free(segs))

    def _lease_from_reader(self, reader: SlotReader, copy: bool):
        header, descr = self._decode_meta(reader.meta)
        if reader.flags & FLAG_HEAP:
            return self._lease_from_heap(reader, header, descr, copy)
        self.stats.recvs += 1
        self.stats.bytes_recv += reader.payload_nbytes
        payload = reader.slot.payload_view
        if copy:
            tree = _unpack(descr, payload, copy=True)
            # counted staging copy: the receive-side memcpy the zero-copy
            # serving path exists to eliminate
            self._engine.count("recv_copy", _count_leaves(descr),
                               reader.payload_nbytes)
            reader.release()
            return tree, header
        return RecvLease(_unpack(descr, payload, copy=False), header, reader)

    def recv(self, timeout_s: float = 30.0, copy: bool = True,
             hint_nbytes: int = 0):
        """Receive one pytree; ``copy=False`` returns a :class:`RecvLease`
        whose arrays are zero-copy views into the slot."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        deadline = time.perf_counter() + timeout_s
        while True:
            reader = self.rx.wait_recv(
                max(1e-3, deadline - time.perf_counter()), hint_nbytes)
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                hint_nbytes = 0
                continue
            return self._lease_from_reader(reader, copy)

    def try_recv(self, copy: bool = True):
        """Non-blocking receive; None when no message is ready."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        while True:
            reader = self.rx.try_poll()
            if reader is None:
                return None
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                continue
            return self._lease_from_reader(reader, copy)

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout_s: float = 5.0) -> None:
        """Flush outstanding sends (the shared copy engine stays up — it
        serves every other channel in the process)."""
        try:
            self.flush(timeout_s)
        except (TimeoutError, ChannelClosed):
            pass


class ControlChannel:
    """Small pickled-object messages (commands, acks) over tiny slots.

    Both receive paths surface :class:`~repro.ipc.ring.ChannelClosed`
    consistently once the peer endpoint announced shutdown (after the
    ring is drained), so callers never have to poke ring internals to
    distinguish "no message yet" from "peer is gone"."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring]):
        self.tx = tx
        self.rx = rx
        self._lock = threading.Lock()

    def send_msg(self, obj: Any, timeout_s: float = 30.0) -> None:
        """Send one small pickled message (blocks while the ring is full)."""
        blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > self.tx.spec.slot_bytes:
            raise ValueError(f"control message of {len(blob)} B too large")
        with self._lock:
            w = self.tx.acquire(timeout_s)
            w.payload[:len(blob)] = blob
            w.publish(len(blob))

    def recv_msg(self, timeout_s: float = 30.0) -> Any:
        """Blocking receive of one message; raises
        :class:`~repro.ipc.ring.ChannelClosed` when the peer shut down
        while we were waiting (in-flight messages are delivered first)."""
        with self.rx.wait_recv(timeout_s) as r:
            return pickle.loads(r.payload)

    def try_recv_msg(self) -> Any:
        """Non-blocking receive; None when no message is waiting, and
        :class:`~repro.ipc.ring.ChannelClosed` once the peer announced
        shutdown and the ring is fully drained."""
        r = self.rx.try_poll()
        if r is None:
            if self.rx.peer_closed:
                raise ChannelClosed(
                    "control peer closed and the ring is drained")
            return None
        with r:
            return pickle.loads(r.payload)
