"""Typed channels over shm rings: numpy pytrees, ROCKET send modes.

A :class:`DataChannel` sends pytrees (nested dict/list/tuple) of numpy
arrays through one :class:`~repro.ipc.ring.Ring`.  The wire format is

- **meta**: a pickled descriptor mirroring the tree structure with each
  array leaf replaced by ``(offset, shape, dtype)`` — plus an optional
  user header dict (op names, job ids, seeds...);
- **payload**: the arrays' bytes packed back-to-back at 64-byte-aligned
  offsets inside the slot — a single memcpy per leaf into pre-mapped
  shared memory, and *zero* copies on the receive side when the caller
  asks for views (``copy=False``).

Send modes follow :class:`~repro.core.policy.OffloadPolicy` exactly like
the tier-1 engine (the paper's Table III):

- ``sync``       — the caller performs the copy inline and the handle is
  complete on return (cpu/DTO);
- ``async``      — a dedicated channel thread (the DSA-engine analogue)
  performs slot acquire + copy + publish; ``send`` returns a handle
  immediately and ``handle.wait()`` applies hybrid polling;
- ``pipelined``  — async plus bounded in-flight depth: when more than
  ``pipeline_depth`` sends are outstanding the oldest is completed first
  (backpressure), with the blocking wait held *outside* the channel lock.

Small below-threshold messages stay inline in every mode (size-based
offload control).
"""
from __future__ import annotations

import pickle
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.core.queuepair import drain_to_depth
from repro.ipc.ring import ChannelClosed, Ring, SlotReader, _align


# ---------------------------------------------------------------------------
# pytree packing (stdlib-only: no jax dependency inside the IPC layer)
# ---------------------------------------------------------------------------

class _Leaf:
    __slots__ = ("offset", "shape", "dtype")

    def __init__(self, offset: int, shape, dtype: str):
        self.offset = offset
        self.shape = tuple(shape)
        self.dtype = dtype


def _pack_descr(tree, cursor: list[int]):
    """Replace array leaves with placement descriptors; returns mirror tree."""
    if isinstance(tree, dict):
        return {k: _pack_descr(v, cursor) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        packed = [_pack_descr(v, cursor) for v in tree]
        return packed if isinstance(tree, list) else tuple(packed)
    arr = np.asarray(tree)
    leaf = _Leaf(cursor[0], arr.shape, arr.dtype.str)
    cursor[0] += _align(arr.nbytes)
    return leaf


def _copy_leaves(tree, descr, payload: memoryview) -> None:
    if isinstance(descr, dict):
        for k, d in descr.items():
            _copy_leaves(tree[k], d, payload)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _copy_leaves(v, d, payload)
        return
    arr = np.ascontiguousarray(np.asarray(tree))
    dst = np.frombuffer(payload, np.uint8, count=arr.nbytes,
                        offset=descr.offset)
    np.copyto(dst, arr.reshape(-1).view(np.uint8))


def _unpack(descr, payload: memoryview, copy: bool):
    if isinstance(descr, dict):
        return {k: _unpack(d, payload, copy) for k, d in descr.items()}
    if isinstance(descr, (list, tuple)):
        out = [_unpack(d, payload, copy) for d in descr]
        return out if isinstance(descr, list) else tuple(out)
    dtype = np.dtype(descr.dtype)
    count = int(np.prod(descr.shape)) if descr.shape else 1
    arr = np.frombuffer(payload, dtype, count=count,
                        offset=descr.offset).reshape(descr.shape)
    return arr.copy() if copy else arr


def tree_nbytes(tree) -> int:
    """Total payload bytes of every array leaf in a pytree."""
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    return np.asarray(tree).nbytes


# ---------------------------------------------------------------------------
# completion handles
# ---------------------------------------------------------------------------

class SendHandle:
    """Completion flag for one send (the job-id side of the paper's API)."""

    def __init__(self, channel: "DataChannel", nbytes: int,
                 future: Optional[Future] = None):
        self.nbytes = nbytes
        self.submit_t = time.perf_counter()
        self._future = future
        self._channel = channel

    def done(self) -> bool:
        """True once the copy has been published (never blocks)."""
        return self._future is None or self._future.done()

    def wait(self, timeout_s: float = 30.0) -> None:
        """Hybrid-polling completion: size-aware deferral + short waits."""
        if self._future is None:
            return
        ch = self._channel
        if not self._future.done():
            pred = ch.latency.defer_seconds(self.nbytes,
                                            ch.policy.defer_fraction)
            remain = pred - (time.perf_counter() - self.submit_t)
            if remain > 0:
                time.sleep(min(remain, timeout_s))
                ch.stats.deferred_sleep_s += min(remain, timeout_s)
            quantum = ch.policy.poll_interval_us * 1e-6
            deadline = time.perf_counter() + timeout_s
            t0 = time.perf_counter()
            while not self._future.done():
                ch.stats.polls += 1
                if time.perf_counter() > deadline:
                    ch.stats.blocked_wait_s += time.perf_counter() - t0
                    raise TimeoutError("send not complete within timeout")
                try:
                    self._future.result(timeout=quantum)
                except (TimeoutError, FuturesTimeout):
                    continue
            ch.stats.blocked_wait_s += time.perf_counter() - t0
        self._future.result()          # surface worker exceptions
        self._future = None


class RecvLease:
    """Zero-copy receive: tree views stay valid until ``release``."""

    def __init__(self, tree, header: dict, reader: SlotReader):
        self.tree = tree
        self.header = header
        self._reader = reader

    def release(self) -> None:
        """Recycle the slot; the leased views become invalid."""
        if self._reader is not None:
            self._reader.release()
            self._reader = None
            # the views are invalid once the slot is recycled; drop them so
            # they can't pin the arena mapping open (BufferError on close)
            self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


@dataclass
class ChannelStats:
    """Per-channel send/recv counters and wait-time accounting."""
    sends: int = 0
    inline: int = 0
    offloaded: int = 0
    recvs: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    polls: int = 0
    deferred_sleep_s: float = 0.0
    blocked_wait_s: float = 0.0

    def snapshot(self) -> dict:
        """A plain-dict copy (for logging/benchmark rows)."""
        return dict(self.__dict__)


# ---------------------------------------------------------------------------
# the channel
# ---------------------------------------------------------------------------

class DataChannel:
    """Bidirectional typed channel over one tx ring + one rx ring."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring],
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None):
        self.tx = tx
        self.rx = rx
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = ChannelStats()
        self._send_lock = threading.Lock()      # slot-order serialization
        self._inflight: list[SendHandle] = []
        self._inflight_lock = threading.Lock()
        self._executor: Optional[ThreadPoolExecutor] = None

    def _engine(self) -> ThreadPoolExecutor:
        # one worker: the single offload engine; also guarantees slot order
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="rocket-ipc")
        return self._executor

    # -- send -----------------------------------------------------------------
    def _do_send(self, tree, header: Optional[dict],
                 timeout_s: float) -> None:
        cursor = [0]
        descr = _pack_descr(tree, cursor)
        nbytes = cursor[0]
        meta = pickle.dumps((header or {}, descr),
                            protocol=pickle.HIGHEST_PROTOCOL)
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B — create the transport with a "
                f"larger data_slot_bytes")
        if len(meta) > self.tx.spec.meta_bytes:
            raise ValueError(
                f"meta of {len(meta)} B exceeds meta capacity "
                f"{self.tx.spec.meta_bytes} B")
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
            _copy_leaves(tree, descr, writer.payload)
            writer.meta[:len(meta)] = meta
            writer.publish(nbytes, len(meta))

    def send(self, tree, header: Optional[dict] = None,
             mode: ExecutionMode | str | None = None,
             timeout_s: float = 30.0) -> SendHandle:
        """Send one pytree under the given (or policy) mode; see module
        docstring for the sync/async/pipelined semantics."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        nbytes = tree_nbytes(tree)
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes

        if mode == ExecutionMode.SYNC or not self.policy.should_offload(nbytes):
            self.stats.inline += 1
            self.flush(timeout_s)      # FIFO: inline never overtakes offloads
            self._do_send(tree, header, timeout_s)
            return SendHandle(self, nbytes)

        self.stats.offloaded += 1
        fut = self._engine().submit(self._do_send, tree, header, timeout_s)
        handle = SendHandle(self, nbytes, future=fut)
        with self._inflight_lock:
            # track every offloaded send so flush() orders later sync sends
            # after it; prune cleanly-completed ones so async stays bounded
            # (a failed handle is kept: flush must surface its exception)
            while (self._inflight and self._inflight[0]._future is not None
                   and self._inflight[0]._future.done()
                   and self._inflight[0]._future.exception() is None):
                self._inflight.pop(0)._future = None
            self._inflight.append(handle)
        if mode == ExecutionMode.PIPELINED:
            # bounded in-flight depth (the engine's backpressure, same shape)
            drain_to_depth(self._inflight, self._inflight_lock,
                           self.policy.pipeline_depth,
                           lambda h: h.wait(timeout_s))
        return handle

    def flush(self, timeout_s: float = 30.0) -> None:
        """Complete all outstanding pipelined sends (batch-level check)."""
        with self._inflight_lock:
            pending, self._inflight = self._inflight, []
        for h in pending:
            h.wait(timeout_s)

    # -- recv -----------------------------------------------------------------
    def recv(self, timeout_s: float = 30.0, copy: bool = True,
             hint_nbytes: int = 0):
        """Receive one pytree; ``copy=False`` returns a :class:`RecvLease`
        whose arrays are zero-copy views into the slot."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        reader = self.rx.wait_recv(timeout_s, hint_nbytes)
        header, descr = pickle.loads(reader.meta)
        self.stats.recvs += 1
        self.stats.bytes_recv += reader.payload_nbytes
        payload = reader.slot.payload_view
        if copy:
            tree = _unpack(descr, payload, copy=True)
            reader.release()
            return tree, header
        return RecvLease(_unpack(descr, payload, copy=False), header, reader)

    def try_recv(self, copy: bool = True):
        """Non-blocking receive; None when no message is ready."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        reader = self.rx.try_poll()
        if reader is None:
            return None
        header, descr = pickle.loads(reader.meta)
        self.stats.recvs += 1
        self.stats.bytes_recv += reader.payload_nbytes
        if copy:
            tree = _unpack(descr, reader.slot.payload_view, copy=True)
            reader.release()
            return tree, header
        return RecvLease(_unpack(descr, reader.slot.payload_view,
                                 copy=False), header, reader)

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout_s: float = 5.0) -> None:
        """Flush outstanding sends and stop the offload engine thread."""
        try:
            self.flush(timeout_s)
        except (TimeoutError, ChannelClosed):
            pass
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None


class ControlChannel:
    """Small pickled-object messages (commands, acks) over tiny slots."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring]):
        self.tx = tx
        self.rx = rx
        self._lock = threading.Lock()

    def send_msg(self, obj: Any, timeout_s: float = 30.0) -> None:
        """Send one small pickled message (blocks while the ring is full)."""
        blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > self.tx.spec.slot_bytes:
            raise ValueError(f"control message of {len(blob)} B too large")
        with self._lock:
            w = self.tx.acquire(timeout_s)
            w.payload[:len(blob)] = blob
            w.publish(len(blob))

    def recv_msg(self, timeout_s: float = 30.0) -> Any:
        """Blocking receive of one message."""
        with self.rx.wait_recv(timeout_s) as r:
            return pickle.loads(r.payload)

    def try_recv_msg(self) -> Any:
        """Non-blocking receive; None when no message is waiting."""
        r = self.rx.try_poll()
        if r is None:
            return None
        with r:
            return pickle.loads(r.payload)
