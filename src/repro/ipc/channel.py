"""Typed channels over shm rings: numpy pytrees, ROCKET modes, fast paths.

A :class:`DataChannel` sends pytrees (nested dict/list/tuple) of numpy
arrays through one :class:`~repro.ipc.ring.Ring`.  The wire format is

- **meta**: ``[u8 format | u32 descriptor length | descriptor pickle |
  header]`` encoded *directly into the claimed slot's meta region* (no
  staging allocation).  The descriptor mirrors the tree structure with
  each array leaf replaced by ``(offset, shape, dtype)`` and is **cached
  by structural signature** (tree shape + leaf shapes/dtypes) on the
  sender and by descriptor bytes on the receiver.  The header is
  struct-packed by a tiny tag codec (``META_BINARY``) covering
  scalars/strings/bytes/int-tuples — the steady-state case — with a
  transparent per-message fallback to pickle (``META_PICKLE``) for rich
  headers.  Together the caches + binary headers make the steady-state
  send/recv hot path **pickle-free**; every residual ``pickle.dumps`` /
  ``loads`` on the meta path is counted (``ChannelStats.meta_pickles`` /
  ``meta_unpickles``), so "0 pickle calls per send" is a gated metric,
  not a hope;
- **payload**: the arrays' bytes packed back-to-back at 64-byte-aligned
  offsets inside the slot — one scatter-gather descriptor per tree,
  executed by the process-wide :class:`~repro.core.copyengine.CopyEngine`
  (a single counted memcpy per leaf into pre-mapped shared memory), and
  *zero* copies on the receive side when the caller asks for views
  (``copy=False``).

Send modes follow :class:`~repro.core.policy.OffloadPolicy` exactly like
the tier-1 engine (the paper's Table III):

- ``sync``       — the caller performs the copy inline and the handle is
  complete on return (cpu/DTO);
- ``async``      — the shared copy engine (one work queue per channel, so
  FIFO order holds without a per-channel thread) performs slot acquire +
  copy + publish; ``send`` returns a handle immediately and
  ``handle.wait()`` applies hybrid polling;
- ``pipelined``  — async plus bounded in-flight depth: when more than
  ``pipeline_depth`` sends are outstanding the oldest is completed first
  (backpressure), with the blocking wait held *outside* the channel lock.

**Send coalescing** (the small-message fast path): with
``policy.coalesce_bytes > 0`` (or under the adaptive governor) an
async/pipelined message at/below the coalescing cap joins a **microbatch
frame**: the channel claims one ring slot, packs up to
``policy.coalesce_max`` sub-messages into it (payloads back-to-back,
each sub-message's meta encoded into the slot's meta region behind a
sub-message table), and publishes the whole frame under ONE state flip
(``FLAG_COALESCED``) — slot claim, meta encode, and doorbell amortized
K-ways, which is what makes doorbells-per-message < 1 a counted metric.
A partial frame is flushed by the next non-coalesced send, an explicit
``flush()``/``handle.wait()``, or the first send after
``policy.coalesce_window_us``.  The receiver unpacks a frame into K
*independent* leases sharing one refcounted slot reader: the slot
recycles when the last lease releases.

**Per-message strategy selection**: with ``policy.governor="adaptive"``
a :class:`~repro.core.governor.ChannelGovernor` replaces the static
``offload_threshold_bytes`` decision — it picks inline / offload /
coalesce / heap per message from measured per-size-class cost EWMAs and
queue occupancy (the paper's hybrid coordination as a feedback loop).
Static policy keeps the exact pre-governor semantics.

The **reserve-then-fill** path (:meth:`DataChannel.reserve`) exposes the
ring's :class:`~repro.ipc.ring.SlotWriter` as a typed :class:`TxSlot`:
the caller claims the destination slot first and packs the message
directly into it (e.g. a serving reply written straight into the
client's tx slot), eliminating the staging copy a ``send`` of an
already-materialized tree would add.

The **large-message datapath**: when the transport attached a
:class:`~repro.ipc.heap.BulkHeap`, payloads at/over
``policy.heap_threshold_bytes`` (and anything that would not fit a slot)
are written into heap *extents* instead and the ring slot carries only
the compact extent descriptor (``FLAG_HEAP``).  Sync mode fills the
extents with one blocking gather; async/pipelined split the fill into
``policy.heap_chunk_bytes`` SG submissions on the channel's work queue,
so the copy of message k+1 overlaps the peer's drain of message k.
Receivers get zero-copy views into the extents (scatter allocations
reassemble only boundary-straddling leaves, counted), and the *lease
release frees the extents* — receiver-driven reclamation, with a held
lease acting as byte-granular backpressure on the sender's allocator.
"""
from __future__ import annotations

import math
import pickle
import struct
import threading
import time
import zlib
from collections import OrderedDict, deque
from typing import Any, Optional

import numpy as np

from repro.core.copyengine import (
    CopyEngine,
    CopyJob,
    Descriptor,
    HybridPollStats,
    SGList,
    WouldBlock,
    get_engine,
    split_sg,
)
from repro.core.governor import (
    COALESCE,
    HEAP,
    INLINE,
    OFFLOAD,
    ChannelGovernor,
)
from repro.core.latency import LatencyModel
from repro.core.policy import Device, ExecutionMode, OffloadPolicy
from repro.core.queuepair import drain_to_depth
from repro.ft import inject as _inject
from repro.ipc.heap import MAX_SEGMENTS, BulkHeap, HeapExhausted
from repro.obs import hwcounters as _hw
from repro.obs import trace as _trace
from repro.ipc.ring import (
    FLAG_COALESCED,
    FLAG_CRC,
    FLAG_HEAP,
    ChannelClosed,
    Ring,
    SlotReader,
    SlotWriter,
    _align,
)

from dataclasses import dataclass

_U32 = struct.Struct("<I")
_DESCR_CACHE_MAX = 64
# header key carrying the heap scatter list on the wire (stripped before
# the header dict reaches the application)
_HX_KEY = "__rocket_hx__"

# SLO wire meta: reserved header keys carrying a request's priority class
# and absolute deadline.  Both values are plain ints, so they ride the
# META_BINARY tag codec (``_TAG_INT``) — adding a lane or a deadline to a
# request never demotes its header to the pickle fallback.  The serving
# fabric strips them before the header reaches application handlers.
#: priority lane (0 = highest; requests without the key default to lane 0)
PRIO_KEY = "__rocket_prio__"
#: absolute deadline in ``time.perf_counter_ns()`` ticks (CLOCK_MONOTONIC
#: on Linux — the same cross-process timebase the tracer uses; 0 = none)
DEADLINE_KEY = "__rocket_dl__"
#: idempotent request id: ``(client session id << 32) | job_id`` as one
#: int (rides the tag codec like the keys above).  The serving fabric
#: strips it and feeds the dispatcher's exactly-once dedup window, so a
#: reconnecting client can replay unacked requests without re-execution.
DEDUP_KEY = "__rocket_dd__"

# ---------------------------------------------------------------------------
# wire meta formats (first byte of the slot meta region)
# ---------------------------------------------------------------------------

#: ``[u8 0 | u32 dlen | descr pickle | header pickle]`` — rich-header
#: fallback (counted: ``ChannelStats.meta_pickles``)
META_PICKLE = 0
#: ``[u8 1 | u32 dlen | descr pickle | binary header]`` — steady state:
#: no pickle anywhere on the per-message path
META_BINARY = 1
#: coalesced frame: ``[u8 2 | u16 K | K×(u32 meta_off | u32 meta_len |
#: u32 pay_off | u32 pay_len) | sub-metas…]`` with payloads packed into
#: the slot payload region at each ``pay_off`` (used with FLAG_COALESCED)
META_FRAME = 2

_B8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_I64 = struct.Struct("<q")
_F64 = struct.Struct("<d")
_FRAME_HDR = struct.Struct("<BH")          # format byte + sub-message count
_FRAME_ENTRY = struct.Struct("<IIII")      # meta_off, meta_len, pay_off, pay_len
_META_FIXED = 5                            # u8 format + u32 dlen

# binary header value tags
_TAG_NONE, _TAG_TRUE, _TAG_FALSE = 0, 1, 2
_TAG_INT, _TAG_FLOAT, _TAG_STR, _TAG_BYTES = 3, 4, 5, 6
_TAG_TUPLE, _TAG_LIST = 7, 8
_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1


class MetaOverflow(ValueError):
    """The encoded meta would not fit the slot's meta region."""


class _Unencodable(Exception):
    """Header value outside the binary codec's vocabulary (pickle it)."""


def _put(mv: memoryview, off: int, st: struct.Struct, *vals) -> int:
    if off + st.size > len(mv):
        raise MetaOverflow(f"meta exceeds capacity {len(mv)} B")
    st.pack_into(mv, off, *vals)
    return off + st.size


def _put_bytes(mv: memoryview, off: int, b: bytes) -> int:
    end = off + len(b)
    if end > len(mv):
        raise MetaOverflow(f"meta exceeds capacity {len(mv)} B")
    mv[off:end] = b
    return end


def _enc_value(mv: memoryview, off: int, v) -> int:
    """Binary-encode one header value; raises :class:`_Unencodable` for
    anything outside the flat scalar/bytes/int-tuple vocabulary."""
    if v is None:
        return _put(mv, off, _B8, _TAG_NONE)
    if v is True:
        return _put(mv, off, _B8, _TAG_TRUE)
    if v is False:
        return _put(mv, off, _B8, _TAG_FALSE)
    if isinstance(v, int) and not isinstance(v, bool):
        if not (_I64_MIN <= v <= _I64_MAX):
            raise _Unencodable
        off = _put(mv, off, _B8, _TAG_INT)
        return _put(mv, off, _I64, v)
    if isinstance(v, float):
        off = _put(mv, off, _B8, _TAG_FLOAT)
        return _put(mv, off, _F64, v)
    if isinstance(v, str):
        b = v.encode("utf-8")
        off = _put(mv, off, _B8, _TAG_STR)
        off = _put(mv, off, _U32, len(b))
        return _put_bytes(mv, off, b)
    if isinstance(v, (bytes, bytearray)):
        off = _put(mv, off, _B8, _TAG_BYTES)
        off = _put(mv, off, _U32, len(v))
        return _put_bytes(mv, off, bytes(v))
    if isinstance(v, (tuple, list)):
        if len(v) > 0xFFFF:
            raise _Unencodable
        off = _put(mv, off, _B8,
                   _TAG_TUPLE if isinstance(v, tuple) else _TAG_LIST)
        off = _put(mv, off, _U16, len(v))
        for item in v:
            off = _enc_value(mv, off, item)
        return off
    raise _Unencodable


def _enc_header(mv: memoryview, off: int, header: dict) -> int:
    """Binary header: ``u16 n_items`` then per item ``u8 keylen | key |
    value``.  Raises :class:`_Unencodable` on non-str keys or rich values
    (the caller falls back to pickle for the whole header)."""
    if len(header) > 0xFFFF:
        raise _Unencodable
    off = _put(mv, off, _U16, len(header))
    for k, v in header.items():
        if not isinstance(k, str):
            raise _Unencodable
        kb = k.encode("utf-8")
        if len(kb) > 0xFF:
            raise _Unencodable
        off = _put(mv, off, _B8, len(kb))
        off = _put_bytes(mv, off, kb)
        off = _enc_value(mv, off, v)
    return off


def _dec_value(raw: bytes, off: int):
    tag = raw[off]
    off += 1
    if tag == _TAG_NONE:
        return None, off
    if tag == _TAG_TRUE:
        return True, off
    if tag == _TAG_FALSE:
        return False, off
    if tag == _TAG_INT:
        return _I64.unpack_from(raw, off)[0], off + 8
    if tag == _TAG_FLOAT:
        return _F64.unpack_from(raw, off)[0], off + 8
    if tag == _TAG_STR:
        (n,) = _U32.unpack_from(raw, off)
        off += 4
        return raw[off:off + n].decode("utf-8"), off + n
    if tag == _TAG_BYTES:
        (n,) = _U32.unpack_from(raw, off)
        off += 4
        return bytes(raw[off:off + n]), off + n
    if tag in (_TAG_TUPLE, _TAG_LIST):
        (n,) = _U16.unpack_from(raw, off)
        off += 2
        out = []
        for _ in range(n):
            v, off = _dec_value(raw, off)
            out.append(v)
        return (tuple(out) if tag == _TAG_TUPLE else out), off
    raise ValueError(f"corrupt binary header (tag {tag})")


def _dec_header(raw: bytes, off: int) -> dict:
    (n,) = _U16.unpack_from(raw, off)
    off += 2
    out = {}
    for _ in range(n):
        klen = raw[off]
        off += 1
        key = raw[off:off + klen].decode("utf-8")
        off += klen
        out[key], off = _dec_value(raw, off)
    return out


# ---------------------------------------------------------------------------
# pytree packing (stdlib-only: no jax dependency inside the IPC layer)
# ---------------------------------------------------------------------------

class _Leaf:
    __slots__ = ("offset", "shape", "dtype")

    def __init__(self, offset: int, shape, dtype: str):
        self.offset = offset
        self.shape = tuple(shape)
        self.dtype = dtype


def _pack_descr(tree, cursor: list[int]):
    """Replace array leaves with placement descriptors; returns mirror tree."""
    if isinstance(tree, dict):
        return {k: _pack_descr(v, cursor) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        packed = [_pack_descr(v, cursor) for v in tree]
        return packed if isinstance(tree, list) else tuple(packed)
    arr = np.asarray(tree)
    leaf = _Leaf(cursor[0], arr.shape, arr.dtype.str)
    cursor[0] += _align(arr.nbytes)
    return leaf


# structure-signature markers (distinct from any dict key / dtype string)
_SIG_DICT, _SIG_LIST, _SIG_TUPLE = 0, 1, 2


def _signature(tree, out: list) -> None:
    """Flatten the tree's *structure* (container shape, keys, leaf
    shapes/dtypes) into a hashable token list — the descriptor-cache key.
    Any structural change (new key, reordered keys, different shape or
    dtype) yields a different signature, which is the cache invalidation."""
    if isinstance(tree, dict):
        out.append(_SIG_DICT)
        out.append(len(tree))
        for k, v in tree.items():
            out.append(k)
            _signature(v, out)
        return
    if isinstance(tree, (list, tuple)):
        out.append(_SIG_LIST if isinstance(tree, list) else _SIG_TUPLE)
        out.append(len(tree))
        for v in tree:
            _signature(v, out)
        return
    arr = np.asarray(tree)
    out.append(arr.dtype.str)
    out.append(arr.shape)


def _gather_sg(tree, descr, payload: memoryview, sg: SGList) -> None:
    """Append one SG entry per leaf: leaf bytes → its slot placement."""
    if isinstance(descr, dict):
        for k, d in descr.items():
            _gather_sg(tree[k], d, payload, sg)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _gather_sg(v, d, payload, sg)
        return
    arr = np.asarray(tree)
    dst = np.frombuffer(payload, np.uint8, count=arr.nbytes,
                        offset=descr.offset)
    sg.add(arr, dst)


def _unpack(descr, payload: memoryview, copy: bool):
    if isinstance(descr, dict):
        return {k: _unpack(d, payload, copy) for k, d in descr.items()}
    if isinstance(descr, (list, tuple)):
        out = [_unpack(d, payload, copy) for d in descr]
        return out if isinstance(descr, list) else tuple(out)
    dtype = np.dtype(descr.dtype)
    count = math.prod(descr.shape)
    arr = np.frombuffer(payload, dtype, count=count,
                        offset=descr.offset).reshape(descr.shape)
    return arr.copy() if copy else arr


def _heap_fill_sg(tree, descr, heap: BulkHeap, direction: int, segments,
                  total_nbytes: int, sg: SGList) -> None:
    """One flat-u8 SG entry per (leaf, heap piece): leaf bytes → the heap
    range(s) its virtual placement resolves to.  Contiguous allocations
    yield exactly one entry per leaf; scatter allocations split leaves
    that straddle a segment boundary (still one *logical* copy — the
    submitter accounts with ``count_copies``)."""
    if isinstance(descr, dict):
        for k, d in descr.items():
            _heap_fill_sg(tree[k], d, heap, direction, segments,
                          total_nbytes, sg)
        return
    if isinstance(descr, (list, tuple)):
        for v, d in zip(tree, descr):
            _heap_fill_sg(v, d, heap, direction, segments, total_nbytes, sg)
        return
    arr = np.asarray(tree)
    if not arr.flags["C_CONTIGUOUS"]:
        arr = np.ascontiguousarray(arr)
    src = arr.reshape(-1).view(np.uint8)
    off = 0
    for piece in heap.resolve(direction, segments, descr.offset, arr.nbytes,
                              total_nbytes):
        sg.add_array(src[off:off + piece.nbytes], piece)
        off += piece.nbytes


def _unpack_heap(descr, heap: BulkHeap, direction: int, segments,
                 total_nbytes: int, copy: bool):
    """Rebuild a pytree from heap extents.  ``copy=False`` returns
    zero-copy views for every leaf that lies inside one segment and
    reassembles (one counted copy) only boundary-straddling leaves;
    returns ``(tree, reassembled_copies, reassembled_bytes)``."""
    counters = [0, 0]

    def walk(d):
        if isinstance(d, dict):
            return {k: walk(v) for k, v in d.items()}
        if isinstance(d, (list, tuple)):
            out = [walk(v) for v in d]
            return out if isinstance(d, list) else tuple(out)
        dtype = np.dtype(d.dtype)
        count = math.prod(d.shape)
        nbytes = count * dtype.itemsize
        pieces = heap.resolve(direction, segments, d.offset, nbytes,
                              total_nbytes)
        if len(pieces) == 1 and not copy:
            return np.frombuffer(pieces[0], dtype,
                                 count=count).reshape(d.shape)
        buf = np.empty(count, dtype)
        u8, off = buf.view(np.uint8), 0
        for p in pieces:
            u8[off:off + p.nbytes] = p
            off += p.nbytes
        if not copy:                   # straddler reassembled under a lease
            counters[0] += 1
            counters[1] += nbytes
        return buf.reshape(d.shape)

    return walk(descr), counters[0], counters[1]


def _writable_heap_tree(descr, heap: BulkHeap, direction: int, segments,
                        total_nbytes: int):
    """Reserve-then-fill layout over heap extents: leaves contiguous in
    one segment become writable views straight into the heap; straddlers
    get a staging array copied in at publish.  Returns ``(tree, staged)``
    with ``staged`` a list of ``(array, leaf_descr)`` pairs."""
    staged: list = []

    def walk(d):
        if isinstance(d, dict):
            return {k: walk(v) for k, v in d.items()}
        if isinstance(d, (list, tuple)):
            out = [walk(v) for v in d]
            return out if isinstance(d, list) else tuple(out)
        dtype = np.dtype(d.dtype)
        count = math.prod(d.shape)
        pieces = heap.resolve(direction, segments, d.offset,
                              count * dtype.itemsize, total_nbytes)
        if len(pieces) == 1:
            return np.frombuffer(pieces[0], dtype,
                                 count=count).reshape(d.shape)
        buf = np.empty(d.shape, dtype)
        staged.append((buf, d))
        return buf

    return walk(descr), staged


def _count_leaves(descr) -> int:
    if isinstance(descr, dict):
        return sum(_count_leaves(d) for d in descr.values())
    if isinstance(descr, (list, tuple)):
        return sum(_count_leaves(d) for d in descr)
    return 1


def tree_nbytes(tree) -> int:
    """Total payload bytes of every array leaf in a pytree."""
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    return np.asarray(tree).nbytes


# ---------------------------------------------------------------------------
# completion handles / leases
# ---------------------------------------------------------------------------

class _Frame:
    """Sender-side open microbatch frame: one claimed slot being filled
    with sub-messages (payloads copied at append; table + publish at
    flush).  Lives under the channel's coalescing lock."""

    __slots__ = ("writer", "kcap", "k", "meta_cursor", "pay_cursor",
                 "table", "entries", "event", "err", "opened_t",
                 "copies", "copied_bytes")

    def __init__(self, writer: SlotWriter, kcap: int, opened_t: float):
        self.writer = writer
        self.kcap = kcap
        self.k = 0
        self.meta_cursor = _FRAME_HDR.size + kcap * _FRAME_ENTRY.size
        self.pay_cursor = 0
        self.table: list[tuple[int, int, int, int]] = []
        self.entries: list[tuple[int, float]] = []   # (nbytes, append µs)
        self.event = threading.Event()
        self.err: Optional[BaseException] = None
        self.opened_t = opened_t
        self.copies = 0              # accounted once per frame at flush
        self.copied_bytes = 0


class SendHandle:
    """Completion flag for one send (the job-id side of the paper's API);
    offloaded sends are backed by a copy-engine completion record,
    coalesced sends by their frame's publish event (``wait`` flushes a
    still-open frame — the pull side of partial-frame flushing)."""

    def __init__(self, channel: "DataChannel", nbytes: int,
                 job: Optional[CopyJob] = None,
                 frame: Optional[_Frame] = None, route: str = INLINE):
        self.nbytes = nbytes
        self.route = route
        self.submit_t = time.perf_counter()
        self._job = job
        self._frame = frame
        self._channel = channel if frame is not None else None

    def done(self) -> bool:
        """True once the copy has been published (never blocks)."""
        if self._frame is not None:
            return self._frame.event.is_set()
        return self._job is None or self._job.done()

    def failed(self) -> bool:
        """True when the offloaded send completed with an exception."""
        if self._frame is not None:
            return self._frame.err is not None
        return self._job is not None and self._job.failed()

    def wait(self, timeout_s: float = 30.0) -> None:
        """Hybrid-polling completion: size-aware deferral + short waits;
        re-raises engine-side exceptions (e.g. a timed-out slot acquire).
        Waiting on a coalesced send flushes its frame first."""
        if self._frame is not None:
            if not self._frame.event.is_set():
                self._channel._flush_frame(self._frame)
            if self._frame.err is not None:
                raise self._frame.err
            self._frame = None
            self._channel = None
            return
        if self._job is not None:
            # the job reference is kept (not nulled): a completed CopyJob's
            # wait() returns immediately, and the governor reads its
            # completion-record timestamps after the depth-drain wait
            self._job.wait(timeout_s)


class _SharedFrameReader:
    """Refcounted slot reader backing a coalesced frame's K leases: the
    slot recycles when the LAST lease releases (lease independence —
    release order is the consumer's business)."""

    __slots__ = ("_reader", "_remaining", "_lock")

    def __init__(self, reader: SlotReader, k: int):
        self._reader = reader
        self._remaining = k
        self._lock = threading.Lock()

    def ref(self) -> "_FrameSlotRef":
        return _FrameSlotRef(self)

    def _dec(self) -> None:
        with self._lock:
            self._remaining -= 1
            last = self._remaining == 0
        if last:
            self._reader.release()


class _FrameSlotRef:
    """One lease's handle on the shared frame reader (duck-types the
    ``release()`` a :class:`RecvLease` expects)."""

    __slots__ = ("_shared",)

    def __init__(self, shared: _SharedFrameReader):
        self._shared = shared

    def release(self) -> None:
        shared, self._shared = self._shared, None
        if shared is not None:
            shared._dec()


class RecvLease:
    """Zero-copy receive: tree views stay valid until ``release``.

    A lease over a heap-routed message additionally owns its extents:
    ``release`` frees them back to the sender's allocator (``on_release``)
    — the *receiver-driven* reclamation that makes heap lifetime equal
    lease lifetime, and a held lease the sender's backpressure.  A lease
    from a coalesced frame shares its slot with the frame's siblings and
    holds it until the last of them releases."""

    def __init__(self, tree, header: dict, reader,
                 on_release=None):
        self.tree = tree
        self.header = header
        self._reader = reader
        self._on_release = on_release
        # lease birth timestamp: with tracing on, release() emits a
        # LEASE_HOLD span covering delivery → release (how long this
        # message pinned its ring slot / heap extents); the hw profiler
        # accounts the same interval wall-clock-only (delivery and
        # release run on different threads, so per-thread counter
        # deltas across the hold would be meaningless)
        self._t0 = (_trace.now()
                    if _trace.TRACE.enabled or _hw.PROF.enabled else 0)

    @property
    def rid(self) -> int:
        """Request id propagated in the wire meta (0 when untraced)."""
        header = self.header
        if isinstance(header, dict):
            v = header.get(_trace.RID_KEY, 0)
            return v if isinstance(v, int) else 0
        return 0

    @property
    def held(self) -> bool:
        """True while the lease still occupies its ring slot or heap
        extents (a lease made from an already-copied message reports
        False)."""
        return self._reader is not None or self._on_release is not None

    def release(self) -> None:
        """Recycle the slot and free any heap extents; the leased views
        become invalid."""
        released = False
        if self._reader is not None:
            self._reader.release()
            self._reader = None
            released = True
        if self._on_release is not None:
            cb, self._on_release = self._on_release, None
            cb()
            released = True
        if released and self._t0:
            if _trace.TRACE.enabled:
                _trace.emit(_trace.LEASE_HOLD, self._t0, rid=self.rid)
            if _hw.PROF.enabled:
                _hw.account_wall("lease_hold", self._t0)
        if released:
            # the views are invalid once the slot/extents are recycled;
            # drop them so they can't pin the arena mapping open
            # (BufferError on close)
            self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


class TxSlot:
    """A reserved tx destination with typed writable views
    (reserve-then-fill).

    ``tree`` mirrors the template pytree with numpy views *into the
    destination* — a ring slot's payload region, or (for large templates)
    bulk-heap extents; write results straight into them, then
    :meth:`publish` (which encodes the cached-descriptor meta directly
    into the slot's meta region).  :meth:`abort` gives an unfillable
    reservation back (slot path: a skip sentinel the receive path
    ignores; heap path: the extents return to FREE — no ring slot was
    claimed yet, so there is nothing to sentinel).  As a context manager
    it publishes on clean exit and aborts if the block raised.
    """

    def __init__(self, tree, writer: Optional[SlotWriter],
                 descr_bytes: bytes, header: Optional[dict],
                 nbytes: int, channel: "DataChannel",
                 heap_state: Optional[dict] = None):
        self.tree = tree
        self._writer = writer
        self._descr_bytes = descr_bytes
        self._header = header
        self._nbytes = nbytes
        self._channel = channel
        self._heap = heap_state
        self._done = False

    def _publish_heap(self) -> None:
        """Stage straddling leaves into their extents, then claim a ring
        slot for the compact extent descriptor and ring the doorbell.  Any
        failure (meta overflow, ring acquire timeout) frees the extents —
        ownership only transfers on a successful publish."""
        hs, ch = self._heap, self._channel
        heap = ch._heap
        try:
            if hs["staged"]:
                sg = SGList()
                for buf, d in hs["staged"]:
                    src = np.ascontiguousarray(buf).reshape(-1).view(np.uint8)
                    off = 0
                    for piece in heap.resolve(heap.tx_dir, hs["segments"],
                                              d.offset, src.nbytes,
                                              self._nbytes):
                        sg.add_array(src[off:off + piece.nbytes], piece)
                        off += piece.nbytes
                ch._engine.run_sg(sg, injection=ch.policy.injection_enabled(),
                                  tag="heap_stage",
                                  count_copies=len(hs["staged"]))
            with ch._send_lock:
                w = ch.tx.acquire(hs["timeout_s"])
        except BaseException:
            heap.free(hs["segments"], heap.tx_dir)
            raise
        try:
            ch._publish(w, self._descr_bytes, self._header, self._nbytes,
                        flags=FLAG_HEAP, segments=hs["segments"])
        except BaseException:
            heap.free(hs["segments"], heap.tx_dir)
            raise
        ch.stats.sends += 1
        ch.stats.inline += 1
        ch.stats.heap_sends += 1
        ch.stats.bytes_sent += self._nbytes

    def publish(self) -> None:
        """Encode the (cached) descriptor meta into the slot and ring the
        doorbell."""
        if self._done:
            return
        self._done = True
        ch = self._channel
        if self._heap is not None:
            self._publish_heap()
            self.tree = None
            return
        w = self._writer
        self._writer = None
        ch._publish(w, self._descr_bytes, self._header, self._nbytes)
        ch.stats.sends += 1
        ch.stats.inline += 1
        ch.stats.bytes_sent += self._nbytes
        self.tree = None

    def abort(self) -> None:
        """Give the reservation back unfilled (slot: skip sentinel; heap:
        extents freed)."""
        if self._done:
            return
        self._done = True
        if self._heap is not None:
            ch = self._channel
            ch._heap.free(self._heap["segments"], ch._heap.tx_dir)
        else:
            self._writer.abort()
            self._writer = None
        self.tree = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        if exc_type is not None:
            self.abort()
        else:
            self.publish()


@dataclass
class ChannelStats(HybridPollStats):
    """Per-channel counters: the shared hybrid-polling fields plus
    send/recv/byte totals, descriptor-cache effectiveness, coalescing,
    and the counted meta pickle calls (0 per send/recv steady state)."""
    sends: int = 0
    recvs: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    descr_cache_hits: int = 0
    descr_cache_misses: int = 0
    heap_sends: int = 0          # messages routed through bulk-heap extents
    heap_recvs: int = 0
    heap_reassembles: int = 0    # straddling leaves rebuilt with a copy
    coalesced_sends: int = 0     # messages that rode a microbatch frame
    coalesced_recvs: int = 0
    frames_sent: int = 0         # frames published (doorbells for the above)
    frames_recv: int = 0
    meta_pickles: int = 0        # pickle.dumps on the send meta path
    meta_unpickles: int = 0      # pickle.loads on the recv meta path
    corrupt_drops: int = 0       # slots quarantined by the meta CRC check


# ---------------------------------------------------------------------------
# the channel
# ---------------------------------------------------------------------------

class DataChannel:
    """Bidirectional typed channel over one tx ring + one rx ring.

    Receive-side methods (``recv``/``try_recv``/``try_recv_many``) are
    single-consumer, matching the SPSC ring underneath."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring],
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 copy_engine: Optional[CopyEngine] = None,
                 descr_cache: bool = True,
                 heap: Optional[BulkHeap] = None):
        self.tx = tx
        self.rx = rx
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = ChannelStats()
        self._engine = copy_engine or get_engine()
        self._heap = heap
        self._send_lock = threading.Lock()      # slot-order serialization
        self._inflight: deque[SendHandle] = deque()
        self._inflight_lock = threading.Lock()
        self._cache_enabled = descr_cache
        self._tx_descr_cache: OrderedDict = OrderedDict()
        self._rx_descr_cache: OrderedDict = OrderedDict()
        # small-message fast path: the open microbatch frame + rx-side
        # queue of sub-messages already unpacked from a received frame
        self._coal_lock = threading.Lock()
        self._frame: Optional[_Frame] = None
        self._rx_pending: deque = deque()
        self.governor: Optional[ChannelGovernor] = (
            ChannelGovernor(self.policy, self.latency)
            if self.policy.governor == "adaptive" else None)

    def bind_heap(self, heap: Optional[BulkHeap]) -> None:
        """Attach the connection's bulk heap: payloads at/over
        ``policy.heap_threshold_bytes`` (and anything over the slot
        capacity) are routed through heap extents from now on."""
        self._heap = heap

    def _use_heap(self, nbytes: int) -> bool:
        """Inline-slot vs heap path selection (OffloadPolicy threshold)."""
        if self._heap is None or not self._heap.spec.enabled:
            return False
        return (nbytes > self.tx.spec.slot_bytes
                or nbytes >= self.policy.heap_threshold_bytes)

    # -- wire encoding (descriptor cache + binary headers) --------------------
    def _encode_descr(self, tree):
        """Build (descriptor, descriptor bytes, payload nbytes); the
        descriptor and its pickle are cached by structural signature, so
        steady-state sends never call ``pickle.dumps`` for it."""
        sig: Optional[tuple] = None
        hit = None
        if self._cache_enabled:
            toks: list = []
            _signature(tree, toks)
            sig = tuple(toks)
            hit = self._tx_descr_cache.get(sig)
        if hit is not None:
            descr, descr_bytes, nbytes = hit
            self._tx_descr_cache.move_to_end(sig)
            self.stats.descr_cache_hits += 1
        else:
            cursor = [0]
            descr = _pack_descr(tree, cursor)
            nbytes = cursor[0]
            descr_bytes = pickle.dumps(descr,
                                       protocol=pickle.HIGHEST_PROTOCOL)
            self.stats.descr_cache_misses += 1
            self.stats.meta_pickles += 1
            if self._cache_enabled:
                self._tx_descr_cache[sig] = (descr, descr_bytes, nbytes)
                while len(self._tx_descr_cache) > _DESCR_CACHE_MAX:
                    self._tx_descr_cache.popitem(last=False)
        return descr, descr_bytes, nbytes

    def _encode_meta_into(self, mv: memoryview, descr_bytes: bytes,
                          header: Optional[dict], segments=None,
                          count: bool = True) -> int:
        """Encode one message's wire meta directly into ``mv`` (a slot
        meta region or a sub-frame slice of it) — no staging bytes, no
        concatenation.  Binary header when the values fit the flat codec,
        per-message pickle fallback otherwise (counted).  Returns the
        encoded length; raises :class:`MetaOverflow` when it cannot fit."""
        if segments is not None:
            header = dict(header or {})
            header[_HX_KEY] = tuple(segments)
        base = _put_bytes(mv, _META_FIXED, descr_bytes)
        try:
            end = _enc_header(mv, base, header or {})
            fmt = META_BINARY
        except _Unencodable:
            blob = pickle.dumps(header or {},
                                protocol=pickle.HIGHEST_PROTOCOL)
            if count:
                self.stats.meta_pickles += 1
            end = _put_bytes(mv, base, blob)
            fmt = META_PICKLE
        _B8.pack_into(mv, 0, fmt)
        _U32.pack_into(mv, 1, len(descr_bytes))
        return end

    def _publish(self, writer: SlotWriter, descr_bytes: bytes,
                 header: Optional[dict], nbytes: int, flags: int = 0,
                 segments=None) -> None:
        """Encode the meta into the claimed slot and flip it READY; any
        encode failure (oversized meta, unpicklable header) aborts the
        slot as a skip sentinel — a WRITING slot left behind would wedge
        the strictly-ordered SPSC ring forever."""
        t0 = _trace.now() if _trace.TRACE.enabled else 0
        c0 = _hw.begin() if _hw.PROF.enabled else None
        try:
            mlen = self._encode_meta_into(writer.meta, descr_bytes, header,
                                          segments)
        except MetaOverflow:
            writer.abort()
            raise ValueError(
                f"meta exceeds meta capacity {self.tx.spec.meta_bytes} B "
                f"(raise data_meta_bytes)") from None
        except BaseException:
            writer.abort()
            raise
        meta_crc = zlib.crc32(writer.meta[:mlen]) \
            if self.policy.meta_checksum else -1
        if _inject._PLANE is not None:
            corrupt = _inject.fire("channel.meta.corrupt")
            if corrupt is not None and mlen > 0:
                # flip a meta byte AFTER the checksum: the receiver's CRC
                # verify (when enabled) quarantines this as a corrupt_drop
                writer.meta[0] ^= (corrupt.arg or 0xFF) & 0xFF
            _inject.stall("channel.doorbell.delay")
        writer.publish(nbytes, mlen, flags=flags, meta_crc=meta_crc)
        if t0 or c0 is not None:
            rid = (header.get(_trace.RID_KEY, 0)
                   if isinstance(header, dict) else 0)
            rid = rid if isinstance(rid, int) else 0
            if t0:
                _trace.emit(_trace.CH_PUBLISH, t0, rid=rid, arg=nbytes)
            if c0 is not None:
                _hw.end(c0, "publish", nbytes=nbytes, rid=rid)

    def _decode_meta(self, raw: bytes):
        """(header, descriptor) from wire meta; descriptors are cached by
        their pickled bytes and binary headers decode without pickle, so
        a stable stream never calls ``pickle.loads``."""
        fmt = raw[0]
        (dlen,) = _U32.unpack_from(raw, 1)
        descr_bytes = raw[_META_FIXED:_META_FIXED + dlen]
        descr = self._rx_descr_cache.get(descr_bytes)
        if descr is None:
            descr = pickle.loads(descr_bytes)
            self.stats.meta_unpickles += 1
            if self._cache_enabled:
                self._rx_descr_cache[descr_bytes] = descr
                while len(self._rx_descr_cache) > _DESCR_CACHE_MAX:
                    self._rx_descr_cache.popitem(last=False)
        else:
            self._rx_descr_cache.move_to_end(descr_bytes)
        if fmt == META_BINARY:
            header = _dec_header(raw, _META_FIXED + dlen)
        else:
            header = pickle.loads(raw[_META_FIXED + dlen:])
            self.stats.meta_unpickles += 1
        return header, descr

    # -- route selection (static thresholds or the adaptive governor) ---------
    def _tx_backlog(self) -> float:
        """Sender-side queue depth: unconsumed ring slots + engine-queued
        sends + the open frame's entries (shared-counter reads only)."""
        backlog = self.tx.produced - self.tx.consumed + len(self._inflight)
        frame = self._frame
        if frame is not None:
            backlog += frame.k
        return float(backlog)

    def _coalesce_capable(self, nbytes: int, mode: ExecutionMode) -> bool:
        """Structural coalescing legality: async/pipelined sub-slot
        message under the size cap, K > 1 possible."""
        return (mode != ExecutionMode.SYNC
                and self.policy.coalesce_max > 1
                and nbytes <= min(self.policy.coalesce_limit_bytes(),
                                  self.tx.spec.slot_bytes)
                and not self._use_heap(nbytes))

    def _route(self, nbytes: int, mode: ExecutionMode) -> str:
        gov = self.governor
        if gov is None:
            if self._use_heap(nbytes):
                return HEAP
            if (self.policy.coalesce_bytes > 0
                    and nbytes <= self.policy.coalesce_bytes
                    and self._coalesce_capable(nbytes, mode)):
                return COALESCE
            if (mode == ExecutionMode.SYNC
                    or not self.policy.should_offload(nbytes)):
                return INLINE
            return OFFLOAD
        heap_ok = self._heap is not None and self._heap.spec.enabled
        if heap_ok and nbytes > self.tx.spec.slot_bytes:
            return HEAP                  # mandatory: cannot fit a slot
        eligible = [INLINE]
        if mode != ExecutionMode.SYNC and self.policy.device == Device.OFFLOAD:
            eligible.append(OFFLOAD)
        if self._coalesce_capable(nbytes, mode):
            eligible.append(COALESCE)
        if heap_ok and nbytes >= self._heap.spec.extent_bytes:
            eligible.append(HEAP)
        return gov.decide(nbytes, eligible, backlog_fn=self._tx_backlog)

    def _observe_done_handle(self, h: SendHandle) -> None:
        """Feed the governor an offloaded/heap send's completion-record
        latency (submit→finish, taken by the engine — no extra clocks)."""
        gov = self.governor
        if gov is None or h._job is None or h._job.finished_t is None:
            return
        gov.observe(h.route, h.nbytes,
                    (h._job.finished_t - h._job.submit_t) * 1e6)

    def _track_inflight(self, handle: SendHandle, mode: ExecutionMode,
                        timeout_s: float) -> None:
        """Register an offloaded send for FIFO flushes + pipelined depth;
        prunes cleanly-completed handles (a failed one is kept: flush must
        surface its exception) and harvests their governor observations."""
        with self._inflight_lock:
            while (self._inflight and self._inflight[0].done()
                   and not self._inflight[0].failed()):
                self._observe_done_handle(self._inflight.popleft())
            self._inflight.append(handle)
        if mode == ExecutionMode.PIPELINED:
            # bounded in-flight depth (the engine's backpressure, same
            # shape); handles drained here must still feed the governor —
            # under sustained offload the depth wait consumes almost every
            # handle, and without the observation the route's cost would
            # stay unmeasured while it keeps being picked
            def waited(h: SendHandle) -> None:
                h.wait(timeout_s)
                self._observe_done_handle(h)

            drain_to_depth(self._inflight, self._inflight_lock,
                           self.policy.pipeline_depth, waited)

    # -- send -----------------------------------------------------------------
    def _fill_and_publish(self, sg: SGList, descr_bytes: bytes,
                          header: Optional[dict], nbytes: int) -> None:
        self._publish(sg.ctx, descr_bytes, header, nbytes)

    def _acquire_sg(self, tree, descr, timeout_s: float) -> SGList:
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    def _acquire_sg_nonblocking(self, tree, descr, timeout_s: float,
                                state: dict) -> SGList:
        """Engine-thread slot acquire: never blocks a shared copy-engine
        worker.  A full ring raises :class:`WouldBlock` so the engine parks
        this channel's work queue and retries at quantum cadence — other
        channels keep copying meanwhile; the blocking-path semantics
        (ChannelClosed on peer shutdown, TimeoutError after ``timeout_s``)
        are preserved."""
        if state.get("deadline") is None:
            state["deadline"] = time.perf_counter() + timeout_s
        with self._send_lock:
            writer = self.tx.try_acquire()
        if writer is None:
            if self.tx.peer_closed:
                raise ChannelClosed("peer endpoint closed the transport")
            if time.perf_counter() > state["deadline"]:
                raise TimeoutError(
                    f"ring full for {timeout_s}s (consumer stalled?)")
            raise WouldBlock(self.policy.poll_interval_us * 1e-6)
        sg = SGList()
        _gather_sg(tree, descr, writer.payload, sg)
        sg.ctx = writer
        return sg

    # -- coalesced (small-message) send path ----------------------------------
    def _frame_kcap(self) -> int:
        """Sub-message table capacity: the policy K bounded by what the
        meta region can hold (table + headroom for the sub-metas)."""
        room = (self.tx.spec.meta_bytes - _FRAME_HDR.size) // (
            _FRAME_ENTRY.size * 2)
        return max(2, min(self.policy.coalesce_max, room))

    def _frame_append(self, frame: _Frame, tree, descr, descr_bytes: bytes,
                      header: Optional[dict], nbytes: int) -> bool:
        """Pack one sub-message into the open frame (sub-meta encoded into
        the slot's meta region, payload gathered into the slot); False
        when the frame is full (payload, meta, or K capacity)."""
        if frame.k >= frame.kcap:
            return False
        pay_off = _align(frame.pay_cursor)
        if pay_off + nbytes > self.tx.spec.slot_bytes:
            return False
        try:
            mlen = self._encode_meta_into(
                frame.writer.meta[frame.meta_cursor:], descr_bytes, header)
        except MetaOverflow:
            return False
        sg = SGList()
        _gather_sg(tree, descr, frame.writer.payload[pay_off:], sg)
        self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                            tag="send", account=False)
        frame.copies += len(sg)      # accounted once per frame at flush
        frame.copied_bytes += sg.nbytes
        frame.table.append((frame.meta_cursor, mlen, pay_off, nbytes))
        frame.meta_cursor += mlen
        frame.pay_cursor = pay_off + nbytes
        frame.k += 1
        return True

    def _flush_frame_locked(self, frame: _Frame) -> None:
        """Write the sub-message table and publish the frame under one
        state flip (the amortized doorbell).  Caller holds the coalescing
        lock and has verified ``frame`` is the open one."""
        t0 = time.perf_counter()
        mv = frame.writer.meta
        _FRAME_HDR.pack_into(mv, 0, META_FRAME, frame.k)
        off = _FRAME_HDR.size
        for entry in frame.table:
            _FRAME_ENTRY.pack_into(mv, off, *entry)
            off += _FRAME_ENTRY.size
        meta_crc = zlib.crc32(mv[:frame.meta_cursor]) \
            if self.policy.meta_checksum else -1
        frame.writer.publish(frame.pay_cursor, frame.meta_cursor,
                             flags=FLAG_COALESCED, meta_crc=meta_crc)
        self._frame = None
        self.stats.frames_sent += 1
        # one accounting pass per frame: the appends' deferred copy counts
        # plus the frame/message events the doorbell gate reads
        self._engine.count("send", frame.copies, frame.copied_bytes,
                           injection=self.policy.injection_enabled())
        self._engine.count_event("coalesced_frames")
        self._engine.count_event("coalesced_msgs", frame.k)
        gov = self.governor
        if gov is not None:
            # per-message cost = an equal share of the WHOLE frame's time
            # (appends + claim + publish).  Spreading — rather than
            # per-entry attribution — matters: the slot-acquire wait under
            # backpressure lands entirely on the frame-opening append, and
            # diluting it across K keeps that throughput signal in every
            # observation instead of one outlier the robust EWMA clips
            total_us = (time.perf_counter() - t0) * 1e6
            for nbytes, append_us in frame.entries:
                total_us += append_us
            per_msg_us = total_us / frame.k
            for nbytes, _ in frame.entries:
                gov.observe(COALESCE, nbytes, per_msg_us)
        frame.event.set()

    def _flush_frame(self, frame: Optional[_Frame] = None) -> None:
        """Publish the open frame (all of it).  With ``frame`` given, only
        if that exact frame is still the open one (a handle's pull-flush
        must not force out a successor frame)."""
        with self._coal_lock:
            cur = self._frame
            if cur is None or (frame is not None and cur is not frame):
                return
            self._flush_frame_locked(cur)

    def _coalesce_send(self, tree, descr, descr_bytes: bytes,
                       header: Optional[dict], nbytes: int,
                       timeout_s: float) -> Optional[SendHandle]:
        """Append one message to the open microbatch frame (opening one —
        which claims the next tx slot — if needed).  Returns None when the
        message structurally cannot ride a frame (the caller falls back to
        the inline route)."""
        t0 = time.perf_counter()
        with self._coal_lock:
            frame = self._frame
            for _ in range(2):
                if frame is None:
                    # FIFO: a new frame's slot must be claimed after every
                    # earlier offloaded send has published — otherwise the
                    # frame overtakes them on the wire (the inline and
                    # offload paths enforce the same order via flush())
                    self._drain_inflight(timeout_s)
                    with self._send_lock:
                        writer = self.tx.acquire(timeout_s)
                    frame = self._frame = _Frame(writer, self._frame_kcap(),
                                                 t0)
                if self._frame_append(frame, tree, descr, descr_bytes,
                                      header, nbytes):
                    break
                if frame.k == 0:
                    # cannot fit even an empty frame (huge descriptor?):
                    # give the slot back as a skip sentinel, fall back
                    frame.writer.abort()
                    self._frame = None
                    return None
                self._flush_frame_locked(frame)
                frame = None
            self.stats.sends += 1
            self.stats.inline += 1
            self.stats.coalesced_sends += 1
            self.stats.bytes_sent += nbytes
            now = time.perf_counter()
            frame.entries.append((nbytes, (now - t0) * 1e6))
            window_s = self.policy.coalesce_window_us * 1e-6
            if frame.k >= frame.kcap or now - frame.opened_t >= window_s:
                self._flush_frame_locked(frame)
            return SendHandle(self, nbytes, frame=frame, route=COALESCE)

    # -- heap (large-message) send path ---------------------------------------
    def _heap_alloc_blocking(self, nbytes: int, timeout_s: float):
        """Blocking extent allocation that converts "peer died while we
        waited" into the channel's usual :class:`ChannelClosed`."""
        try:
            return self._heap.alloc(
                nbytes, timeout_s=timeout_s,
                poll_interval_s=self.policy.poll_interval_us * 1e-6,
                abort_check=lambda: self.tx.peer_closed)
        except HeapExhausted as e:
            raise ChannelClosed(str(e)) from None

    def _validate_heap_meta(self, descr_bytes: bytes,
                            header: Optional[dict]) -> None:
        """Fail a heap send *before* any copy/alloc when even a
        worst-case scatter list cannot fit the ring's meta region."""
        cap = self._heap.spec.dir_bytes
        scratch = memoryview(bytearray(self.tx.spec.meta_bytes))
        try:
            self._encode_meta_into(scratch, descr_bytes, header,
                                   ((cap, cap),) * MAX_SEGMENTS, count=False)
        except MetaOverflow:
            raise ValueError(
                f"heap meta exceeds meta capacity "
                f"{self.tx.spec.meta_bytes} B (raise data_meta_bytes)"
            ) from None

    def _send_heap_inline(self, tree, descr, descr_bytes, header,
                          nbytes: int, timeout_s: float) -> SendHandle:
        """Sync/below-offload heap send: one blocking gather into the
        extents on the caller's thread, then publish the descriptor."""
        self.stats.inline += 1
        self.flush(timeout_s)      # FIFO: inline never overtakes offloads
        segs = self._heap_alloc_blocking(nbytes, timeout_s)
        heap = self._heap
        try:
            sg = SGList()
            _heap_fill_sg(tree, descr, heap, heap.tx_dir, segs, nbytes, sg)
            self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                                tag="heap_fill",
                                count_copies=_count_leaves(descr))
            with self._send_lock:
                w = self.tx.acquire(timeout_s)
        except BaseException:
            heap.free(segs, heap.tx_dir)   # ownership transfers at publish
            raise
        try:
            self._publish(w, descr_bytes, header, nbytes, flags=FLAG_HEAP,
                          segments=segs)
        except BaseException:
            heap.free(segs, heap.tx_dir)
            raise
        return SendHandle(self, nbytes, route=HEAP)

    def _send_heap_offloaded(self, tree, descr, descr_bytes, header,
                             nbytes: int, timeout_s: float) -> SendHandle:
        """Async/pipelined heap send: the fill is split into chunk-sized
        SG submissions on this channel's work queue (copy of message k+1
        overlaps the peer's drain of message k), the last submission
        claims a ring slot and publishes the extent descriptor."""
        self.stats.offloaded += 1
        heap = self._heap
        n_leaves = _count_leaves(descr)
        chunk_bytes = max(1, self.policy.heap_chunk_bytes)
        n_chunks = max(1, -(-nbytes // chunk_bytes))
        chunk_jobs: list[CopyJob] = []
        state: dict = {"segs": None, "chunks": None, "err": None,
                       "alloc_deadline": None, "ring_deadline": None}

        def fail(e: BaseException):
            state["err"] = e
            raise e

        def build_chunk(i: int) -> SGList:
            if state["err"] is not None:
                raise state["err"]
            if i == 0 and state["chunks"] is None:
                if state["alloc_deadline"] is None:
                    state["alloc_deadline"] = time.perf_counter() + timeout_s
                segs = heap.try_alloc(nbytes)
                if segs is None:
                    if self.tx.peer_closed:
                        fail(ChannelClosed(
                            "peer endpoint closed the transport"))
                    if time.perf_counter() > state["alloc_deadline"]:
                        fail(TimeoutError(
                            f"bulk heap exhausted for {timeout_s}s "
                            f"(receiver holding leases?)"))
                    raise WouldBlock(self.policy.poll_interval_us * 1e-6)
                try:
                    sg = SGList()
                    _heap_fill_sg(tree, descr, heap, heap.tx_dir, segs,
                                  nbytes, sg)
                    state["chunks"] = split_sg(sg, chunk_bytes)
                    state["segs"] = segs
                except BaseException as e:
                    heap.free(segs, heap.tx_dir)
                    fail(e)
            chunks = state["chunks"]
            if chunks is None:
                raise RuntimeError("heap fill aborted (earlier chunk failed)")
            return chunks[i] if i < len(chunks) else SGList()

        def build_final() -> SGList:
            if state["err"] is not None:
                raise state["err"]
            if state["segs"] is None:
                raise RuntimeError("heap fill aborted (earlier chunk failed)")
            # chunk jobs are fire-and-forget, so a copy failure on the
            # engine thread (not routed through fail()) must be surfaced
            # HERE: publishing after a failed chunk would hand the
            # receiver a payload with an uncopied hole as a success
            for j in chunk_jobs:
                if j.failed():
                    heap.free(state["segs"], heap.tx_dir)
                    state["segs"] = None
                    j.wait(0)              # re-raises the chunk's exception
            if state["ring_deadline"] is None:
                state["ring_deadline"] = time.perf_counter() + timeout_s
            with self._send_lock:
                writer = self.tx.try_acquire()
            if writer is None:
                if self.tx.peer_closed:
                    heap.free(state["segs"], heap.tx_dir)
                    fail(ChannelClosed(
                        "peer endpoint closed the transport"))
                if time.perf_counter() > state["ring_deadline"]:
                    heap.free(state["segs"], heap.tx_dir)
                    fail(TimeoutError(
                        f"ring full for {timeout_s}s (consumer stalled?)"))
                raise WouldBlock(self.policy.poll_interval_us * 1e-6)
            sg = SGList()
            sg.ctx = writer
            return sg

        def complete_final(sg: SGList):
            writer: SlotWriter = sg.ctx
            try:
                self._publish(writer, descr_bytes, header, nbytes,
                              flags=FLAG_HEAP, segments=state["segs"])
            except BaseException:
                heap.free(state["segs"], heap.tx_dir)
                raise

        inject = self.policy.injection_enabled()
        for i in range(n_chunks):
            chunk_jobs.append(self._engine.submit(
                Descriptor(build=lambda i=i: build_chunk(i),
                           nbytes=min(chunk_bytes, nbytes - i * chunk_bytes),
                           injection=inject, tag="heap_fill",
                           count_copies=n_leaves if i == 0 else 0),
                wq=self, policy=self.policy, latency=self.latency,
                stats=self.stats))
        job = self._engine.submit(
            Descriptor(build=build_final, complete=complete_final,
                       nbytes=nbytes, injection=inject, tag="heap_publish",
                       count_copies=0),
            wq=self, policy=self.policy, latency=self.latency,
            stats=self.stats)
        return SendHandle(self, nbytes, job=job, route=HEAP)

    def _send_heap(self, tree, descr, descr_bytes, header,
                   nbytes: int, mode: ExecutionMode,
                   timeout_s: float) -> SendHandle:
        """Route one large pytree through the bulk heap; the ring carries
        only the compact extent descriptor."""
        self._validate_heap_meta(descr_bytes, header)   # before any counting
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes
        self.stats.heap_sends += 1
        if mode == ExecutionMode.SYNC or not self.policy.should_offload(nbytes):
            gov = self.governor
            t0 = time.perf_counter() if gov is not None else 0.0
            handle = self._send_heap_inline(tree, descr, descr_bytes, header,
                                            nbytes, timeout_s)
            if gov is not None:
                gov.observe(HEAP, nbytes, (time.perf_counter() - t0) * 1e6)
            return handle
        handle = self._send_heap_offloaded(tree, descr, descr_bytes, header,
                                           nbytes, timeout_s)
        self._track_inflight(handle, mode, timeout_s)
        return handle

    def send(self, tree, header: Optional[dict] = None,
             mode: ExecutionMode | str | None = None,
             timeout_s: float = 30.0) -> SendHandle:
        """Send one pytree under the given (or policy) mode; see module
        docstring for the sync/async/pipelined semantics.  The per-message
        strategy — inline slot copy, engine offload, coalesced microbatch
        frame, or bulk-heap extents — comes from the static policy
        thresholds or, with ``policy.governor="adaptive"``, from the
        channel's measured-break-even governor.

        When tracing is enabled a request id is minted (or reused from
        ``header``) under the reserved :data:`repro.obs.trace.RID_KEY`
        header key so the message's lifecycle joins across processes; the
        wire bytes are unchanged when tracing is off."""
        if not _trace.TRACE.enabled:
            return self._send_impl(tree, header, mode, timeout_s)
        header = {} if header is None else header
        rid = header.get(_trace.RID_KEY) or _trace.mint_rid()
        header[_trace.RID_KEY] = rid
        t0 = _trace.now()
        try:
            return self._send_impl(tree, header, mode, timeout_s)
        finally:
            _trace.emit(_trace.CH_SEND, t0, rid=rid)

    def _send_impl(self, tree, header: Optional[dict],
                   mode: ExecutionMode | str | None,
                   timeout_s: float) -> SendHandle:
        """Untraced body of :meth:`send` (route, encode, publish)."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        descr, descr_bytes, nbytes = self._encode_descr(tree)
        route = self._route(nbytes, mode)
        if route == HEAP:
            if not (self._heap is not None and self._heap.spec.enabled):
                raise ValueError(
                    f"message of {nbytes} B exceeds slot capacity "
                    f"{self.tx.spec.slot_bytes} B and no bulk heap is "
                    f"attached — raise data_slot_bytes or enable "
                    f"heap_extents")
            return self._send_heap(tree, descr, descr_bytes, header, nbytes,
                                   mode, timeout_s)
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B and no bulk heap is attached "
                f"— raise data_slot_bytes or enable heap_extents")
        if route == COALESCE:
            handle = self._coalesce_send(tree, descr, descr_bytes, header,
                                         nbytes, timeout_s)
            if handle is not None:
                return handle
            route = INLINE                 # structural fallback
        self.stats.sends += 1
        self.stats.bytes_sent += nbytes

        if route == INLINE:
            gov = self.governor
            # subsample inline observations 4:1 once warm — the EWMA needs
            # a trickle of fresh cost data, not a pair of clock reads on
            # every send; while the estimate is cold, observe every send
            # so the baseline isn't four unlucky draws
            observe = gov is not None and ((self.stats.sends & 3) == 0
                                           or gov.wants_sample(INLINE,
                                                               nbytes))
            t0 = time.perf_counter() if observe else 0.0
            self.stats.inline += 1
            self._flush_frame()        # FIFO: publish the open frame first
            self.flush(timeout_s)      # FIFO: inline never overtakes offloads
            sg = self._acquire_sg(tree, descr, timeout_s)
            self._engine.run_sg(sg, injection=self.policy.injection_enabled(),
                                tag="send")
            self._fill_and_publish(sg, descr_bytes, header, nbytes)
            if observe:
                gov.observe(INLINE, nbytes, (time.perf_counter() - t0) * 1e6)
            return SendHandle(self, nbytes)

        self.stats.offloaded += 1
        self._flush_frame()            # FIFO wrt pending coalesced messages
        acquire_state: dict = {}       # deadline anchored at first attempt
        job = self._engine.submit(
            Descriptor(build=lambda: self._acquire_sg_nonblocking(
                           tree, descr, timeout_s, acquire_state),
                       complete=lambda sg: self._fill_and_publish(
                           sg, descr_bytes, header, nbytes),
                       nbytes=nbytes,
                       injection=self.policy.injection_enabled(),
                       tag="send"),
            wq=self, policy=self.policy, latency=self.latency,
            stats=self.stats)
        handle = SendHandle(self, nbytes, job=job, route=OFFLOAD)
        self._track_inflight(handle, mode, timeout_s)
        return handle

    def reserve(self, template, header: Optional[dict] = None,
                timeout_s: float = 30.0) -> TxSlot:
        """Reserve-then-fill: claim the next tx slot, lay it out for
        ``template`` (a pytree of arrays — shapes/dtypes only, nothing is
        copied), and return a :class:`TxSlot` of writable views.  The
        caller packs the message directly into the destination slot and
        calls ``publish()`` — no staging copy, and the descriptor meta
        comes from the same structure-keyed cache as ``send``.

        A template at/over ``policy.heap_threshold_bytes`` (or over the
        slot capacity) reserves bulk-heap extents instead: the returned
        views point into the heap, and ``publish()`` claims a ring slot
        only for the compact extent descriptor."""
        if self.tx is None:
            raise RuntimeError("receive-only channel")
        descr, descr_bytes, nbytes = self._encode_descr(template)
        if self._use_heap(nbytes):
            self._validate_heap_meta(descr_bytes, header)
            self._flush_frame()
            self.flush(timeout_s)      # FIFO wrt earlier offloaded sends
            segs = self._heap_alloc_blocking(nbytes, timeout_s)
            tree, staged = _writable_heap_tree(descr, self._heap,
                                               self._heap.tx_dir, segs,
                                               nbytes)
            return TxSlot(tree, None, descr_bytes, header, nbytes, self,
                          heap_state={"segments": segs, "staged": staged,
                                      "timeout_s": timeout_s})
        if nbytes > self.tx.spec.slot_bytes:
            raise ValueError(
                f"message of {nbytes} B exceeds slot capacity "
                f"{self.tx.spec.slot_bytes} B and no bulk heap is attached "
                f"— raise data_slot_bytes or enable heap_extents")
        self._flush_frame()            # FIFO wrt pending coalesced messages
        self.flush(timeout_s)          # FIFO wrt earlier offloaded sends
        with self._send_lock:
            writer = self.tx.acquire(timeout_s)
        tree = _unpack(descr, writer.payload, copy=False)
        return TxSlot(tree, writer, descr_bytes, header, nbytes, self)

    def _drain_inflight(self, timeout_s: float) -> None:
        """Complete every outstanding offloaded send (never touches the
        coalescing lock, so frame paths may call it while holding it)."""
        with self._inflight_lock:
            if not self._inflight:
                return
            pending, self._inflight = self._inflight, deque()
        for h in pending:
            h.wait(timeout_s)
            self._observe_done_handle(h)

    def flush_open_frame(self) -> None:
        """Publish the open coalesced frame, if any (cheap no-op
        otherwise) — the non-blocking half of :meth:`flush` for callers
        that must put pending framed messages on the wire without waiting
        out unrelated in-flight offloaded sends."""
        self._flush_frame()

    def flush(self, timeout_s: float = 30.0) -> None:
        """Publish the open coalesced frame and complete all outstanding
        pipelined sends (batch-level check)."""
        self._flush_frame()
        self._drain_inflight(timeout_s)

    # -- recv -----------------------------------------------------------------
    def _lease_from_heap(self, reader: SlotReader, header: dict, descr,
                         copy: bool):
        """Resolve a heap-routed message: the ring slot held only the
        extent descriptor, so it is released immediately — the lease (and
        its backpressure) is the *extents*, freed on release/unpack."""
        heap = self._heap
        segs = header.pop(_HX_KEY, None)
        if heap is None or not heap.spec.enabled or segs is None:
            reader.release()
            raise RuntimeError(
                "received a heap-routed message on a transport without a "
                "bulk heap (mismatched TransportSpec?)")
        nbytes = reader.payload_nbytes         # heap bytes (FLAG_HEAP)
        self.stats.recvs += 1
        self.stats.heap_recvs += 1
        self.stats.bytes_recv += nbytes
        tree, reasm, reasm_bytes = _unpack_heap(descr, heap, heap.rx_dir,
                                                segs, nbytes, copy)
        if reasm:
            # straddling leaves rebuilt with a counted copy (scatter allocs)
            self.stats.heap_reassembles += reasm
            self._engine.count("heap_reassemble", reasm, reasm_bytes)
        reader.release()                       # descriptor slot recycles now
        if copy:
            # counted staging copy, same tag as the slot path's copy-out
            self._engine.count("recv_copy", _count_leaves(descr), nbytes)
            heap.free(segs)
            return tree, header
        return RecvLease(tree, header, None,
                         on_release=lambda: heap.free(segs))

    def _msgs_from_frame(self, reader: SlotReader, copy: bool) -> list:
        """Unpack a coalesced frame into its K independent messages.  With
        ``copy=False`` each message is a lease sharing the refcounted slot
        reader (the slot recycles when the last one releases); with
        ``copy=True`` everything is copied out and the slot recycles now."""
        raw = reader.meta
        _, k = _FRAME_HDR.unpack_from(raw, 0)
        shared = None if copy else _SharedFrameReader(reader, k)
        pay = reader.slot.payload_view
        out = []
        off = _FRAME_HDR.size
        copied_leaves = copied_bytes = 0
        for _ in range(k):
            m_off, m_len, p_off, p_len = _FRAME_ENTRY.unpack_from(raw, off)
            off += _FRAME_ENTRY.size
            header, descr = self._decode_meta(raw[m_off:m_off + m_len])
            self.stats.recvs += 1
            self.stats.coalesced_recvs += 1
            self.stats.bytes_recv += p_len
            sub = pay[p_off:]
            if copy:
                tree = _unpack(descr, sub, copy=True)
                copied_leaves += _count_leaves(descr)
                copied_bytes += p_len
                out.append((tree, header))
            else:
                out.append(RecvLease(_unpack(descr, sub, copy=False),
                                     header, shared.ref()))
        if copy:
            # one counted batch per frame (same tag/totals as per-message
            # counting; one engine-lock round-trip instead of K)
            self._engine.count("recv_copy", copied_leaves, copied_bytes)
            reader.release()
        self.stats.frames_recv += 1
        return out

    def _pending_as(self, item, copy: bool):
        """Adapt a queued frame sub-message to the caller's ``copy``
        choice: a receive stream may legally alternate modes (e.g. warmup
        copies, then zero-copy), but a frame was unpacked under the mode
        of the recv that *polled* it."""
        if isinstance(item, RecvLease):
            if not copy:
                return item
            def walk(t):
                if isinstance(t, dict):
                    return {k: walk(v) for k, v in t.items()}
                if isinstance(t, (list, tuple)):
                    out = [walk(v) for v in t]
                    return out if isinstance(t, list) else tuple(out)
                return np.array(t)
            tree, header = walk(item.tree), item.header
            self._engine.count("recv_copy", _count_leaves(tree),
                               tree_nbytes(tree))
            item.release()
            return tree, header
        if copy:
            return item
        return RecvLease(item[0], item[1], None)   # already copied out

    def _crc_ok(self, reader: SlotReader) -> bool:
        """Verify a FLAG_CRC slot's meta checksum.  A mismatch quarantines
        the slot: counted (``corrupt_drops``), released, skipped — the
        drain loop survives instead of crashing on undecodable meta.  A
        corrupt FLAG_HEAP descriptor necessarily strands its extents
        (their addresses were in the corrupt meta); the stamp-based heap
        reaper reclaims them, which is the whole point of datable stamps."""
        if reader.meta_crc < 0:
            return True
        if zlib.crc32(reader.slot.meta_view[:reader.meta_nbytes]) == \
                reader.meta_crc:
            return True
        self.stats.corrupt_drops += 1
        reader.release()
        return False

    def _lease_from_reader(self, reader: SlotReader, copy: bool):
        if reader.flags & FLAG_COALESCED:
            msgs = self._msgs_from_frame(reader, copy)
            self._rx_pending.extend(msgs[1:])
            return msgs[0]
        header, descr = self._decode_meta(reader.meta)
        if reader.flags & FLAG_HEAP:
            return self._lease_from_heap(reader, header, descr, copy)
        self.stats.recvs += 1
        self.stats.bytes_recv += reader.payload_nbytes
        payload = reader.slot.payload_view
        if copy:
            tree = _unpack(descr, payload, copy=True)
            # counted staging copy: the receive-side memcpy the zero-copy
            # serving path exists to eliminate
            self._engine.count("recv_copy", _count_leaves(descr),
                               reader.payload_nbytes)
            reader.release()
            return tree, header
        return RecvLease(_unpack(descr, payload, copy=False), header, reader)

    def recv(self, timeout_s: float = 30.0, copy: bool = True,
             hint_nbytes: int = 0):
        """Receive one pytree; ``copy=False`` returns a :class:`RecvLease`
        whose arrays are zero-copy views into the slot.  Sub-messages of a
        coalesced frame are delivered one at a time, in order — only the
        first costs a ring poll."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        if self._rx_pending:
            return self._pending_as(self._rx_pending.popleft(), copy)
        deadline = time.perf_counter() + timeout_s
        while True:
            reader = self.rx.wait_recv(
                max(1e-3, deadline - time.perf_counter()), hint_nbytes)
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                hint_nbytes = 0
                continue
            if (reader.flags & FLAG_CRC) and not self._crc_ok(reader):
                hint_nbytes = 0
                continue
            return self._lease_from_reader(reader, copy)

    def try_recv(self, copy: bool = True):
        """Non-blocking receive; None when no message is ready."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        if self._rx_pending:
            return self._pending_as(self._rx_pending.popleft(), copy)
        while True:
            reader = self.rx.try_poll()
            if reader is None:
                return None
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                continue
            if (reader.flags & FLAG_CRC) and not self._crc_ok(reader):
                continue
            return self._lease_from_reader(reader, copy)

    def try_recv_many(self, limit: int, copy: bool = True) -> list:
        """Drain up to ``limit`` ready messages in one sweep — pending
        frame sub-messages first, then ring polls.  A coalesced frame's K
        messages cost ONE poll here (the receive half of the amortized
        doorbell); the reactor uses this to feed a whole frame into batch
        formation without K separate poll iterations."""
        if self.rx is None:
            raise RuntimeError("send-only channel")
        out: list = []
        while len(out) < limit:
            if self._rx_pending:
                out.append(self._pending_as(self._rx_pending.popleft(),
                                            copy))
                continue
            reader = self.rx.try_poll()
            if reader is None:
                break
            if reader.meta_nbytes == 0:     # aborted reserve: skip sentinel
                reader.release()
                continue
            if (reader.flags & FLAG_CRC) and not self._crc_ok(reader):
                continue
            out.append(self._lease_from_reader(reader, copy))
        return out

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout_s: float = 5.0) -> None:
        """Flush the open frame + outstanding sends (the shared copy
        engine stays up — it serves every other channel in the process)."""
        try:
            self.flush(timeout_s)
        except (TimeoutError, ChannelClosed):
            pass


class ControlChannel:
    """Small pickled-object messages (commands, acks) over tiny slots.

    Both receive paths surface :class:`~repro.ipc.ring.ChannelClosed`
    consistently once the peer endpoint announced shutdown (after the
    ring is drained), so callers never have to poke ring internals to
    distinguish "no message yet" from "peer is gone"."""

    def __init__(self, tx: Optional[Ring], rx: Optional[Ring]):
        self.tx = tx
        self.rx = rx
        self._lock = threading.Lock()

    def send_msg(self, obj: Any, timeout_s: float = 30.0) -> None:
        """Send one small pickled message (blocks while the ring is full)."""
        blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) > self.tx.spec.slot_bytes:
            raise ValueError(f"control message of {len(blob)} B too large")
        with self._lock:
            w = self.tx.acquire(timeout_s)
            w.payload[:len(blob)] = blob
            w.publish(len(blob))

    def recv_msg(self, timeout_s: float = 30.0) -> Any:
        """Blocking receive of one message; raises
        :class:`~repro.ipc.ring.ChannelClosed` when the peer shut down
        while we were waiting (in-flight messages are delivered first)."""
        with self.rx.wait_recv(timeout_s) as r:
            return pickle.loads(r.payload)

    def try_recv_msg(self) -> Any:
        """Non-blocking receive; None when no message is waiting, and
        :class:`~repro.ipc.ring.ChannelClosed` once the peer announced
        shutdown and the ring is fully drained."""
        r = self.rx.try_poll()
        if r is None:
            if self.rx.peer_closed:
                raise ChannelClosed(
                    "control peer closed and the ring is drained")
            return None
        with r:
            return pickle.loads(r.payload)
