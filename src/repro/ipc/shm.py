"""Shared-memory arena: pre-mapped cross-process segments + seqlock words.

The real-IPC substrate for the paper's queue pairs (§IV-C).  A
:class:`SharedMemoryArena` is one named POSIX shared-memory segment
(`multiprocessing.shared_memory`) that both endpoints map:

- the **creator** allocates the segment, writes the arena header, and
  *first-touches* every page at setup (``buf[:] = 0``), so no page faults or
  copy-on-write remaps happen on the data path — the paper's pre-mapping;
- the **attacher** opens the same name and validates the header (magic,
  version, size), mirroring the paper's connection setup handshake.

Layout: ``[ArenaHeader | user region]``.  The header carries a small table of
64-bit control words (head/tail cursors, state flags) that the ring layer
uses; single-word reads/writes of aligned int64 through numpy are the
"atomic" primitive (CPython + the GIL + a single aligned store make these
untorn in practice on every platform we target).

Multi-word metadata that one side writes while the other polls is protected
with a :class:`SeqLock` — the classic sequence lock: the writer makes the
sequence odd, writes the payload, makes it even; a reader retries whenever it
observes an odd sequence or the sequence changed across its read (torn read).
"""
from __future__ import annotations

import os
import struct
import time
from multiprocessing import shared_memory
import numpy as np

MAGIC = 0x524F434B          # "ROCK"
VERSION = 1
_HEADER_FMT = "<IIQQ"       # magic, version, total_bytes, user_offset
_HEADER_BYTES = struct.calcsize(_HEADER_FMT)
# control-word table: the rings' cursors/flags as contiguous int64 words.
# They share cache lines (no 64B padding) — at Python's access rates false
# sharing is noise; what matters is that each word is written by one side.
N_CONTROL_WORDS = 16
_WORD_STRIDE = 8            # int64 words, contiguous (numpy view)
_CONTROL_BYTES = N_CONTROL_WORDS * _WORD_STRIDE
HEADER_REGION = 64 + _CONTROL_BYTES      # header struct padded to 64


class SeqLock:
    """Sequence lock over one aligned int64 word in shared memory.

    Writer:  ``with lock.write(): ...mutate payload...``
    Reader:  ``lock.read(fn)`` retries ``fn()`` until an even, stable
    sequence brackets the read (no torn/in-progress observation).
    """

    def __init__(self, word: np.ndarray):
        assert word.dtype == np.int64 and word.size == 1
        self._word = word

    @property
    def sequence(self) -> int:
        """Current sequence value (odd = a write is in progress)."""
        return int(self._word[0])

    def write_begin(self) -> None:
        """Make the sequence odd: readers retry until write_end."""
        seq = int(self._word[0])
        if seq % 2:
            raise RuntimeError("seqlock already held by a writer")
        self._word[0] = seq + 1           # odd: write in progress

    def write_end(self) -> None:
        """Make the sequence even again: the payload is stable."""
        seq = int(self._word[0])
        if seq % 2 == 0:
            raise RuntimeError("seqlock write_end without write_begin")
        self._word[0] = seq + 1           # even: stable

    class _WriteCtx:
        def __init__(self, lock: "SeqLock"):
            self._lock = lock

        def __enter__(self):
            self._lock.write_begin()
            return self._lock

        def __exit__(self, *exc):
            self._lock.write_end()

    def write(self) -> "SeqLock._WriteCtx":
        """Context manager bracketing a payload write with begin/end."""
        return SeqLock._WriteCtx(self)

    def read(self, fn, max_retries: int = 1_000_000,
             spin_sleep_s: float = 1e-6):
        """Run ``fn()`` under torn-read protection and return its result."""
        for _ in range(max_retries):
            s1 = int(self._word[0])
            if s1 % 2:                    # writer mid-flight
                time.sleep(spin_sleep_s)
                continue
            out = fn()
            s2 = int(self._word[0])
            if s1 == s2:
                return out
            time.sleep(spin_sleep_s)      # torn: payload changed underneath
        raise TimeoutError("seqlock read retries exhausted")


class ShmMutex:
    """Cross-process mutex built on *exclusive* shm-segment creation.

    ``shm_open(O_CREAT|O_EXCL)`` is the one atomic test-and-set the OS gives
    us without extra dependencies: creating a named segment fails with
    ``FileExistsError`` when it already exists.  Acquire = create the segment
    (stamping owner pid + wall-clock time into it); release = unlink it.

    Used by the listener's registration handshake, where multiple client
    processes that share nothing but a name must take turns writing the
    rendezvous mailbox (our rings are strictly SPSC).

    A holder that dies without releasing would wedge everyone, so contenders
    break locks older than ``stale_s``.  ``shm_unlink`` removes *by name*,
    so a breaker re-reads the stamp from a freshly attached handle right
    before unlinking and only proceeds if it still matches the stale stamp
    it decided on — a segment some other breaker just re-created (fresh
    stamp) is left alone.  A residual race remains (two breakers can pass
    the re-check before either unlinks; POSIX shm has no compare-and-unlink)
    but it needs a holder death *plus* two simultaneous breakers, and its
    worst case is bounded: the registration mailbox writer raises (seqlock
    write_begin refuses a second writer) or a registration times out and
    can be retried — never silent corruption.
    """

    _STAMP_FMT = "<qd"          # owner pid, wall-clock acquire time

    def __init__(self, name: str, stale_s: float = 30.0):
        self.name = name
        self.stale_s = stale_s
        self._held: shared_memory.SharedMemory | None = None

    def acquire(self, timeout_s: float = 10.0,
                poll_s: float = 0.002) -> None:
        """Take the lock, breaking stale holders; TimeoutError on contention."""
        deadline = time.perf_counter() + timeout_s
        while True:
            try:
                seg = shared_memory.SharedMemory(
                    self.name, create=True,
                    size=struct.calcsize(self._STAMP_FMT))
                struct.pack_into(self._STAMP_FMT, seg.buf, 0,
                                 os.getpid(), time.time())
                self._held = seg
                return
            except FileExistsError:
                self._break_if_stale()
            if time.perf_counter() > deadline:
                raise TimeoutError(f"lock {self.name!r} contended for "
                                   f"{timeout_s}s")
            time.sleep(poll_s)

    def _read_stamp(self):
        """(pid, acquire-time) from the current segment, or None if gone."""
        try:
            seg = shared_memory.SharedMemory(self.name, create=False)
        except FileNotFoundError:
            return None                 # holder released between our attempts
        except ValueError:
            # raced the creator between shm_open and ftruncate: the segment
            # exists but is still empty (mmap of size 0) — treat as "stamp
            # not readable yet", i.e. a fresh, non-stale holder
            return None
        try:
            return struct.unpack_from(self._STAMP_FMT, seg.buf, 0)
        except struct.error:
            return None
        finally:
            seg.close()

    def _break_if_stale(self) -> None:
        stamp = self._read_stamp()
        if stamp is None or not stamp[1] or \
                time.time() - stamp[1] <= self.stale_s:
            return
        # revalidate on a fresh handle right before unlinking: the name may
        # now belong to a segment another breaker just re-created (unlink
        # removes by NAME, not the inode we inspected)
        try:
            seg = shared_memory.SharedMemory(self.name, create=False)
        except (FileNotFoundError, ValueError):
            return                      # gone, or re-created mid-ftruncate
        try:
            if struct.unpack_from(self._STAMP_FMT, seg.buf, 0) == stamp:
                seg.unlink()            # holder presumed dead
        except (struct.error, FileNotFoundError):
            pass
        finally:
            seg.close()

    def release(self) -> None:
        """Drop the lock (idempotent)."""
        if self._held is not None:
            held, self._held = self._held, None
            held.close()
            try:
                held.unlink()
            except FileNotFoundError:
                pass

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class SharedMemoryArena:
    """One named, pre-mapped shared-memory segment with a validated header."""

    def __init__(self, name: str, size: int = 0, create: bool = False,
                 pre_touch: bool = True):
        self.name = name
        self.is_creator = create
        if create:
            total = HEADER_REGION + size
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=total)
            if pre_touch:
                # first-touch every page now so the data path never faults;
                # memset through a view (no arena-sized bytes temporary)
                view = np.frombuffer(self._shm.buf, np.uint8)
                view[:] = 0
                del view
            struct.pack_into(_HEADER_FMT, self._shm.buf, 0,
                             MAGIC, VERSION, total, HEADER_REGION)
        else:
            self._shm = shared_memory.SharedMemory(name=name, create=False)
            magic, version, total, user_off = struct.unpack_from(
                _HEADER_FMT, self._shm.buf, 0)
            if magic != MAGIC:
                raise ValueError(f"{name}: not a ROCKET arena (magic "
                                 f"{magic:#x})")
            if version != VERSION:
                raise ValueError(f"{name}: arena version {version} != "
                                 f"{VERSION}")
        self._user_offset = HEADER_REGION
        self._closed = False

    # -- views ---------------------------------------------------------------
    @property
    def buf(self) -> memoryview:
        """The raw mapped segment (header + control words + user region)."""
        return self._shm.buf

    @property
    def size(self) -> int:
        """Bytes available in the user region."""
        return len(self._shm.buf) - self._user_offset

    def control_words(self) -> np.ndarray:
        """The int64 control-word table (shared cursors/flags)."""
        return np.frombuffer(self._shm.buf, np.int64,
                             count=N_CONTROL_WORDS, offset=64)

    def seqlock(self, word_index: int) -> SeqLock:
        """A :class:`SeqLock` over the given control word."""
        words = self.control_words()
        return SeqLock(words[word_index:word_index + 1])

    def view(self, offset: int, nbytes: int) -> memoryview:
        """A memoryview into the user region at ``offset``."""
        start = self._user_offset + offset
        if start + nbytes > len(self._shm.buf):
            raise ValueError(
                f"view [{offset}, {offset + nbytes}) exceeds arena user "
                f"region of {self.size} bytes")
        return self._shm.buf[start:start + nbytes]

    def ndarray(self, offset: int, shape, dtype) -> np.ndarray:
        """A typed zero-copy numpy view into the user region."""
        dtype = np.dtype(dtype)
        nbytes = int(np.prod(shape)) * dtype.itemsize
        return np.frombuffer(self.view(offset, nbytes), dtype).reshape(shape)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Unmap the segment from this process (unlink destroys it)."""
        if self._closed:
            return
        self._closed = True
        try:
            self._shm.close()
        except BufferError:
            # a numpy view into the segment is still alive somewhere; collect
            # dropped references and retry once before giving up loudly
            import gc
            gc.collect()
            self._shm.close()

    def unlink(self) -> None:
        """Destroy the segment (creator-side, after both ends closed)."""
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        if self.is_creator:
            self.unlink()


def attach_retry(name: str, timeout_s: float = 10.0,
                 interval_s: float = 0.01) -> SharedMemoryArena:
    """Attach to an arena that a peer process may not have created yet.

    A ValueError (bad magic/version) is also retried within the window: the
    segment becomes visible before the creator finishes pre-touching and
    writing the header, so an early attacher can read zeros where the magic
    belongs.  Only at the deadline is it surfaced as a real mismatch.
    """
    deadline = time.perf_counter() + timeout_s
    while True:
        try:
            return SharedMemoryArena(name, create=False)
        except FileNotFoundError:
            if time.perf_counter() > deadline:
                raise TimeoutError(f"arena {name!r} never appeared")
        except ValueError:
            if time.perf_counter() > deadline:
                raise
        time.sleep(interval_s)
